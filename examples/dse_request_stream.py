"""Request-stream serving DSE: search {batch window, max inflight,
prefill_frac, decode_batch} (plus the full workload/collective/network
stacks) against an arrival-driven request load — as one declarative study.

Requests arrive by a Poisson process, queue, and admit in waves under the
searched batching window; admitted waves run through disaggregated
prefill/decode pools as ONE pipelined multi-wave trace.  The reward is
streaming: goodput = requests meeting both the TTFT and TPOT SLOs, per
second.  ``--prompt-len-range``/``--decode-len-range`` switch the stream to
heterogeneous per-request lengths drawn from a seeded distribution.

Also prints the pipelined-vs-analytic disagg comparison on a multi-wave
load point (the pipelined multi-wave trace must beat the analytic
composition there).

    PYTHONPATH=src python examples/dse_request_stream.py [--steps 500]
                                [--arch gpt3-13b] [--rate 8] [--requests 64]
                                [--prompt-len-range 256 2048]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # for benchmarks/

from benchmarks.common import PIPELINE_COMPARE_ARCH, compare_pipelined_vs_analytic
from repro.core.study import StudySpec, run_study


def print_pipelined_vs_analytic() -> None:
    evs = compare_pipelined_vs_analytic()
    pipe, anal = evs[True], evs[False]
    verdict = "beats" if pipe.latency_ms < anal.latency_ms else "does NOT beat"
    print(f"\npipelined multi-wave trace {verdict} the analytic composition "
          f"on {PIPELINE_COMPARE_ARCH}/system2 (512 requests, "
          f"{pipe.detail['waves']} waves): "
          f"{pipe.latency_ms:.1f} ms vs {anal.latency_ms:.1f} ms "
          f"(x{anal.latency_ms / max(pipe.latency_ms, 1e-9):.3f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--arch", default="gpt3-13b")
    ap.add_argument("--system", default="system2",
                    choices=["system1", "system2", "system3"])
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/sec")
    ap.add_argument("--requests", type=int, default=64,
                    help="requests in the simulated stream")
    ap.add_argument("--seq", type=int, default=2048, help="prompt length")
    ap.add_argument("--decode-tokens", type=int, default=64)
    ap.add_argument("--prompt-len-range", type=int, nargs=2, default=None,
                    metavar=("LO", "HI"),
                    help="per-request prompt lengths ~ seeded uniform")
    ap.add_argument("--decode-len-range", type=int, nargs=2, default=None,
                    metavar=("LO", "HI"),
                    help="per-request decode lengths ~ seeded uniform")
    ap.add_argument("--ttft-slo-ms", type=float, default=4000.0)
    ap.add_argument("--tpot-slo-ms", type=float, default=200.0)
    ap.add_argument("--batch-size", type=int, default=32,
                    help="population evaluated per agent round")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    params = dict(n_requests=args.requests, seq=args.seq,
                  decode_tokens=args.decode_tokens, rate_rps=args.rate,
                  seed=args.seed, ttft_slo_ms=args.ttft_slo_ms,
                  tpot_slo_ms=args.tpot_slo_ms)
    if args.prompt_len_range:
        params["prompt_len_range"] = tuple(args.prompt_len_range)
    if args.decode_len_range:
        params["decode_len_range"] = tuple(args.decode_len_range)
    spec = StudySpec(
        name="request-stream", arch=args.arch, system=args.system,
        scenario="request-stream", scenario_params=params,
        objective="goodput", agents=("ga",), seeds=(args.seed,),
        steps=args.steps, batch_size=args.batch_size, workers=args.workers)
    res = run_study(spec).outcomes[0].result

    print(f"request-stream GA @ {args.steps} steps on {args.arch}/"
          f"{args.system}, {args.rate} req/s Poisson load:")
    print(f"  best goodput {res.best_reward:.2f} req/s meeting SLOs "
          f"(TTFT<={args.ttft_slo_ms:.0f}ms, TPOT<={args.tpot_slo_ms:.0f}ms);"
          f" steps_to_peak {res.steps_to_peak}, "
          f"points_per_s {res.points_per_s:.0f}")
    if res.best_config:
        cfg = res.best_config
        d = spec.build_env().evaluate_config(cfg).detail
        print(f"  best design: DP={cfg['dp']} SP={cfg['sp']} PP={cfg['pp']} "
              f"prefill_frac={cfg['prefill_frac']} "
              f"decode_batch={cfg['decode_batch']} "
              f"window={cfg['batch_window_ms']}ms "
              f"max_inflight={cfg['max_inflight']}")
        print(f"  TTFT p50/p99 {d['ttft_p50_ms']:.1f}/{d['ttft_p99_ms']:.1f} "
              f"ms; TPOT p50/p99 {d['tpot_p50_ms']:.2f}/{d['tpot_p99_ms']:.2f}"
              f" ms; goodput {d['goodput_rps']:.2f} req/s "
              f"({d['n_ok']}/{d['n_requests']} in SLO over "
              f"{d['horizon_ms']:.0f} ms, {d['waves']} waves)")
        if "prompt_len_mean" in d:
            print(f"  heterogeneous lengths: prompt mean/max "
                  f"{d['prompt_len_mean']:.0f}/{d['prompt_len_max']} tok, "
                  f"decode mean/max {d['decode_len_mean']:.1f}/"
                  f"{d['decode_len_max']} tok")

    print_pipelined_vs_analytic()


if __name__ == "__main__":
    main()
