"""Quickstart: the full COSMIC loop in one minute.

1. Search the full-stack design space for a GPT3-13B training cluster.
2. Map the discovered workload design onto an executable JAX mesh plan.
3. Train a (reduced) qwen2-family model a few steps with the real runtime.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.bridge import plan_from_design
from repro.core.compute import SYSTEM_1_DEVICE
from repro.core.dse import run_search
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.workload import Parallelism
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train.train_step import RunConfig, init_train_state, make_train_step


def main():
    # -- 1. agent-based full-stack DSE (paper Sections 4-6) ---------------
    spec = ARCHS["gpt3-13b"]
    env = CosmicEnv(spec=spec, n_npus=512, device=SYSTEM_1_DEVICE,
                    batch=512, seq=2048)
    res = run_search(paper_psa(512), env, "ga", steps=300, seed=0)
    cfg = res.best_config
    print(f"[dse] best reward {res.best_reward:.3e} "
          f"latency {res.best_latency_ms:.1f} ms at step {res.steps_to_peak}")
    print(f"[dse] discovered workload: DP={cfg['dp']} SP={cfg['sp']} PP={cfg['pp']} "
          f"ZeRO={cfg['weight_sharded']} | collectives {cfg['coll_algo']} "
          f"| topology {cfg['topology']}")

    # -- 2. the design point is executable -------------------------------
    par = Parallelism(512, cfg["dp"], cfg["sp"], cfg["pp"], bool(cfg["weight_sharded"]))
    plan = plan_from_design(par)
    print(f"[bridge] mesh plan: shape={plan.shape} axes={plan.axis_names} "
          f"fsdp={plan.fsdp} sp={plan.sp}")

    # -- 3. train a real (reduced) model with the runtime ------------------
    mspec = reduced(ARCHS["qwen2-1.5b"])
    run_cfg = RunConfig(remat="none")
    state = init_train_state(jax.random.PRNGKey(0), mspec, run_cfg)
    step = jax.jit(make_train_step(mspec, cfg=run_cfg))
    data = SyntheticLM(mspec, DataConfig(global_batch=8, seq_len=64, seed=0))
    for i in range(20):
        state, metrics = step(state, data.batch_at(i))
        if i % 5 == 0:
            print(f"[train] step {i} loss {float(metrics['loss']):.4f}")
    print("[done] quickstart complete")


if __name__ == "__main__":
    main()
