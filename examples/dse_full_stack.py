"""The paper's headline experiment, runnable at desk scale:
full-stack vs single-stack DSE for GPT3-175B (Fig. 6), with all four agents
compared (Fig. 10) — each experiment a declarative ``StudySpec`` executed by
the campaign runner (shared eval_store + process pool across cells).

Every study here can be serialized (``--dump-specs DIR``) and re-run
bit-identically via ``python -m repro.dse run <spec>.json``.

    PYTHONPATH=src python examples/dse_full_stack.py [--steps 600]
                                                     [--batch 32] [--workers 0]
"""
import argparse

from repro.core.study import StudySpec, run_study

STACK_SCENARIOS = {
    "workload-only": ("workload",),
    "collective-only": ("collective",),
    "network-only": ("network",),
    "full-stack": None,
}


def stack_study(stacks, args, *, agents=("ga",), name: str) -> StudySpec:
    return StudySpec(
        name=name, arch="gpt3-175b", system=args.system,
        scenario="train", objective="perf_per_bw",
        stacks=stacks, agents=agents, seeds=(0,), steps=args.steps,
        batch_size=args.batch, workers=args.workers)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--system", default="system2", choices=["system1", "system2", "system3"])
    ap.add_argument("--batch", type=int, default=32,
                    help="population evaluated per agent round (1 = sequential)")
    ap.add_argument("--workers", type=int, default=0,
                    help=">1 fans each batch out to a process pool")
    ap.add_argument("--dump-specs", default=None,
                    help="also write each StudySpec JSON into this directory")
    args = ap.parse_args()

    def maybe_dump(spec: StudySpec) -> StudySpec:
        if args.dump_specs:
            from pathlib import Path
            d = Path(args.dump_specs)
            d.mkdir(parents=True, exist_ok=True)
            spec.to_json(d / f"{spec.name}.json")
        return spec

    print(f"== single-stack vs full-stack (GPT3-175B, {args.system}, GA, "
          f"batch={args.batch}) ==")
    best = {}
    for name, stacks in STACK_SCENARIOS.items():
        spec = maybe_dump(stack_study(stacks, args, name=f"fullstack-{name}"))
        res = run_study(spec).outcomes[0].result
        best[name] = res
        print(f"{name:16s} reward={res.best_reward:.3e} "
              f"latency={res.best_latency_ms:9.1f} ms "
              f"steps_to_peak={res.steps_to_peak} "
              f"points_per_s={res.points_per_s:7.0f}")
    full = best["full-stack"].best_reward
    for name in STACK_SCENARIOS:
        if name != "full-stack":
            print(f"full-stack vs {name}: x{full / max(best[name].best_reward, 1e-30):.2f}")

    print(f"\n== agent comparison (full stack, {args.steps} steps) ==")
    # one study, four agents, one shared eval_store: BO's cubic GP cost caps
    # its per-cell budget at 200 steps
    spec = maybe_dump(stack_study(
        None, args, name="fullstack-agents",
        agents=("rw", "ga", "aco",
                {"kind": "bo", "steps": min(args.steps, 200)})))
    for cell in run_study(spec).outcomes:
        res = cell.result
        print(f"{cell.agent:4s} best={res.best_reward:.3e} steps_to_peak={res.steps_to_peak} "
              f"invalid_rate={res.invalid_rate:.2f} "
              f"points_per_s={res.points_per_s:.0f}")


if __name__ == "__main__":
    main()
