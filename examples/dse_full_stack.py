"""The paper's headline experiment, runnable at desk scale:
full-stack vs single-stack DSE for GPT3-175B (Fig. 6), with all four agents
compared (Fig. 10), driven by the batched evaluation engine.

    PYTHONPATH=src python examples/dse_full_stack.py [--steps 600]
                                                     [--batch 32] [--workers 0]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # for benchmarks/

from benchmarks.common import BASE_DEFAULTS, WORKLOAD_DEFAULTS, make_env, make_pset
from repro.core.dse import run_search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--system", default="system2", choices=["system1", "system2", "system3"])
    ap.add_argument("--batch", type=int, default=32,
                    help="population evaluated per agent round (1 = sequential)")
    ap.add_argument("--workers", type=int, default=0,
                    help=">1 fans each batch out to a process pool")
    args = ap.parse_args()

    scenarios = {
        "workload-only": {"workload"},
        "collective-only": {"collective"},
        "network-only": {"network"},
        "full-stack": None,
    }
    print(f"== single-stack vs full-stack (GPT3-175B, {args.system}, GA, "
          f"batch={args.batch}) ==")
    best = {}
    for name, stacks in scenarios.items():
        ps = make_pset(args.system, stacks=stacks)
        with make_env("gpt3-175b", args.system) as env:
            res = run_search(ps, env, "ga", steps=args.steps, seed=0,
                             batch_size=args.batch, workers=args.workers)
        best[name] = res
        print(f"{name:16s} reward={res.best_reward:.3e} "
              f"latency={res.best_latency_ms:9.1f} ms "
              f"steps_to_peak={res.steps_to_peak} "
              f"points_per_s={res.points_per_s:7.0f}")
    full = best["full-stack"].best_reward
    for name in scenarios:
        if name != "full-stack":
            print(f"full-stack vs {name}: x{full / max(best[name].best_reward, 1e-30):.2f}")

    print(f"\n== agent comparison (full stack, {args.steps} steps) ==")
    for agent in ("rw", "ga", "aco", "bo"):
        steps = min(args.steps, 200) if agent == "bo" else args.steps
        with make_env("gpt3-175b", args.system) as env:
            res = run_search(make_pset(args.system), env, agent, steps=steps,
                             seed=0, batch_size=args.batch, workers=args.workers)
        print(f"{agent:4s} best={res.best_reward:.3e} steps_to_peak={res.steps_to_peak} "
              f"invalid_rate={res.invalid_rate:.2f} "
              f"points_per_s={res.points_per_s:.0f}")


if __name__ == "__main__":
    main()
