"""End-to-end training driver: a qwen2-family LM on the synthetic pipeline
with checkpointing, straggler monitoring, and resume — a few hundred steps.

Defaults to a ~5M-parameter model so a few hundred steps complete in
minutes on CPU; pass --d-model 512 --layers 8 (~100M with the full vocab)
on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import sys

from repro.configs import ARCHS, reduced
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    spec = reduced(ARCHS["qwen2-1.5b"],
                   d_model=args.d_model, n_layers=args.layers,
                   d_ff=args.d_model * 4, vocab_size=2048, head_dim=32)
    print(f"[train_lm] {spec.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    ns = argparse.Namespace(
        steps=args.steps, batch=args.batch, seq=args.seq, lr=3e-3, warmup=20,
        seed=0, bf16=False, remat="none", microbatches=1, mesh="",
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=20,
        straggler_sigma=3.0)
    train_loop(ns, spec)


if __name__ == "__main__":
    main()
