"""Fleet serving DSE: search the router, the autoscaler, the continuous-
batching engine knobs, and the full workload/collective/network stacks of a
multi-replica serving fleet against a diurnal request trace — as one
declarative study on goodput per provisioned dollar.

The fleet carves the cluster into N replica partitions, routes every
request to a replica (round-robin / least-outstanding / prefix-affinity
hash), and autoscale decisions (target-utilization, cooldown-limited)
set how many replicas are provisioned per epoch; each replica's routed
sub-stream then runs through the pipelined request-stream engine.  The
reward divides SLO goodput by the dollars actually provisioned, so a
policy that sheds idle replicas during traffic troughs wins over static
uniform provisioning.

Also prints the same-budget STATIC UNIFORM baseline (router pinned to
round-robin, autoscaling off): on a diurnal trace the searched fleet
should strictly beat it.

    PYTHONPATH=src python examples/dse_fleet.py [--steps 400]
                            [--arch qwen2-1.5b] [--replicas 4]
                            [--arrival diurnal] [--rate 24]
"""
import argparse

from repro.core.study import StudySpec, run_study


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--system", default="system2",
                    choices=["system1", "system2", "system3"])
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--arrival", default="diurnal",
                    choices=["poisson", "diurnal", "bursty"])
    ap.add_argument("--rate", type=float, default=24.0,
                    help="base arrival rate, requests/sec")
    ap.add_argument("--period", type=float, default=30.0,
                    help="diurnal period, seconds")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--epoch", type=float, default=5.0,
                    help="autoscaler decision epoch, seconds")
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    params = dict(n_requests=args.requests, seq=args.seq,
                  decode_tokens=args.decode_tokens, arrival=args.arrival,
                  rate_rps=args.rate, period_s=args.period,
                  replicas=args.replicas, epoch_s=args.epoch,
                  seed=args.seed)

    def study(name, overrides):
        spec = StudySpec(
            name=name, arch=args.arch, system=args.system, scenario="fleet",
            scenario_params=params, objective="goodput_per_dollar",
            agents=("ga",), seeds=(args.seed,), steps=args.steps,
            batch_size=args.batch_size, psa_overrides=overrides)
        return spec, run_study(spec).outcomes[0].result

    _, static = study(
        "fleet-static", dict(router="round-robin", autoscale_target=0.0,
                             autoscale_cooldown_s=10.0))
    spec, searched = study("fleet-searched", {})

    # the fleet knobs are cheap next to the engine/parallelism search:
    # polish both winners with the exhaustive router x autoscaler grid
    env, sc = spec.build_env(), spec.build_scenario()
    best_reward = searched.best_reward
    best_config = searched.best_config
    for seed_cfg in (searched.best_config, static.best_config):
        if not seed_cfg:
            continue
        for router in sc.routers:
            for target in sc.autoscale_targets:
                for cd in sc.autoscale_cooldowns_s:
                    cfg = dict(seed_cfg, router=router,
                               autoscale_target=target,
                               autoscale_cooldown_s=cd)
                    ev = env.evaluate_config(cfg)
                    if ev.valid and ev.reward > best_reward:
                        best_reward, best_config = ev.reward, cfg

    print(f"fleet GA @ {args.steps} steps on {args.arch}/{args.system}: "
          f"{args.replicas} replicas, {args.arrival} arrivals "
          f"@ {args.rate} req/s base:")
    print(f"  static uniform baseline: {static.best_reward:.3f} "
          f"goodput/$M (router=round-robin, autoscaling off)")
    print(f"  searched fleet:          {best_reward:.3f} goodput/$M "
          f"(x{best_reward / max(static.best_reward, 1e-9):.2f})")
    if best_config:
        cfg = best_config
        d = env.evaluate_config(cfg).detail
        print(f"  best policy: router={cfg['router']} "
              f"autoscale_target={cfg['autoscale_target']} "
              f"cooldown={cfg['autoscale_cooldown_s']}s; engine "
              f"window={cfg['batch_window_ms']}ms "
              f"max_inflight={cfg['max_inflight']} "
              f"DP={cfg['dp']} SP={cfg['sp']} PP={cfg['pp']}")
        print(f"  goodput {d['goodput_rps']:.2f} req/s over "
              f"{d['horizon_ms']:.0f} ms; provisioned "
              f"${d['provisioned_cost']:.0f} "
              f"(active per epoch: {d['active_per_epoch']}); "
              f"requests per replica: {d['replica_requests']}")


if __name__ == "__main__":
    main()
