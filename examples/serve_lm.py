"""Batched serving: prefill + decode with KV caches through the Engine.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --new 24
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    spec = reduced(ARCHS[args.arch])
    params = M.init_params(jax.random.PRNGKey(0), spec)
    eng = Engine(spec, params, max_len=args.prompt_len + args.new)

    prompts = np.random.default_rng(0).integers(
        0, spec.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    out, stats = eng.generate(prompts, max_new=args.new,
                              temperature=args.temperature)
    print(f"[serve] prefill {stats.prefill_s*1e3:.0f} ms, "
          f"decode {stats.decode_tok_per_s:.1f} tok/s "
          f"({stats.tokens_out} tokens)")
    for i, row in enumerate(out[: min(4, len(out))]):
        print(f"  request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
