"""Disaggregated-serving DSE: does splitting the cluster into prefill and
decode pools beat the best monolithic serving config the same search budget
can find?

Two declarative studies over the same system and budget:

  monolithic  scenario="train" (mode="serve") — one pool, one
              parallelization for both phases;
  disagg      scenario="disagg-serve" — the agent additionally searches the
              scenario stack (prefill_frac, decode_batch), so prefill can
              keep MXU-efficient moderate TP while decode shards weight/KV
              reads across its own pool.

    PYTHONPATH=src python examples/dse_disagg_serve.py [--steps 500]
                                [--arch gpt3-13b] [--batch-size 32]
"""
import argparse

from repro.core.study import StudySpec, run_study


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--arch", default="gpt3-13b")
    ap.add_argument("--system", default="system2",
                    choices=["system1", "system2", "system3"])
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per serving round")
    ap.add_argument("--seq", type=int, default=2048, help="prompt length")
    ap.add_argument("--decode-tokens", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=32,
                    help="population evaluated per agent round")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    scenarios = {
        "monolithic": ("train", dict(batch=args.requests, seq=args.seq,
                                     mode="serve",
                                     decode_tokens=args.decode_tokens)),
        "disagg": ("disagg-serve", dict(batch=args.requests, seq=args.seq,
                                        decode_tokens=args.decode_tokens)),
    }
    results = {}
    for name, (kind, params) in scenarios.items():
        spec = StudySpec(
            name=f"serve-{name}", arch=args.arch, system=args.system,
            scenario=kind, scenario_params=params, objective="latency",
            agents=("ga",), seeds=(args.seed,), steps=args.steps,
            batch_size=args.batch_size, workers=args.workers)
        res = run_study(spec).outcomes[0].result
        results[name] = res
        print(f"{name:10s} best e2e latency {res.best_latency_ms:9.1f} ms "
              f"(reward {res.best_reward:.3e}, steps_to_peak "
              f"{res.steps_to_peak}, points_per_s {res.points_per_s:.0f})")
        if res.best_config:
            cfg = res.best_config
            knobs = f"DP={cfg['dp']} SP={cfg['sp']} PP={cfg['pp']}"
            if "prefill_frac" in cfg:
                knobs += (f" prefill_frac={cfg['prefill_frac']} "
                          f"decode_batch={cfg['decode_batch']}")
            print(f"{'':10s} {knobs}")

    mono, disagg = results["monolithic"], results["disagg"]
    speedup = mono.best_latency_ms / max(disagg.best_latency_ms, 1e-9)
    verdict = "beats" if disagg.best_latency_ms < mono.best_latency_ms \
        else "does NOT beat"
    print(f"\ndisaggregation {verdict} the best monolithic config: "
          f"{disagg.best_latency_ms:.1f} ms vs {mono.best_latency_ms:.1f} ms "
          f"(x{speedup:.2f})")


if __name__ == "__main__":
    main()
