"""Losses. Cross-entropy upcasts to fp32 at the logsumexp only."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, ignore_index: int = -1):
    """logits: (B, S, V); labels: (B, S) int32. Returns mean nll over valid.

    The gold logit is selected with an iota-compare masked sum rather than
    ``take_along_axis``: a gather over a vocab-SHARDED logits tensor makes
    XLA's SPMD partitioner replicate the whole (B, S, V) fp32 array (an
    all-gather measured in the hundreds of GB/step on 100k+ vocabularies);
    the masked sum keeps the reduction local + one tiny all-reduce."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    v = lf.shape[-1]
    idx = jnp.maximum(labels, 0).astype(jnp.int32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == idx[..., None], lf, 0.0), axis=-1)
    nll = lse - gold
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(hidden, head_fn, labels, *, chunk: int = 512,
                          ignore_index: int = -1):
    """CE without ever materializing the full (B, S, V) logits.

    hidden: (B, S, D); head_fn(hidden_chunk) -> (B, c, V) logits.  Scans over
    sequence chunks, computing the head projection + logsumexp per chunk
    (remat'd so the backward recomputes each chunk too).  For 150k-260k
    vocabularies this removes the dominant fp32 activation from the memory
    roofline term (§Perf).
    """
    b, s, d = hidden.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    hc = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        tot, cnt = carry
        h, lab = xs
        logits = head_fn(h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        idx = jnp.maximum(lab, 0)[..., None].astype(jnp.int32)
        gold = jnp.take_along_axis(logits, idx, axis=-1)[..., 0]
        mask = (lab != ignore_index).astype(jnp.float32)
        return (tot + jnp.sum((lse - gold) * mask), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
