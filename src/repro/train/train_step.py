"""Train-step factory: microbatched grad accumulation + AdamW + sharding.

``make_train_step`` returns pure functions suitable for jit/lower on any
mesh; everything (remat policy, microbatches, dtypes) is a RunConfig knob so
the roofline perf loop can sweep them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models import model as M
from repro.parallel.sharding import NULL_PLAN, ShardingPlan
from repro.train import optimizer as opt
from repro.train.loss import cross_entropy


@dataclass(frozen=True)
class RunConfig:
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    remat: str = "dots"          # none | dots | full | save_kv
    microbatches: int = 1
    lb_weight: float = 0.01      # MoE load-balance loss weight
    loss_chunk: int = 0          # >0: chunked CE (never materialize logits)
    opt: opt.OptConfig = opt.OptConfig()

    def with_(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


BF16_RUN = RunConfig(compute_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)


def batch_abstract(spec: ArchSpec, batch: int, seq: int, compute_dtype=jnp.bfloat16):
    if spec.frontend == "tokens":
        inp = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    else:
        inp = jax.ShapeDtypeStruct((batch, seq, spec.d_model), compute_dtype)
    return {"inputs": inp, "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def batch_axes(spec: ArchSpec):
    inp = ("batch", None) if spec.frontend == "tokens" else ("batch", None, None)
    return {"inputs": inp, "labels": ("batch", None)}


def make_loss_fn(spec: ArchSpec, plan: ShardingPlan, cfg: RunConfig):
    from repro.train.loss import chunked_cross_entropy

    def loss_fn(params, batch):
        if cfg.loss_chunk > 0:
            hidden, aux = M.forward_hidden(params, batch["inputs"], spec, plan,
                                           compute_dtype=cfg.compute_dtype,
                                           remat=cfg.remat)
            ce = chunked_cross_entropy(hidden, M.head_fn(params, spec, plan),
                                       batch["labels"], chunk=cfg.loss_chunk)
        else:
            logits, aux = M.forward(params, batch["inputs"], spec, plan,
                                    compute_dtype=cfg.compute_dtype, remat=cfg.remat)
            ce = cross_entropy(logits, batch["labels"])
        loss = ce + cfg.lb_weight * aux
        return loss, {"ce": ce, "lb": aux}

    return loss_fn


def make_train_step(spec: ArchSpec, plan: ShardingPlan = NULL_PLAN,
                    cfg: RunConfig = RunConfig(), opt_plan: ShardingPlan | None = None):
    """opt_plan: optional sharding plan for gradients/optimizer state.  When
    weights are partially replicated (attn_dp/mamba_dp), gradients are
    reduce-SCATTERED into this fully-sharded layout per microbatch and
    parameters re-gathered once per step — ZeRO-2 semantics, instead of a
    full gradient all-reduce every microbatch."""
    loss_fn = make_loss_fn(spec, plan, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    _axes = M.param_axes(spec)

    def shard_grads(g):
        if opt_plan is None:
            return g
        return jax.tree.map(
            lambda ax, x: opt_plan.constrain(x, ax), _axes, g,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    def train_step(state, batch):
        params = state["params"]
        if cfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = shard_grads(grads)
        else:
            k = cfg.microbatches
            bsz = jax.tree.leaves(batch)[0].shape[0]
            mb = bsz // k
            assert bsz % k == 0, (bsz, k)

            def mb_body(carry, i):
                acc, loss_acc = carry
                sl = jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, i * mb, mb, 0), batch)
                (l, _), g = grad_fn(params, sl)
                g = shard_grads(g)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zero = shard_grads(jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(mb_body, (zero, 0.0), jnp.arange(k))
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
            metrics = {}
        new_state, om = opt.apply_updates(state, grads, cfg.opt)
        out = {"loss": loss, **{k: v for k, v in metrics.items()}, **om}
        return new_state, out

    return train_step


def init_train_state(rng, spec: ArchSpec, cfg: RunConfig = RunConfig()):
    params = M.init_params(rng, spec, jnp.float32)
    return opt.init_state(params, cfg.param_dtype)


def abstract_train_state(spec: ArchSpec, cfg: RunConfig = RunConfig()):
    return opt.abstract_state(M.abstract_params(spec), cfg.param_dtype)


def train_state_axes(spec: ArchSpec, cfg: RunConfig = RunConfig()):
    return opt.state_axes(M.param_axes(spec), cfg.param_dtype)
