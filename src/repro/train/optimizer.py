"""AdamW with mixed precision + optional ZeRO-style sharded states.

State layout (plain dict pytree, transparent to pjit/checkpointing):
  params : compute-precision weights (bf16 on TPU)
  master : fp32 master copy (omitted when param_dtype is fp32)
  m, v   : fp32 moments — sharded exactly like params, which under the
           Weight-Sharded plan means optimizer state is ZeRO-partitioned
  step   : int32
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio * cfg.lr + 0.5 * (1 - cfg.min_lr_ratio) * cfg.lr * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params, param_dtype=jnp.float32) -> dict[str, Any]:
    f32 = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    state = {
        "params": jax.tree.map(lambda a: a.astype(param_dtype), params),
        "m": f32(params),
        "v": f32(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if param_dtype != jnp.float32:
        state["master"] = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    return state


def abstract_state(abstract_params, param_dtype=jnp.float32):
    sds = lambda a, dt: jax.ShapeDtypeStruct(a.shape, dt)
    state = {
        "params": jax.tree.map(lambda a: sds(a, param_dtype), abstract_params),
        "m": jax.tree.map(lambda a: sds(a, jnp.float32), abstract_params),
        "v": jax.tree.map(lambda a: sds(a, jnp.float32), abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if param_dtype != jnp.float32:
        state["master"] = jax.tree.map(lambda a: sds(a, jnp.float32), abstract_params)
    return state


def state_axes(param_axes_tree, param_dtype=jnp.float32):
    """Logical-axes tree mirroring the state (for ShardingPlan.spec)."""
    state = {
        "params": param_axes_tree,
        "m": param_axes_tree,
        "v": param_axes_tree,
        "step": (),
    }
    if param_dtype != jnp.float32:
        state["master"] = param_axes_tree
    return state


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32))) for a in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(state: dict[str, Any], grads, cfg: OptConfig) -> tuple[dict[str, Any], dict[str, Any]]:
    """One AdamW step.  grads: tree matching params (any float dtype)."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(g32)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    g32 = jax.tree.map(lambda g: g * scale, g32)

    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    master = state.get("master", state["params"])
    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], g32)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], g32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))

    new_master = jax.tree.map(upd, master, new_m, new_v)
    param_dtype = jax.tree.leaves(state["params"])[0].dtype
    new_state = dict(state)
    new_state["m"], new_state["v"], new_state["step"] = new_m, new_v, step
    if "master" in state:
        new_state["master"] = new_master
        new_state["params"] = jax.tree.map(lambda a: a.astype(param_dtype), new_master)
    else:
        new_state["params"] = new_master
    return new_state, {"grad_norm": gnorm, "lr": lr}
