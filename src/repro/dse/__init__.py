"""``python -m repro.dse`` — the Study CLI.

    python -m repro.dse run study.json [--out results.jsonl] [--resume]
    python -m repro.dse list-scenarios
    python -m repro.dse list-systems
    python -m repro.dse list-objectives

``run`` executes a serialized ``StudySpec`` as one campaign (shared
eval_store + process pool across the (agent x seed) grid), streaming
per-cell results to a JSONL file next to the spec; ``--resume`` finishes a
half-done campaign without re-evaluating completed cells.  The ``list-*``
commands enumerate the registries a spec's names resolve through.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from pathlib import Path


def _cmd_run(args: argparse.Namespace) -> int:
    from repro.core.study import StudySpec, run_study

    say = (lambda s: None) if args.quiet else print
    try:
        spec = StudySpec.from_json(Path(args.spec))
        if args.steps is not None or args.workers is not None:
            # a --steps override changes the spec (and its hash): a resumed
            # run must use the same override as the original.  --workers
            # only changes evaluation parallelism and is hash-exempt.
            spec = dataclasses.replace(
                spec,
                steps=args.steps if args.steps is not None else spec.steps,
                workers=args.workers if args.workers is not None
                else spec.workers)
        say(f"study {spec.name!r} [{spec.spec_hash()}]: "
            f"{spec.arch} on {spec.system}, scenario={spec.scenario}, "
            f"objective={spec.objective}, "
            f"{len(spec.agents)} agent(s) x {len(spec.seeds)} seed(s)")
        out = Path(args.out) if args.out else \
            Path(args.spec).with_suffix(".results.jsonl")
        res = run_study(spec, out=out, resume=args.resume, log=say)
    except (ValueError, OSError) as e:
        # ValueError covers spec validation + resume refusals + bad JSON
        # (json.JSONDecodeError subclasses it); OSError covers missing files
        print(f"error: {e}", file=sys.stderr)
        return 2
    best = res.best()
    if best is not None:
        say(f"best cell {best.cell_id}: reward={best.result.best_reward:.6g}"
            f" latency_ms={best.result.best_latency_ms:.1f}")
    # the stable machine-readable trailer (CI greps cells_run on resume)
    print(f"campaign done: cells_run={res.cells_run} "
          f"cells_skipped={res.cells_skipped} store_hits={res.store_hits} "
          f"store_misses={res.store_misses} "
          f"distinct_points={res.distinct_points} "
          f"wall_s={res.wall_s:.1f} results={res.out}")
    return 0


def _cmd_list_scenarios(args: argparse.Namespace) -> int:
    from repro.core.scenario import list_scenarios

    for kind, doc in sorted(list_scenarios().items()):
        print(f"{kind:16s} {doc}")
    return 0


def _cmd_list_systems(args: argparse.Namespace) -> int:
    from repro.core.systems import list_systems

    for name, p in sorted(list_systems().items()):
        print(f"{name:10s} n_npus={p.n_npus:<5d} device={p.device.name:18s} "
              f"{p.doc}")
    return 0


def _cmd_list_objectives(args: argparse.Namespace) -> int:
    from repro.core.rewards import list_objectives

    for name, obj in sorted(list_objectives().items()):
        kind = "stream" if obj.streaming else "scalar"
        print(f"{name:18s} [{kind}] {obj.doc}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse",
        description="Run serialized DSE studies and inspect the registries "
                    "their names resolve through.")
    sub = ap.add_subparsers(dest="command", required=True)

    run_p = sub.add_parser("run", help="execute a StudySpec JSON file")
    run_p.add_argument("spec", help="path to the study .json")
    run_p.add_argument("--out", default=None,
                       help="results JSONL path (default: <spec>.results.jsonl)")
    run_p.add_argument("--resume", action="store_true",
                       help="skip cells already in the results file")
    run_p.add_argument("--steps", type=int, default=None,
                       help="override the spec's step budget")
    run_p.add_argument("--workers", type=int, default=None,
                       help="override the spec's process-pool size")
    run_p.add_argument("--quiet", action="store_true",
                       help="only print the final campaign trailer")
    run_p.set_defaults(fn=_cmd_run)

    sub.add_parser("list-scenarios",
                   help="registered scenario kinds").set_defaults(
        fn=_cmd_list_scenarios)
    sub.add_parser("list-systems",
                   help="registered system presets").set_defaults(
        fn=_cmd_list_systems)
    sub.add_parser("list-objectives",
                   help="registered objectives").set_defaults(
        fn=_cmd_list_objectives)

    args = ap.parse_args(argv)
    return args.fn(args)
