"""``python -m repro.dse`` — the Study CLI.

    python -m repro.dse run study.json [--out results.jsonl] [--resume]
                                       [--backend reference|jax]
    python -m repro.dse lint study.json
    python -m repro.dse analyze results.jsonl
    python -m repro.dse compare a.results.jsonl b.results.jsonl
    python -m repro.dse store stats evals.jsonl
    python -m repro.dse list-scenarios
    python -m repro.dse list-systems
    python -m repro.dse list-objectives
    python -m repro.dse list-backends

``run`` executes a serialized ``StudySpec`` as one campaign (shared
eval_store + process pool across the (agent x seed) grid), streaming
per-cell results to a JSONL file next to the spec; ``--resume`` finishes a
half-done campaign without re-evaluating completed cells.  ``lint``
statically checks a spec WITHOUT running it: every registry name resolves,
the constraint set is satisfiable, no searched knob is dead, and a probe
design point's scheduling plan verifies — plus campaign shape/cost
(cells, max evaluations, raw cardinality).  ``analyze`` re-derives each
recorded cell's best design point and prints its critical-path bottleneck
attribution (compute vs collective vs xfer vs gate).  ``compare`` prints a
per-cell best-reward table over two results files and a one-line winner
summary.  ``store stats`` inventories a persistent eval store: records,
valid counts, and reward spread per ``eval_signature()`` — the corpus a
surrogate agent warm-starts from.  The ``list-*`` commands enumerate the
registries a spec's names resolve through.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from pathlib import Path


def _cmd_run(args: argparse.Namespace) -> int:
    from repro.core.analysis import PlanVerificationError
    from repro.core.study import StudySpec, run_study

    say = (lambda s: None) if args.quiet else print
    try:
        spec = StudySpec.from_json(Path(args.spec))
        if args.steps is not None or args.workers is not None \
                or args.backend is not None:
            # a --steps or --backend override changes the spec (and its
            # hash): a resumed run must use the same override as the
            # original.  --workers only changes evaluation parallelism and
            # is hash-exempt.
            spec = dataclasses.replace(
                spec,
                steps=args.steps if args.steps is not None else spec.steps,
                workers=args.workers if args.workers is not None
                else spec.workers,
                backend=args.backend if args.backend is not None
                else spec.backend)
        say(f"study {spec.name!r} [{spec.spec_hash()}]: "
            f"{spec.arch} on {spec.system}, scenario={spec.scenario}, "
            f"objective={spec.objective}, backend={spec.backend}, "
            f"{len(spec.agents)} agent(s) x {len(spec.seeds)} seed(s)")
        # instantiate the backend BEFORE run_study touches the results
        # file: a missing optional dep (the jax extra) must fail with a
        # clean error, not a traceback after the header was written
        from repro.core.backends import get_backend
        get_backend(spec.backend)
        out = Path(args.out) if args.out else \
            Path(args.spec).with_suffix(".results.jsonl")
        res = run_study(spec, out=out, resume=args.resume, log=say)
    except PlanVerificationError as e:
        # the per-cell preflight gate: a defective scheduling plan (cycle,
        # dangling reference, infeasible pool) fails fast with the report
        print(f"error: static verification failed\n{e.report.format()}",
              file=sys.stderr)
        return 2
    except (ValueError, OSError, ImportError) as e:
        # ValueError covers spec validation + resume refusals + bad JSON
        # (json.JSONDecodeError subclasses it); OSError covers missing
        # files; ImportError covers an unavailable optional backend
        print(f"error: {e}", file=sys.stderr)
        return 2
    best = res.best()
    if best is not None:
        say(f"best cell {best.cell_id}: reward={best.result.best_reward:.6g}"
            f" latency_ms={best.result.best_latency_ms:.1f}")
    persist = "" if res.spec.eval_store_path is None else \
        (f"store_preloaded={res.store_preloaded} "
         f"store_persisted={res.store_persisted} ")
    # the stable machine-readable trailer (CI greps cells_run on resume)
    print(f"campaign done: cells_run={res.cells_run} "
          f"cells_skipped={res.cells_skipped} store_hits={res.store_hits} "
          f"store_misses={res.store_misses} "
          f"store_hit_rate={res.store_hit_rate:.2f} {persist}"
          f"distinct_points={res.distinct_points} "
          f"wall_s={res.wall_s:.1f} results={res.out}")
    return 0


def _read_campaign(path: Path) -> tuple[dict, dict[str, dict]]:
    """(study header, cell_id -> cell record) from a results JSONL."""
    from repro.core.study import iter_jsonl_lenient

    header: dict = {}
    cells: dict[str, dict] = {}
    for rec in iter_jsonl_lenient(path):
        if rec.get("record") == "study" and not header:
            header = rec
        elif rec.get("record") == "cell" and "cell_id" in rec:
            cells[rec["cell_id"]] = rec
    if not cells:
        raise ValueError(f"{path} holds no cell records")
    return header, cells


def _cmd_compare(args: argparse.Namespace) -> int:
    path_a, path_b = Path(args.a), Path(args.b)
    try:
        (head_a, cells_a), (head_b, cells_b) = \
            _read_campaign(path_a), _read_campaign(path_b)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    hash_a, hash_b = head_a.get("spec_hash"), head_b.get("spec_hash")
    if hash_a != hash_b:
        print(f"warning: spec hashes differ ({hash_a} vs {hash_b}) — "
              f"the campaigns ran different studies; comparing by cell id "
              f"anyway", file=sys.stderr)

    def reward(rec: "dict | None") -> "float | None":
        if rec is None:
            return None
        return (rec.get("result") or {}).get("best_reward")

    ids = list(dict.fromkeys([*cells_a, *cells_b]))
    name_a, name_b = path_a.name, path_b.name
    w = max(len(i) for i in ids)
    print(f"{'cell':<{w}}  {'A: ' + name_a:>24}  {'B: ' + name_b:>24}  "
          f"delta")
    wins_a = wins_b = 0
    for cid in ids:
        ra, rb = reward(cells_a.get(cid)), reward(cells_b.get(cid))
        fa = "n/a" if ra is None else f"{ra:.6g}"
        fb = "n/a" if rb is None else f"{rb:.6g}"
        if ra is None or rb is None:
            delta = "n/a"
        elif rb == ra:
            delta = "tie"
        else:
            wins_b += rb > ra
            wins_a += ra > rb
            delta = "+inf% B" if ra == 0 else \
                f"{(rb - ra) / abs(ra) * 100:+.2f}% {'B' if rb > ra else 'A'}"
        print(f"{cid:<{w}}  {fa:>24}  {fb:>24}  {delta}")

    both = [cid for cid in ids if cid in cells_a and cid in cells_b]
    best_a = max((r for c in cells_a if (r := reward(cells_a[c])) is not None),
                 default=None)
    best_b = max((r for c in cells_b if (r := reward(cells_b[c])) is not None),
                 default=None)
    if wins_a == wins_b:
        verdict = "tie"
    else:
        win_name, wins = (name_a, wins_a) if wins_a > wins_b \
            else (name_b, wins_b)
        verdict = f"{win_name} — better in {wins}/{len(both)} shared cells"
    fmt = lambda r: "n/a" if r is None else f"{r:.6g}"  # noqa: E731
    print(f"winner: {verdict} "
          f"(best reward A={fmt(best_a)} B={fmt(best_b)})")
    return 0


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro.core.analysis import lint_study
    from repro.core.study import StudySpec

    try:
        spec = StudySpec.from_json(Path(args.spec))
        rep = lint_study(spec)
    except (ValueError, OSError, ImportError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    print(rep.format())
    if not rep.ok:
        print(f"lint: {len(rep.errors)} error(s) — this study would fail "
              f"or waste its campaign", file=sys.stderr)
        return 1
    return 0


def _cmd_analyze(args: argparse.Namespace) -> int:
    from repro.core.analysis import (PlanVerificationError, aggregate_summaries,
                                     analyze_job)
    from repro.core.study import StudySpec, _result_from_record

    try:
        header, cells = _read_campaign(Path(args.results))
        spec_d = header.get("spec")
        if not spec_d:
            raise ValueError(f"{args.results} has no study header record — "
                             f"cannot rebuild the evaluation environment")
        spec = StudySpec.from_dict(spec_d)
        from repro.core.backends import get_backend
        backend = args.backend or spec.backend
        get_backend(backend)
        env = spec.build_env()

        cols = ("cell", "reward", "makespan_ms", "cp%", "compute%", "coll%",
                "xfer%", "gate%", "bound")
        rows: list[tuple] = []
        for cid, rec in sorted(cells.items()):
            res = _result_from_record(rec)
            if res.best_config is None:
                rows.append((cid, "n/a") + ("-",) * (len(cols) - 2))
                continue
            job = env.scenario.sim_job(env.context(res.best_config))
            _, summaries = analyze_job(job, backend)
            agg = aggregate_summaries(summaries)
            if agg is None:    # best point gated invalid on re-evaluation
                rows.append((cid, f"{res.best_reward:.6g}")
                            + ("-",) * (len(cols) - 2))
                continue

            def _attr_row(label, reward, a):
                frac = a["breakdown_frac"]
                return (label, reward,
                        f"{a['makespan_us'] / 1e3:.1f}",
                        f"{a['cp_frac_of_makespan'] * 100:.1f}",
                        f"{frac['compute'] * 100:.1f}",
                        f"{frac['collective'] * 100:.1f}",
                        f"{frac['xfer'] * 100:.1f}",
                        f"{frac['gate'] * 100:.1f}",
                        a["bound"])

            rows.append(_attr_row(cid, f"{res.best_reward:.6g}", agg))
            if args.per_call and len(summaries) > 1:
                # per-call sub-rows: one per SimCall — for fleet jobs that
                # is one per replica, attributing bottlenecks replica by
                # replica
                for i, s in enumerate(summaries):
                    sub = aggregate_summaries([s])
                    if sub is not None:
                        rows.append(_attr_row(f"{cid}[{i}]", "-", sub))
    except PlanVerificationError as e:
        print(f"error: static verification failed\n{e.report.format()}",
              file=sys.stderr)
        return 2
    except (ValueError, OSError, ImportError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    widths = [max(len(str(r[i])) for r in [cols, *rows])
              for i in range(len(cols))]
    for r in [cols, *rows]:
        print("  ".join(f"{str(v):<{w}}" for v, w in zip(r, widths)).rstrip())
    return 0


def _cmd_store_stats(args: argparse.Namespace) -> int:
    """Per-signature inventory of a persistent eval store: how much corpus
    each ``eval_signature()`` has accumulated (the surrogate layer's
    warm-start budget) and its reward spread.  Tolerates a torn tail —
    the store is append-only and a killed campaign may leave one."""
    import statistics

    from repro.core.study import iter_jsonl_lenient

    path = Path(args.store)
    try:
        if not path.exists():
            raise OSError(f"eval store {path} does not exist")
        per: dict[str, dict] = {}
        for rec in iter_jsonl_lenient(path):
            sig = rec.get("sig")
            if not isinstance(rec.get("config"), dict) \
                    or "reward" not in rec or not isinstance(sig, str):
                continue
            d = per.setdefault(sig, {"n": 0, "valid": 0, "rewards": []})
            d["n"] += 1
            d["valid"] += bool(rec.get("valid"))
            d["rewards"].append(float(rec["reward"]))
        if not per:
            raise ValueError(f"{path} holds no eval records")
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    cols = ("signature", "records", "valid", "reward_min", "reward_median",
            "reward_max")
    rows = []
    for sig in sorted(per, key=lambda s: -per[s]["n"]):
        d = per[sig]
        rows.append((sig, str(d["n"]), str(d["valid"]),
                     f"{min(d['rewards']):.6g}",
                     f"{statistics.median(d['rewards']):.6g}",
                     f"{max(d['rewards']):.6g}"))
    widths = [max(len(str(r[i])) for r in [cols, *rows])
              for i in range(len(cols))]
    for r in [cols, *rows]:
        print("  ".join(f"{str(v):<{w}}" for v, w in zip(r, widths)).rstrip())
    print(f"total: {sum(d['n'] for d in per.values())} record(s) across "
          f"{len(per)} signature(s) in {path}")
    return 0


def _cmd_list_scenarios(args: argparse.Namespace) -> int:
    from repro.core.scenario import list_scenarios

    for kind, doc in sorted(list_scenarios().items()):
        print(f"{kind:16s} {doc}")
    return 0


def _cmd_list_systems(args: argparse.Namespace) -> int:
    from repro.core.systems import list_systems

    for name, p in sorted(list_systems().items()):
        print(f"{name:10s} n_npus={p.n_npus:<5d} device={p.device.name:18s} "
              f"{p.doc}")
    return 0


def _cmd_list_objectives(args: argparse.Namespace) -> int:
    from repro.core.rewards import list_objectives

    for name, obj in sorted(list_objectives().items()):
        kind = "stream" if obj.streaming else "scalar"
        print(f"{name:18s} [{kind}] {obj.doc}")
    return 0


def _cmd_list_backends(args: argparse.Namespace) -> int:
    from repro.core.backends import backend_available, list_backends

    for name, doc in sorted(list_backends().items()):
        avail = "" if backend_available(name) else " [unavailable]"
        print(f"{name:12s} {doc}{avail}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse",
        description="Run serialized DSE studies and inspect the registries "
                    "their names resolve through.")
    sub = ap.add_subparsers(dest="command", required=True)

    run_p = sub.add_parser("run", help="execute a StudySpec JSON file")
    run_p.add_argument("spec", help="path to the study .json")
    run_p.add_argument("--out", default=None,
                       help="results JSONL path (default: <spec>.results.jsonl)")
    run_p.add_argument("--resume", action="store_true",
                       help="skip cells already in the results file")
    run_p.add_argument("--steps", type=int, default=None,
                       help="override the spec's step budget")
    run_p.add_argument("--workers", type=int, default=None,
                       help="override the spec's process-pool size")
    run_p.add_argument("--backend", default=None,
                       help="override the spec's simulation backend "
                            "(see list-backends)")
    run_p.add_argument("--quiet", action="store_true",
                       help="only print the final campaign trailer")
    run_p.set_defaults(fn=_cmd_run)

    lint_p = sub.add_parser(
        "lint", help="statically check a StudySpec without running it")
    lint_p.add_argument("spec", help="path to the study .json")
    lint_p.set_defaults(fn=_cmd_lint)

    an_p = sub.add_parser(
        "analyze",
        help="critical-path bottleneck attribution for each recorded "
             "cell's best design point")
    an_p.add_argument("results", help="campaign results .jsonl")
    an_p.add_argument("--backend", default=None,
                      help="simulation backend for the re-evaluation "
                           "(default: the spec's)")
    an_p.add_argument("--per-call", action="store_true", dest="per_call",
                      help="also print one attribution row per SimCall "
                           "(per replica, for fleet scenarios)")
    an_p.set_defaults(fn=_cmd_analyze)

    cmp_p = sub.add_parser(
        "compare", help="per-cell best-reward table over two results files")
    cmp_p.add_argument("a", help="first results .jsonl")
    cmp_p.add_argument("b", help="second results .jsonl")
    cmp_p.set_defaults(fn=_cmd_compare)

    store_p = sub.add_parser(
        "store", help="inspect a persistent eval store (JSONL)")
    store_sub = store_p.add_subparsers(dest="action", required=True)
    stats_p = store_sub.add_parser(
        "stats", help="per-signature record counts and reward spread")
    stats_p.add_argument("store", help="eval store .jsonl "
                                       "(a StudySpec's eval_store_path)")
    stats_p.set_defaults(fn=_cmd_store_stats)

    sub.add_parser("list-scenarios",
                   help="registered scenario kinds").set_defaults(
        fn=_cmd_list_scenarios)
    sub.add_parser("list-systems",
                   help="registered system presets").set_defaults(
        fn=_cmd_list_systems)
    sub.add_parser("list-objectives",
                   help="registered objectives").set_defaults(
        fn=_cmd_list_objectives)
    sub.add_parser("list-backends",
                   help="registered simulation backends").set_defaults(
        fn=_cmd_list_backends)

    args = ap.parse_args(argv)
    return args.fn(args)
