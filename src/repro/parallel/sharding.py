"""Logical-axis sharding: the bridge between model code and mesh layout.

Model code annotates every parameter and key activation with *logical* axes
('embed', 'ff', 'vocab', 'batch', ...).  A ``ShardingPlan`` maps logical axes
to mesh axes through an ordered rule table with divisibility-aware fallbacks,
so the same model definition runs on 1 CPU device, a 16x16 pod, or a
2x16x16 multi-pod mesh without edits.

This realizes the paper's Workload knobs on real hardware: DP (batch over
('pod','data')), Weight-Sharded/ZeRO (embed-dim over 'data'), TP (ff/heads/
vocab/experts over 'model'), SP (residual-stream sequence dim over 'model'),
EP (experts over 'model').
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Order in which logical axes get first pick of mesh axes.  Earlier entries
# claim 'model' before later ones can.
_PRIORITY = (
    "expert", "ff", "vocab", "q_heads", "kv_heads", "d_inner", "ssm_heads",
    "batch", "kv_seq", "moe_groups", "seq", "embed", "ssm_head_dim", "head_dim",
)


def _default_rules(fsdp: bool, sp: bool) -> dict[str, list[tuple[str, ...]]]:
    """logical axis -> candidate mesh-axis tuples, best first."""
    rules: dict[str, list[tuple[str, ...]]] = {
        "expert": [("model",)],
        "ff": [("model",)],
        "vocab": [("model",)],
        "q_heads": [("model",)],
        "kv_heads": [("model",)],
        "d_inner": [("model",)],
        "ssm_heads": [("model",)],
        # chunk-major token groups: model (seq chunks) is the MAJOR axis
        "moe_groups": [("model", "pod", "data"), ("model", "data"),
                       ("model",), ("pod", "data"), ("data",)],
        "kv_seq": [("data", "model"), ("model",)],
        "batch": [("pod", "data"), ("data",)],
        "seq": [("model",)] if sp else [],
        "embed": [("data",)] if fsdp else [],
        "ssm_head_dim": [("model",)],
        "head_dim": [],
    }
    return rules


@dataclass(frozen=True)
class ShardingPlan:
    """Maps logical axes to a concrete mesh."""

    axis_sizes: dict[str, int] = field(default_factory=dict)  # mesh axis -> size
    fsdp: bool = True            # ZeRO-style weight sharding over 'data'
    sp: bool = True              # sequence parallelism on the residual stream
    rules: dict[str, list[tuple[str, ...]]] | None = None

    def _rules(self) -> dict[str, list[tuple[str, ...]]]:
        return self.rules if self.rules is not None else _default_rules(self.fsdp, self.sp)

    # ------------------------------------------------------------------
    def spec(self, axes: Sequence[str | None], shape: Sequence[int] | None = None) -> P:
        """Build a PartitionSpec for a tensor with the given logical axes.

        Mesh axes are assigned greedily in _PRIORITY order, subject to:
        (i) each mesh axis used at most once per tensor, and (ii) the dim
        size (when known) divisible by the mesh-axis product.
        """
        rules = self._rules()
        n = len(axes)
        assignment: list[tuple[str, ...] | None] = [None] * n
        used: set[str] = set()
        order = sorted(
            range(n),
            key=lambda i: _PRIORITY.index(axes[i]) if axes[i] in _PRIORITY else len(_PRIORITY),
        )
        for i in order:
            name = axes[i]
            if name is None or name not in rules:
                continue
            for option in rules[name]:
                opt = tuple(a for a in option if a in self.axis_sizes)
                if not opt or any(a in used for a in opt):
                    continue
                prod = 1
                for a in opt:
                    prod *= self.axis_sizes[a]
                if prod <= 1:
                    continue
                if shape is not None and shape[i] % prod != 0:
                    continue
                assignment[i] = opt
                used.update(opt)
                break
        parts = [
            (a if a is None or len(a) > 1 else a[0]) for a in assignment
        ]
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    # ------------------------------------------------------------------
    def constrain(self, x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
        """with_sharding_constraint against this plan (no-op on a null plan)."""
        if not self.axis_sizes:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(axes, x.shape))

    def can_shard(self, axis: str, size: int) -> bool:
        """Would `axis` of this size actually get sharded (ignoring siblings)?"""
        for option in self._rules().get(axis, []):
            opt = tuple(a for a in option if a in self.axis_sizes)
            if not opt:
                continue
            prod = 1
            for a in opt:
                prod *= self.axis_sizes[a]
            if prod > 1 and size % prod == 0:
                return True
        return False


NULL_PLAN = ShardingPlan(axis_sizes={}, fsdp=False, sp=False)


def plan_for_mesh(mesh: Mesh | None, *, fsdp: bool = True, sp: bool = True,
                  rules: dict[str, list[tuple[str, ...]]] | None = None) -> ShardingPlan:
    if mesh is None:
        return NULL_PLAN
    return ShardingPlan(
        axis_sizes=dict(zip(mesh.axis_names, mesh.devices.shape)),
        fsdp=fsdp, sp=sp, rules=rules,
    )


def tree_specs(plan: ShardingPlan, axes_tree, shape_tree):
    """Map a pytree of logical-axes tuples + shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda axes, sds: plan.spec(axes, sds.shape),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(mesh: Mesh, specs_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))
