"""Pipeline parallelism: GPipe-style microbatch schedule in shard_map.

The paper's PP knob, realized natively: stages live on a 'pipe' mesh axis,
activations hand off stage-to-stage with ``lax.ppermute``, and the classic
(n_micro + n_stages - 1) schedule — including the bubble — falls out of the
rotation loop.  Generic over the per-stage function, so any layer stack
(dense/MoE/SSM) can be cut into stages.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, mesh: Mesh, axis: str = "pipe"):
    """Build a pipelined forward over ``n_stages`` = mesh.shape[axis].

    stage_fn(stage_params, x) -> y : one stage's computation.
    Returns f(stage_params_stacked, microbatches) -> outputs where
      stage_params_stacked : pytree with leading dim n_stages,
      microbatches         : (n_micro, mb, ...) input microbatches,
      outputs              : (n_micro, mb, ...) final-stage outputs.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def pipelined(stage_params, microbatches):
        n_micro = microbatches.shape[0]
        my_stage = jax.lax.axis_index(axis)
        total = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        carry_in = jnp.zeros_like(microbatches[0])
        outputs = jnp.zeros((n_micro,) + microbatches.shape[1:],
                            microbatches.dtype)

        def tick(t, state):
            carry_in, outputs = state
            # stage 0 ingests microbatch t (when one remains); other stages
            # consume the activation handed off by the previous stage
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(my_stage == 0, microbatches[mb_idx], carry_in)
            y = stage_fn(stage_params, x_in)
            # the last stage emits a finished microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            emit = jnp.logical_and(my_stage == n_stages - 1, t >= n_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o,
                outputs)
            carry_in = jax.lax.ppermute(y, axis, perm)
            return (carry_in, outputs)

        carry_in, outputs = jax.lax.fori_loop(0, total, tick, (carry_in, outputs))
        return outputs

    # stage params are sharded along the pipe axis (one stage per rank);
    # microbatches are replicated in, outputs replicated out (last stage
    # broadcasts its result slice).
    in_specs = (P(axis), P())
    out_specs = P()

    def wrapper(stage_params_stacked, microbatches):
        f = shard_map(
            lambda sp, mb: _strip_leading(pipelined, sp, mb),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False)
        return f(stage_params_stacked, microbatches)

    def _strip_leading(fn, sp, mb):
        sp = jax.tree.map(lambda a: a[0], sp)  # (1, ...) local slice -> (...)
        out = fn(sp, mb)
        # every stage returns an `outputs` buffer but only the last stage
        # wrote real values (others hold zeros) — psum reconstitutes it
        # replicated, matching out_specs=P().
        return jax.lax.psum(out, axis)

    return wrapper


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    """The GPipe bubble: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
