"""Explicit data-parallel train step via shard_map — the path that can
intercept the gradient all-reduce (pjit's implicit DP reduction cannot be),
enabling int8 error-feedback gradient compression on the wire.

Layout: pure DP over one mesh axis; params/optimizer replicated, batch
sharded.  The compressed all-reduce cuts DP gradient wire bytes ~4x
(8-bit payload + fp32 scale) with the quantization residual carried across
steps (see parallel/compression.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.parallel.compression import compressed_psum, init_error_state
from repro.parallel.sharding import NULL_PLAN
from repro.train import optimizer as opt
from repro.train.train_step import RunConfig, make_loss_fn


def make_dp_train_step(spec: ArchSpec, mesh: Mesh, cfg: RunConfig,
                       *, axis: str = "data", compress_bits: int = 0):
    """Returns (train_step, init_extra) where train_step(state, batch) runs
    under shard_map over `axis`.  compress_bits=0 -> plain psum;
    8 -> int8 error-feedback compression (state carries the residual)."""
    loss_fn = make_loss_fn(spec, NULL_PLAN, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local_step(state, batch):
        (loss, _), grads = grad_fn(state["params"], batch)
        if compress_bits:
            grads, new_err = compressed_psum(grads, axis, state["grad_error"],
                                             bits=compress_bits)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_err = state.get("grad_error")
        loss = jax.lax.pmean(loss, axis)
        inner = {k: v for k, v in state.items() if k != "grad_error"}
        new_state, metrics = opt.apply_updates(inner, grads, cfg.opt)
        if new_err is not None:
            new_state["grad_error"] = new_err
        return new_state, {"loss": loss, **metrics}

    replicated = P()
    batch_spec = {"inputs": P(axis), "labels": P(axis)}

    def train_step(state, batch):
        state_specs = jax.tree.map(lambda _: replicated, state)
        f = shard_map(local_step, mesh=mesh,
                      in_specs=(state_specs, batch_spec),
                      out_specs=(state_specs, replicated),
                      check_rep=False)
        return f(state, batch)

    def init_extra(state: dict[str, Any]) -> dict[str, Any]:
        if compress_bits:
            state = dict(state)
            state["grad_error"] = init_error_state(state["params"])
        return state

    return train_step, init_extra
