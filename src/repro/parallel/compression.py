"""Gradient compression for data-parallel reductions (beyond-paper).

int8 quantization with error feedback around the DP all-reduce: ~4x less
wire traffic than fp32 (8-bit payload + one fp32 scale per tensor), with the
quantization residual carried into the next step so the compression bias
vanishes over time (Seide et al. / 1-bit Adam lineage).

Used by the explicit-DP (shard_map) train-step variant; under pjit the DP
reduction is implicit in XLA and can't be intercepted — that trade-off is
recorded in DESIGN.md.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_state(grads_like) -> Any:
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), grads_like)


def _quantize(g, bits: int):
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g)) / qmax
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    return q, scale


def compressed_psum(grads, axis_name: str, error_state, *, bits: int = 8):
    """Error-feedback compressed all-reduce (mean) over ``axis_name``.

    Returns (reduced grads, new error state).  Wire cost per tensor:
    n_elements * bits/8 + 4 bytes, vs n_elements * 4 uncompressed."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # globally shared scale so the integer payloads sum losslessly
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / (2.0 ** (bits - 1) - 1)
        scale = jnp.maximum(scale, 1e-30)
        q = jnp.clip(jnp.round(g / scale), -(2.0 ** (bits - 1) - 1), 2.0 ** (bits - 1) - 1)
        err = g - q * scale                      # residual -> next step
        q_sum = jax.lax.psum(q, axis_name)       # int payload on the wire
        return (q_sum * scale) / n, err

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in out])
    new_e = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_g, new_e


def wire_bytes(grads, *, bits: int = 8) -> tuple[int, int]:
    """(compressed, uncompressed fp32) bytes per all-reduce round."""
    n = sum(int(a.size) for a in jax.tree.leaves(grads))
    tensors = len(jax.tree.leaves(grads))
    return n * bits // 8 + 4 * tensors, n * 4
