"""Deterministic synthetic token pipeline with host-side sharding + prefetch.

Production shape: each host materializes only its shard of the global batch
(``host_slice``), the stream is reproducible from (seed, step) — so a
restarted/elastically-rescaled job resumes mid-epoch with zero drift — and a
background thread keeps a bounded prefetch queue ahead of the train loop.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Iterator

import numpy as np

from repro.configs.base import ArchSpec


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Markov-ish synthetic token stream: deterministic per (seed, step),
    non-trivial enough that loss decreases when the model learns it."""

    def __init__(self, spec: ArchSpec, cfg: DataConfig):
        self.spec = spec
        self.cfg = cfg
        # fixed random transition structure (shared across hosts)
        rng = np.random.default_rng(cfg.seed)
        self.vocab = min(spec.vocab_size, 32_768)
        self._succ = rng.integers(0, self.vocab, size=(self.vocab, 4), dtype=np.int32)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id))
        b, s = cfg.host_batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        branch = rng.integers(0, 4, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], branch[:, t]]
        out: dict[str, Any] = {"labels": toks[:, 1:].copy()}
        if self.spec.frontend == "tokens":
            out["inputs"] = toks[:, :-1].copy()
        else:
            emb_rng = np.random.default_rng((cfg.seed, step, cfg.host_id, 7))
            out["inputs"] = emb_rng.standard_normal(
                (b, s, self.spec.d_model), dtype=np.float32) * 0.02
        return out


class Prefetcher:
    """Bounded background prefetch: keeps `depth` batches ready."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
