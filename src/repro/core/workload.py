"""Workload Trace Generator (WTG).

The paper's WTG expands symbolic per-layer operator templates — shapes in
{B, S, D, H, FF, ...} and partitioning in {dp, sp, tp, pp} — into concrete
traces with collectives injected at tensor producer/consumer boundaries
(Section 4.4).  Ours consumes the SAME ``ArchSpec`` the real JAX models are
built from, so the symbolic trace and the executable model can never drift
apart: one source of truth for dense/GQA/MoE/SSM/hybrid templates.

A trace is the op list of ONE representative NPU (SPMD-symmetric), with
dependency edges; ``repro.core.simulator`` schedules it on a device+network.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Literal

from repro.configs.base import ArchSpec, LayerDef
from repro.core.cache import switchable_lru_cache


@dataclass
class Op:
    uid: int
    name: str
    kind: Literal["comp", "coll", "delay"]
    deps: list[int]
    # comp
    flops: float = 0.0
    bytes: float = 0.0
    # coll
    coll: str = ""        # all_reduce | all_gather | reduce_scatter | all_to_all | xfer
    size_bytes: float = 0.0
    group: str = ""       # tp | dp | ep | pp | xfer
    # which partition's resources this op occupies (multi-pool scenarios:
    # disaggregated prefill/decode pools get their own compute streams)
    pool: int = 0
    # back-to-back executions of this op (condensed decode-token chains:
    # k repeats occupy the resource for k x the single duration)
    repeat: int = 1
    # kind == "delay": a pure time offset on a private timer resource
    # (request-stream arrival releases); never serializes with real work
    delay_us: float = 0.0


# Scenario phases a trace can describe.  The legacy mode strings remain
# accepted spellings ("inference" == "prefill"); traces are generated per
# phase and scenarios compose phases into end-to-end evaluations.
PHASES = ("train", "prefill", "decode")
_PHASE_ALIASES = {"inference": "prefill"}


def resolve_phase(mode: str) -> str:
    phase = _PHASE_ALIASES.get(mode, mode)
    if phase not in PHASES:
        raise ValueError(f"unknown workload phase {mode!r}; "
                         f"known: {PHASES + tuple(_PHASE_ALIASES)}")
    return phase


@dataclass(frozen=True)
class Parallelism:
    """The paper's Workload knobs, resolved against a cluster size."""
    n_npus: int
    dp: int
    sp: int
    pp: int
    weight_sharded: bool = False

    @property
    def tp(self) -> int:
        tp = self.n_npus // (self.dp * self.sp * self.pp)
        return max(tp, 1)

    def valid(self) -> bool:
        return self.dp * self.sp * self.pp <= self.n_npus and \
            self.n_npus % (self.dp * self.sp * self.pp) == 0


@dataclass
class Trace:
    """Op list with dense uids (ops[i].uid == i, as TraceBuilder assigns) —
    the simulator's flat-array scheduling plan relies on it and validates."""
    ops: list[Op]
    meta: dict[str, Any] = field(default_factory=dict)

    def total_flops(self) -> float:
        return sum(o.flops for o in self.ops)

    def total_coll_bytes(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for o in self.ops:
            if o.kind == "coll":
                out[o.group] = out.get(o.group, 0.0) + o.size_bytes
        return out


BYTES_ACT = 2  # bf16 activations
BYTES_GRAD = 2


class TraceBuilder:
    def __init__(self):
        self.ops: list[Op] = []

    def comp(self, name, flops, bytes_, deps):
        op = Op(len(self.ops), name, "comp", list(deps), flops=flops, bytes=bytes_)
        self.ops.append(op)
        return op.uid

    def coll(self, name, coll, size, group, deps):
        op = Op(len(self.ops), name, "coll", list(deps), coll=coll,
                size_bytes=size, group=group)
        self.ops.append(op)
        return op.uid


def _layer_flops_fwd(spec: ArchSpec, ld: LayerDef, b: float, s: float,
                     seq_total: float) -> tuple[float, float]:
    """(mixer, ffn) forward FLOPs for b*s tokens on one NPU shard
    (`seq_total` = full sequence length for attention's S^2 term)."""
    d, hd = spec.d_model, spec.resolved_head_dim
    tok = b * s
    if ld.mixer == "mamba":
        din, ds, nh = spec.d_inner, spec.ssm_state, spec.ssm_heads
        proj = 2 * tok * d * (2 * din + 2 * spec.ssm_groups * ds + nh) + 2 * tok * din * d
        ssd = 2 * tok * nh * spec.ssm_head_dim * ds * 2  # state update + output
        mixer = proj + ssd
    else:
        qkvo = 2 * tok * d * (2 * spec.n_heads * hd + 2 * spec.n_kv_heads * hd)
        ctx = seq_total if ld.mixer != "attn_local" or not spec.sliding_window \
            else min(seq_total, spec.sliding_window)
        attn = 2 * tok * ctx * spec.n_heads * hd * 2  # QK^T + PV (causal ~ /2 folded into ctx avg)
        mixer = qkvo + attn * 0.5
    if ld.ffn == "mlp":
        mults = 3 if spec.act == "silu" else 2
        ffn = 2.0 * tok * d * spec.d_ff * mults
    elif ld.ffn == "moe":
        ffn = 2.0 * tok * d * spec.d_ff * 3 * spec.top_k + 2 * tok * d * spec.n_experts
    else:
        ffn = 0.0
    return mixer, ffn


def _layer_param_bytes(spec: ArchSpec, ld: LayerDef, tp: int, bytes_per: float) -> float:
    d, hd = spec.d_model, spec.resolved_head_dim
    if ld.mixer == "mamba":
        din, ds, nh = spec.d_inner, spec.ssm_state, spec.ssm_heads
        mixer = d * (2 * din + 2 * spec.ssm_groups * ds + nh) + din * d
    else:
        mixer = d * (spec.n_heads + 2 * spec.n_kv_heads) * hd + spec.n_heads * hd * d
    if ld.ffn == "mlp":
        ffn = (3 if spec.act == "silu" else 2) * d * spec.d_ff
    elif ld.ffn == "moe":
        ffn = spec.n_experts * 3 * d * spec.d_ff + d * spec.n_experts
    else:
        ffn = 0.0
    return (mixer + ffn) / tp * bytes_per


def generate_trace(spec: ArchSpec, par: Parallelism, *, batch: int, seq: int,
                   mode: str = "train", microbatches: int | None = None) -> Trace:
    """Expand the symbolic template into one NPU's op trace.

    Memoized on ``(spec, par, batch, seq, mode, microbatches)`` — every
    argument is a hashable value object, so a cache hit returns the SAME
    ``Trace`` built by the uncached expansion.  Callers must treat the
    returned trace as immutable (the simulator only reads it).

    train:   fwd + bwd per layer, TP collectives on activation boundaries,
             per-layer DP gradient reduction overlapping the backward pass,
             PP pipeline-bubble factor on compute.
    prefill: fwd only ("inference" accepted as a legacy spelling).
    decode:  one-token steps against a KV cache (per-token message sizes).
    """
    return _generate_trace_cached(spec, par, batch, seq, resolve_phase(mode),
                                  microbatches)


def _generate_trace_impl(spec: ArchSpec, par: Parallelism, batch: int,
                         seq: int, mode: str,
                         microbatches: int | None) -> Trace:
    mode = resolve_phase(mode)
    tb = TraceBuilder()
    b = batch / par.dp
    s = seq / par.sp
    tp = par.tp

    # most specs repeat one or two LayerDefs; compute per-layer costs once
    _flops_memo: dict[LayerDef, tuple[float, float]] = {}
    _pbytes_memo: dict[tuple[LayerDef, float], float] = {}

    def layer_flops(ld: LayerDef) -> tuple[float, float]:
        v = _flops_memo.get(ld)
        if v is None:
            v = _layer_flops_fwd(spec, ld, b, s, seq)
            _flops_memo[ld] = v
        return v

    def layer_pbytes(ld: LayerDef, bytes_per: float) -> float:
        v = _pbytes_memo.get((ld, bytes_per))
        if v is None:
            v = _layer_param_bytes(spec, ld, tp, bytes_per)
            _pbytes_memo[(ld, bytes_per)] = v
        return v

    if mode == "decode":
        # one token with a KV cache of `seq`: per layer a GEMV over the
        # layer's weights + attention over the cache + a SMALL (b x d)
        # TP all-reduce — the latency-dominated regime where the paper's
        # Expr-2 finds Direct/RHD/DBT beat Ring.  Unlike prefill/train,
        # PP does NOT divide per-token latency: the token traverses every
        # stage sequentially, paying a cross-stage hop at each boundary.
        layers_d = spec.layer_defs()
        n_l = len(layers_d)
        prev = []
        for i, ld in enumerate(layers_d):
            w_bytes = layer_pbytes(ld, BYTES_ACT)
            flops = w_bytes * b  # 2 flops per bf16 weight x b tokens
            kv_read = b * seq * spec.n_kv_heads * spec.resolved_head_dim * 2 * BYTES_ACT / tp                 if ld.mixer.startswith("attn") else 0.0
            u = tb.comp(f"L{i}.decode", flops, w_bytes + kv_read, prev)
            if tp > 1:
                u = tb.coll(f"L{i}.decode.ar", "all_reduce",
                            b * spec.d_model * BYTES_ACT, "tp", [u])
            # exactly pp-1 stage-boundary hops under a balanced partition
            if par.pp > 1 and i + 1 < n_l and \
                    (i + 1) * par.pp // n_l != i * par.pp // n_l:
                u = tb.coll(f"L{i}.decode.pp", "all_gather",
                            b * spec.d_model * BYTES_ACT, "pp", [u])
            prev = [u]
        head_b = spec.d_model * spec.vocab_size / tp * BYTES_ACT
        tb.comp("head.decode", head_b * b, head_b, prev)
        return Trace(tb.ops, meta=dict(arch=spec.name, mode=mode, batch=batch,
                                       seq=seq, dp=par.dp, sp=par.sp, pp=par.pp,
                                       tp=tp, microbatches=1, bubble=1.0,
                                       weight_sharded=par.weight_sharded))

    # MXU-granularity efficiency: a matmul sharded to fewer than ~256 lanes
    # per NPU underutilizes the systolic array; pathological TP degrees
    # inflate compute time (the physics behind the paper's 64.5x Fig-4
    # spread).  eff in (0.02, 1].
    def _eff(width: float) -> float:
        return max(0.02, min(1.0, width / tp / 256.0))

    hd = spec.resolved_head_dim
    mixer_width = max(spec.n_heads * hd, spec.d_inner or 1)
    ffn_width = max(spec.d_ff, 1) if spec.d_ff else mixer_width
    eff_mixer = _eff(mixer_width)
    eff_ffn = _eff(ffn_width)
    layers = spec.layer_defs()
    # one PP stage's layer slice: ceil division models the LARGEST stage, so
    # a non-divisible layers % pp never silently drops remainder layers from
    # the modeled compute (e.g. 34 layers @ pp=4 is a 9-layer stage, not 8)
    stage_layers = layers[: max(1, math.ceil(len(layers) / par.pp))]
    mb = microbatches or (2 * par.pp if par.pp > 1 else 1)
    bubble = 1.0 + (par.pp - 1) / mb if par.pp > 1 else 1.0

    act_bytes = b * s * spec.d_model * BYTES_ACT      # residual activation/NPU
    prev = []
    train = mode == "train"

    # embedding
    emb_flops = 2 * b * s * spec.d_model
    prev = [tb.comp("embed", emb_flops, act_bytes, [])]

    fwd_tail: dict[int, int] = {}
    for i, ld in enumerate(stage_layers):
        mixer_f, ffn_f = layer_flops(ld)
        u = tb.comp(f"L{i}.mixer.fwd", bubble * mixer_f / tp / eff_mixer,
                    3 * act_bytes / max(tp, 1), prev)
        if tp > 1:
            u = tb.coll(f"L{i}.mixer.ar", "all_reduce", act_bytes, "tp", [u])
        if ld.ffn != "none":
            u2 = tb.comp(f"L{i}.ffn.fwd", bubble * ffn_f / tp / eff_ffn,
                         3 * act_bytes / max(tp, 1), [u])
            if ld.ffn == "moe" and tp > 1:
                u2 = tb.coll(f"L{i}.moe.a2a.fwd", "all_to_all",
                             act_bytes * spec.top_k, "ep", [u2])
            elif tp > 1:
                u2 = tb.coll(f"L{i}.ffn.ar", "all_reduce", act_bytes, "tp", [u2])
            u = u2
        prev = [u]
        fwd_tail[i] = u

    # head + loss
    head_f = 2 * b * s * spec.d_model * spec.vocab_size / tp
    u = tb.comp("head", head_f, act_bytes, prev)
    if tp > 1:
        u = tb.coll("head.ar", "all_reduce", b * s * 4, "tp", [u])
    prev = [u]

    if train:
        grad_bytes_per = BYTES_GRAD
        dp_group_sz = par.dp
        for i in reversed(range(len(stage_layers))):
            ld = stage_layers[i]
            mixer_f, ffn_f = layer_flops(ld)
            u = tb.comp(f"L{i}.bwd",
                        bubble * 2.0 * (mixer_f / eff_mixer + ffn_f / eff_ffn) / tp,
                        6 * act_bytes / max(tp, 1), prev)
            if tp > 1:  # Megatron backward re-runs the activation collectives
                u = tb.coll(f"L{i}.bwd.ar", "all_reduce", 2 * act_bytes, "tp", [u])
            prev = [u]
            if dp_group_sz > 1:
                pb = layer_pbytes(ld, grad_bytes_per)
                kind = "reduce_scatter" if par.weight_sharded else "all_reduce"
                tb.coll(f"L{i}.grad.{kind}", kind, pb, "dp", [u])
        # embedding/head grads
        if dp_group_sz > 1:
            emb_b = spec.vocab_size * spec.d_model / tp * grad_bytes_per
            tb.coll("embed.grad", "reduce_scatter" if par.weight_sharded else "all_reduce",
                    emb_b, "dp", prev)
        if par.weight_sharded and dp_group_sz > 1:
            # optimizer re-gathers sharded params for the next step
            tot = sum(layer_pbytes(ld, BYTES_ACT) for ld in stage_layers)
            tb.coll("params.allgather", "all_gather", tot, "dp", prev)

    if par.pp > 1:
        p2p = act_bytes * mb
        tb.coll("pp.sendrecv", "all_gather", p2p, "pp", prev)  # stage handoff

    tr = Trace(tb.ops, meta=dict(arch=spec.name, mode=mode, batch=batch, seq=seq,
                                 dp=par.dp, sp=par.sp, pp=par.pp, tp=tp,
                                 weight_sharded=par.weight_sharded, bubble=bubble,
                                 microbatches=mb))
    return tr


_generate_trace_cached = switchable_lru_cache(maxsize=4096)(_generate_trace_impl)


@dataclass(frozen=True)
class WaveSegment:
    """One phase of one wave: a (cached, immutable) phase trace placed on a
    pool.  ``repeat`` multiplies every op's back-to-back execution count —
    how a ``decode_tokens``-long token chain is condensed into the one-token
    decode trace without op blow-up.  ``transfer_bytes`` inserts a
    cross-partition ``xfer`` collective between this segment and the next
    (the KV-cache handoff from a prefill pool to a decode pool).
    ``transfer_chunks > 1`` models chunked prefill: earlier KV chunks
    stream while the prompt is still computing, so only the LAST chunk
    (``bytes / chunks``) sits on the next segment's critical path; the
    remaining volume still occupies the transfer fabric as a trailing op."""
    trace: Trace
    pool: int
    repeat: int = 1
    transfer_bytes: float = 0.0
    transfer_chunks: int = 1


@dataclass(frozen=True)
class Wave:
    """One admitted request batch moving through its phase segments.

    ``release_ms`` gates the wave's first segment behind a delay op (the
    arrival-process admission time).  ``gates`` adds cross-wave dependency
    edges ``(seg_idx, earlier_wave_idx, earlier_seg_idx)`` — e.g. decode
    continuous-batching capacity (wave w's decode waits for wave w-1's) or
    a max-in-flight admission window (wave w's prefill waits for wave
    w-k's completion)."""
    segments: tuple[WaveSegment, ...]
    release_ms: float = 0.0
    gates: tuple[tuple[int, int, int], ...] = ()


def compose_request_waves(waves: list[Wave],
                          meta: dict[str, Any] | None = None) -> Trace:
    """Stitch K overlapping waves into one pipelined multi-pool trace.

    Within a wave, segment i+1's roots depend on segment i's tails (with an
    optional ``xfer`` collective on the boundary).  Across waves there are
    no implicit dependencies — same-pool phases of different waves contend
    for that pool's resources in the event loop (wave k+1's prefill overlaps
    wave k's decode), which is exactly the pipelining the analytic
    composition can't see.  Release times and explicit ``gates`` add the
    arrival-process and capacity edges.

    ``meta["wave_marks"]`` maps each wave to its op uids: ``release_uid``,
    per-segment ``seg_tails`` lists, and ``xfer_uids`` — scenarios read
    per-wave TTFT/TPOT off ``SimResult.op_finish_us`` through these.
    Input traces are not mutated (they may be cache-interned)."""
    ops: list[Op] = []
    marks: list[dict[str, Any]] = []
    multi = len(waves) > 1
    for wi, wave in enumerate(waves):
        prefix = f"w{wi}." if multi else ""
        gate_tails: dict[int, list[int]] = {}
        for seg_idx, gw, gs in wave.gates:
            gate_tails.setdefault(seg_idx, []).extend(
                marks[gw]["seg_tails"][gs])
        release_uid = None
        prev_tails: list[int] = []
        if wave.release_ms > 0:
            uid = len(ops)
            ops.append(Op(uid, f"{prefix}release", "delay", [],
                          pool=wave.segments[0].pool,
                          delay_us=wave.release_ms * 1e3))
            release_uid = uid
            prev_tails = [uid]
        seg_tails: list[list[int]] = []
        xfer_uids: list[int | None] = []
        for si, seg in enumerate(wave.segments):
            root_deps = prev_tails + gate_tails.get(si, [])
            off = len(ops)
            tr = seg.trace
            has_children = {d for op in tr.ops for d in op.deps}
            for op in tr.ops:
                deps = [d + off for d in op.deps] if op.deps else list(root_deps)
                ops.append(Op(op.uid + off, f"{prefix}s{si}.{op.name}",
                              op.kind, deps, flops=op.flops, bytes=op.bytes,
                              coll=op.coll, size_bytes=op.size_bytes,
                              group=op.group, pool=seg.pool,
                              repeat=op.repeat * seg.repeat,
                              delay_us=op.delay_us))
            tails = [op.uid + off for op in tr.ops
                     if op.uid not in has_children]
            seg_tails.append(tails)
            if seg.transfer_bytes > 0 and si < len(wave.segments) - 1:
                chunks = max(1, int(seg.transfer_chunks))
                uid = len(ops)
                ops.append(Op(uid, f"{prefix}s{si}.xfer", "coll", list(tails),
                              coll="xfer",
                              size_bytes=seg.transfer_bytes / chunks,
                              group="xfer", pool=seg.pool))
                xfer_uids.append(uid)
                prev_tails = [uid]
                if chunks > 1:
                    # chunked prefill: only the final chunk gates the next
                    # segment; the earlier chunks' volume trails behind it
                    # on the same transfer fabric (a sink op — it delays
                    # later waves' transfers, not this wave's first token)
                    bg = len(ops)
                    ops.append(Op(bg, f"{prefix}s{si}.xfer_bg", "coll",
                                  [uid], coll="xfer",
                                  size_bytes=seg.transfer_bytes
                                  * (chunks - 1) / chunks,
                                  group="xfer", pool=seg.pool))
            else:
                xfer_uids.append(None)
                prev_tails = tails
        marks.append({"release_uid": release_uid, "seg_tails": seg_tails,
                      "xfer_uids": xfer_uids})
    pools = sorted({seg.pool for w in waves for seg in w.segments})
    return Trace(ops, meta=dict(meta or {}, pools=pools, wave_marks=marks))


def compose_phases(segments: list[tuple[Trace, int]],
                   transfers: list[float] | tuple[float, ...] = (),
                   meta: dict[str, Any] | None = None) -> Trace:
    """Stitch per-pool phase traces into one multi-pool trace.

    ``segments[i]`` is ``(trace, pool)``; phase i+1's roots depend on phase
    i's tails.  ``transfers[i]`` (bytes) inserts a cross-partition transfer
    collective (group ``"xfer"``, e.g. the KV-cache handoff between a
    prefill and a decode pool) on that boundary; 0 means a bare dependency
    edge.  The single-wave special case of ``compose_request_waves``."""
    segs = tuple(
        WaveSegment(tr, pool,
                    transfer_bytes=(transfers[si] if si < len(transfers)
                                    else 0.0))
        for si, (tr, pool) in enumerate(segments))
    return compose_request_waves([Wave(segs)], meta=meta)
