"""Loop-aware cost analysis of post-SPMD optimized HLO.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, but a
scan-over-layers program keeps ~all of its FLOPs and every per-layer
collective inside while loops — so the stock numbers under-count a 95-layer
model by ~95x.  This module re-derives execution-weighted totals from
``compiled.as_text()``:

  * parses every computation + instruction (shapes, operands, attributes),
  * recovers trip counts of ``while`` loops from their condition
    computations (constant-bound counter compares, which is exactly what
    ``lax.scan`` lowers to),
  * walks the call graph multiplying per-computation costs by trip counts,
  * attributes FLOPs (dot contraction math from dimension_numbers),
    elementwise/transcendental op counts, bytes at fusion boundaries, and
    per-kind collective bytes with replica-group sizes.

It is the shared backbone of (a) the §Roofline analysis and (b) COSMIC's
simulator calibration (ASTRA-sim was validated against real systems; we
validate the analytical model against the XLA compiler's schedule).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# ops that are pure data movement / bookkeeping: no flops
_ZERO_FLOP = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "transpose", "copy", "broadcast", "iota", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "reverse", "gather", "scatter", "convert", "after-all", "custom-call",
    "partition-id", "replica-id", "optimization-barrier", "copy-start",
    "copy-done", "send", "recv", "send-done", "recv-done", "domain",
    "reduce-precision", "rng-bit-generator", "infeed", "outfeed",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    by_name: dict[str, Instruction]


@dataclass
class CostTotals:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    # fusion-optimistic HBM traffic: only ops that MUST touch HBM-resident
    # operands on TPU (dot/conv/gather/scatter/reduce/collectives); assumes
    # every elementwise chain fuses into its producer — the lower bound a
    # perfect TPU fusion pass would achieve.
    bytes_fused: float = 0.0
    collective_bytes: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    # (kind, group_size) -> bytes, for link-level modeling
    collective_by_group: dict[tuple[str, int], float] = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.bytes_fused += other.bytes_fused * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult
        for k, v in other.collective_by_group.items():
            self.collective_by_group[k] += v * mult

    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}\d]+?))\s+([\w\-]+)\((.*)$"
)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        if stripped.startswith("HloModule"):
            continue
        if cur is None:
            m = _COMP_HEADER.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operand names: %name tokens inside the first (...) group
        depth, i, args = 1, 0, ""
        while i < len(rest) and depth > 0:
            ch = rest[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
            i += 1
        attrs = rest[i + 1:]
        operands = re.findall(r"%([\w\.\-]+)", args)
        instr = Instruction(name, type_str, opcode, operands, attrs)
        cur.instructions.append(instr)
        cur.by_name[name] = instr
    return comps


def _called_comps(instr: Instruction) -> list[str]:
    """computation names referenced in attributes (calls/fusion/while)."""
    out = []
    for key in ("to_apply", "body", "condition", "calls", "branch_computations"):
        for m in re.finditer(key + r"=\{?%?([\w\.\-]+)", instr.attrs):
            out.append(m.group(1))
        m = re.search(key + r"=\{([^}]*)\}", instr.attrs)
        if m:
            out = out[:-1] if out else out
            for nm in re.findall(r"%?([\w\.\-]+)", m.group(1)):
                out.append(nm)
    return out


def _attr_comp(instr: Instruction, key: str) -> str | None:
    m = re.search(key + r"=%?([\w\.\-]+)", instr.attrs)
    return m.group(1) if m else None


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    """2 * prod(lhs dims) * prod(rhs non-contracting, non-batch dims)."""
    lhs = comp.by_name.get(instr.operands[0]) if instr.operands else None
    rhs = comp.by_name.get(instr.operands[1]) if len(instr.operands) > 1 else None
    if lhs is None or rhs is None:
        return 2.0 * _shape_elems(instr.type_str)
    lhs_dims = _dims_of(lhs.type_str)
    rhs_dims = _dims_of(rhs.type_str)
    rc = _parse_dim_list(instr.attrs, "rhs_contracting_dims")
    rb = _parse_dim_list(instr.attrs, "rhs_batch_dims")
    lhs_prod = math.prod(lhs_dims) if lhs_dims else 1
    rhs_free = math.prod(
        [d for i, d in enumerate(rhs_dims) if i not in rc and i not in rb]) if rhs_dims else 1
    return 2.0 * lhs_prod * rhs_free


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _parse_dim_list(attrs: str, key: str) -> set[int]:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    if not m or not m.group(1):
        return set()
    return {int(d) for d in m.group(1).split(",")}


_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one", "log-plus-one",
                   "erf", "cbrt", "atan2"}


class HloCostModel:
    """Execution-weighted cost walker over a parsed HLO module."""

    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.text = text
        self._memo: dict[str, CostTotals] = {}
        self.entry = self._find_entry(text)
        self.unknown_trip_loops = 0

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE)
        if m:
            return m.group(1)
        m = re.search(r"entry_computation_name\s*=\s*\"?([\w\.\-]+)", text)
        return m.group(1) if m else next(iter(self.comps))

    # -- trip counts ------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        best = None
        for ins in cond.instructions:
            if ins.opcode != "compare":
                continue
            direction = "LT"
            m = re.search(r"direction=(\w+)", ins.attrs)
            if m:
                direction = m.group(1)
            for opn in ins.operands:
                dep = cond.by_name.get(opn)
                if dep is None or dep.opcode != "constant":
                    continue
                lit = self._const_literal(cond_name, dep)
                if lit is None:
                    continue
                if direction == "LT":
                    best = lit
                elif direction == "GT":
                    best = lit
                elif direction in ("LE", "GE"):
                    best = lit + 1
        if best is None or best < 1:
            self.unknown_trip_loops += 1
            return 1
        return int(best)

    def _const_literal(self, comp_name: str, ins: Instruction) -> int | None:
        # the literal is inside the original text line: "constant(95)"
        pat = re.compile(r"%?" + re.escape(ins.name) + r"\s*=\s*\S+\s+constant\((-?\d+)\)")
        m = pat.search(self.text)
        return int(m.group(1)) if m else None

    # -- cost walk ---------------------------------------------------------
    def analyze(self, comp_name: str | None = None) -> CostTotals:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = CostTotals()
        if comp is None:
            return total
        self._memo[comp_name] = total  # pre-insert to break cycles
        for ins in comp.instructions:
            op = ins.opcode
            if op == "while":
                body = _attr_comp(ins, "body")
                cond = _attr_comp(ins, "condition")
                # XLA annotates counted loops: backend_config={"known_trip_count":{"n":"8"},...}
                m = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"', ins.attrs)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = self.trip_count(cond) if cond else 1
                if body:
                    total.add(self.analyze(body), trips)
                if cond:
                    total.add(self.analyze(cond), trips)
            elif op == "conditional":
                for sub in re.findall(r"%?([\w\.\-]+)", ins.attrs.split("branch_computations=")[-1].split("}")[0]) \
                        if "branch_computations" in ins.attrs else []:
                    if sub in self.comps:
                        total.add(self.analyze(sub), 1.0)
                        break  # cost one branch
                total.bytes_accessed += _shape_bytes(ins.type_str)
            elif op in ("call", "fusion", "async-start"):
                sub = _attr_comp(ins, "to_apply") or _attr_comp(ins, "calls")
                if sub:
                    inner = self.analyze(sub)
                    t = CostTotals()
                    t.add(inner)
                    # bytes at the fusion boundary: operands + output
                    t.bytes_accessed = self._call_site_bytes(comp, ins)
                    total.add(t)
            elif op in ("reduce", "reduce-window", "sort", "map", "select-and-scatter"):
                sub = _attr_comp(ins, "to_apply")
                elems = sum(_shape_elems(comp.by_name[o].type_str)
                            for o in ins.operands if o in comp.by_name) or _shape_elems(ins.type_str)
                inner_flops = self.analyze(sub).flops if sub else 1.0
                total.flops += max(inner_flops, 1.0) * elems
                total.bytes_accessed += self._call_site_bytes(comp, ins)
                total.bytes_fused += self._call_site_bytes(comp, ins)
            elif op.startswith("all-") or op in ("reduce-scatter", "collective-permute", "collective-broadcast"):
                kind = op.replace("-start", "")
                if kind.endswith("-done"):
                    continue
                b = _shape_bytes(ins.type_str)
                gsz = self._group_size(ins)
                total.collective_bytes[kind] += b
                total.collective_counts[kind] += 1
                total.collective_by_group[(kind, gsz)] += b
                total.bytes_accessed += b
                total.bytes_fused += b
            elif op == "dot":
                total.flops += _dot_flops(ins, comp)
                total.bytes_accessed += self._call_site_bytes(comp, ins)
                total.bytes_fused += self._call_site_bytes(comp, ins)
            elif op == "convolution":
                # rough: 2 * output elems * (kernel elems)
                total.flops += 2.0 * _shape_elems(ins.type_str) * 8
                total.bytes_accessed += self._call_site_bytes(comp, ins)
                total.bytes_fused += self._call_site_bytes(comp, ins)
            elif op in ("gather", "scatter", "dynamic-update-slice", "dynamic-slice"):
                # slice-accurate accounting: a DUS/DS/gather/scatter touches
                # only the moved slice, not its whole operand buffer
                b = self._slice_bytes(comp, ins)
                total.bytes_accessed += b
                total.bytes_fused += b
            elif op in _ZERO_FLOP:
                if op in ("custom-call",):
                    b = self._call_site_bytes(comp, ins)
                    total.bytes_accessed += b
                    total.bytes_fused += b
            else:
                elems = _shape_elems(ins.type_str)
                if op in _TRANSCENDENTAL:
                    total.transcendentals += elems
                    total.flops += 4.0 * elems  # transcendental ~ a few flops
                else:
                    total.flops += elems
        return total

    def _slice_bytes(self, comp: Computation, ins: Instruction) -> float:
        if ins.opcode == "dynamic-update-slice" and len(ins.operands) >= 2:
            upd = comp.by_name.get(ins.operands[1])
            if upd is not None:
                return 2.0 * _shape_bytes(upd.type_str)  # read update, write region
        if ins.opcode == "scatter" and len(ins.operands) >= 3:
            upd = comp.by_name.get(ins.operands[2])
            if upd is not None:
                return 2.0 * _shape_bytes(upd.type_str)
        # dynamic-slice / gather: read + write ~ the extracted slice
        return 2.0 * _shape_bytes(ins.type_str)

    def _call_site_bytes(self, comp: Computation, ins: Instruction) -> float:
        b = _shape_bytes(ins.type_str)
        for o in ins.operands:
            dep = comp.by_name.get(o)
            if dep is not None:
                b += _shape_bytes(dep.type_str)
        return float(b)

    def _group_size(self, ins: Instruction) -> int:
        # replica_groups=[8,64]<=[...]  -> 64 per group ; or explicit {{0,1},{2,3}}
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", ins.attrs)
        if m:
            return len(m.group(1).split(","))
        return 1


def analyze_compiled_text(text: str) -> CostTotals:
    return HloCostModel(text).analyze()
