"""Bridge: COSMIC design points <-> executable JAX mesh plans, and
XLA-compiled-artifact calibration of the analytical simulator.

This closes the loop the paper leaves open: a discovered (DP, SP, PP, TP,
weight-sharded) workload point becomes a concrete ``jax.Mesh`` +
``ShardingPlan`` the real train/serve step runs under, and the simulator's
compute/collective terms can be cross-checked against loop-aware HLO totals
from the dry-run (``repro.core.hlo_analysis``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

from repro.core.hlo_analysis import CostTotals
from repro.core.workload import Parallelism, Trace


@dataclass(frozen=True)
class MeshPlan:
    """A realizable mesh layout for a discovered design point."""
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    fsdp: bool
    sp: bool

    def make_mesh(self):
        from repro.launch.mesh import make_mesh
        return make_mesh(self.shape, self.axis_names)


def plan_from_design(par: Parallelism) -> MeshPlan:
    """Map COSMIC workload knobs onto mesh axes.

    dp*sp -> 'data'-like axes (sp realized as sequence sharding over
    'model' in-layer, so the mesh folds sp into data), tp -> 'model',
    pp -> 'pipe' (outermost).
    """
    axes: list[tuple[str, int]] = []
    if par.pp > 1:
        axes.append(("pipe", par.pp))
    axes.append(("data", par.dp * par.sp))
    axes.append(("model", par.tp))
    shape = tuple(n for _, n in axes if n > 1) or (1,)
    names = tuple(a for a, n in axes if n > 1) or ("data",)
    return MeshPlan(shape, names, fsdp=par.weight_sharded, sp=par.sp > 1)


def design_from_mesh(axis_sizes: dict[str, int], *, weight_sharded: bool = True,
                     sp: bool = True) -> Parallelism:
    """Inverse: what design point does a production mesh realize?"""
    n = 1
    for v in axis_sizes.values():
        n *= v
    dp = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
    pp = axis_sizes.get("pipe", 1)
    tp_sp = axis_sizes.get("model", 1)
    # sequence parallelism rides the model axis in our runtime
    return Parallelism(n_npus=n, dp=dp, sp=1, pp=pp, weight_sharded=weight_sharded)


# ---------------------------------------------------------------------------
# calibration: analytical trace vs. compiled HLO
# ---------------------------------------------------------------------------

@dataclass
class Calibration:
    """Per-term ratios (simulated / compiled).  A ratio near 1.0 means the
    analytical model tracks the compiler's schedule; large deviations flag
    modeling gaps (or compiler waste, e.g. remat recompute)."""
    flops_ratio: float
    coll_bytes_ratio: float
    detail: dict[str, Any]


def calibrate(trace: Trace, hlo: CostTotals, n_chips: int) -> Calibration:
    sim_flops = trace.total_flops()
    hlo_flops = hlo.flops
    sim_coll = sum(trace.total_coll_bytes().values())
    hlo_coll = hlo.total_collective_bytes()
    return Calibration(
        flops_ratio=sim_flops / hlo_flops if hlo_flops else float("nan"),
        coll_bytes_ratio=sim_coll / hlo_coll if hlo_coll else float("nan"),
        detail={
            "sim_flops": sim_flops, "hlo_flops_per_device": hlo_flops,
            "sim_coll_bytes": sim_coll, "hlo_coll_bytes_per_device": hlo_coll,
            "sim_coll_by_group": trace.total_coll_bytes(),
            "hlo_coll_by_kind": dict(hlo.collective_bytes),
        },
    )
