"""Process-wide switch + registry for the DSE memoization layers.

The batched evaluation engine memoizes two pure functions on the hot path
(trace generation and multi-dimensional collective timing).  Both caches are
keyed on fully-hashable value objects, so a hit is bit-identical to a miss
by construction; this module only provides

  * a global on/off switch (`set_caches_enabled`) so benchmarks can measure
    the uncached seed-equivalent path honestly, and
  * a registry so tests and long-lived searches can clear or inspect every
    cache in one call.

The `COSMIC_DISABLE_CACHES=1` environment variable disables caching at
import time (useful for A/B throughput runs without touching code).
"""
from __future__ import annotations

import functools
import os
from functools import lru_cache
from typing import Callable

_enabled: bool = os.environ.get("COSMIC_DISABLE_CACHES", "0") != "1"

# lru_cache-wrapped functions registered by the modules that own them
_registry: list = []

# bumped by clear_all_caches(); holders of per-instance memo dicts (e.g.
# CosmicEnv's evaluation memo) compare against it to invalidate lazily
_epoch: int = 0


def caches_enabled() -> bool:
    return _enabled


def set_caches_enabled(flag: bool) -> None:
    """Flip memoization globally (existing entries are kept; a disabled
    cache is simply bypassed)."""
    global _enabled
    _enabled = bool(flag)


def register_cache(fn) -> None:
    """Register an lru_cache-wrapped function for global clear/info."""
    _registry.append(fn)


def switchable_lru_cache(maxsize: int = 128):
    """Memoize a pure function of hashable value objects behind the global
    switch: enabled -> lru_cache (a hit is bit-identical to a miss by
    construction), disabled -> straight call-through.  The cache is
    auto-registered for clear_all_caches()/cache_stats()."""
    def deco(fn):
        cached = lru_cache(maxsize=maxsize)(fn)
        register_cache(cached)

        @functools.wraps(fn)
        def wrapper(*args):
            if _enabled:
                return cached(*args)
            return fn(*args)

        wrapper.cache_info = cached.cache_info
        wrapper.cache_clear = cached.cache_clear
        return wrapper
    return deco


def clear_all_caches() -> None:
    global _epoch
    _epoch += 1
    for fn in _registry:
        fn.cache_clear()


def cache_epoch() -> int:
    return _epoch


def cache_stats() -> dict[str, dict]:
    out = {}
    for fn in _registry:
        info = fn.cache_info()
        out[fn.__name__] = {
            "hits": info.hits, "misses": info.misses,
            "size": info.currsize, "max": info.maxsize,
        }
    return out
