"""The scenario layer: what workload shape is the cluster being designed for?

COSMIC's co-design loop is scenario-agnostic — the paper evaluates training,
serving, and mixed clusters with the same PsA/agent machinery.  A
``Scenario`` packages everything workload-shape-specific behind three
methods:

  * ``psa_params()`` / ``psa_constraints(n_npus)`` — the searchable knobs
    this scenario contributes to the PsA (stack ``"scenario"``), searched by
    agents alongside the workload/collective/network stacks;
  * ``traces(ctx)`` — the symbolic phase traces behind one design point
    (inspection/debug);
  * ``evaluate(ctx)`` — design point -> ``Evaluation`` (reward, latency,
    validity gate), where ``ctx`` is the env-resolved ``EnvContext``.

Four built-ins:

  ``TrainScenario``         one homogeneous training (or monolithic-serving)
                            job — bit-identical to the pre-scenario engine.
  ``DisaggServeScenario``   disaggregated serving: separate prefill and
                            decode NPU pools sized by a searchable
                            ``prefill_frac``, a KV-cache transfer collective
                            between pools, and decode continuous batching
                            with a searchable ``decode_batch``.  Multi-wave
                            loads run as a pipelined multi-wave trace.
  ``RequestStreamScenario`` serving driven by an arrival process (Poisson
                            rate or a replayable inter-arrival trace):
                            requests queue, admit under a searchable
                            batching window / max-in-flight cap, and the
                            admitted waves run as one pipelined multi-pool
                            trace; rewards are streaming metrics (TTFT/TPOT
                            percentiles, SLO goodput).
  ``MultiTenantScenario``   N workloads on disjoint (possibly heterogeneous)
                            cluster partitions whose sizes are searchable;
                            reward is weighted SLO attainment.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, replace
from typing import (Any, Callable, ClassVar, Mapping, Protocol,
                    runtime_checkable)

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.backends import SimCall, SimJob, run_sim_job
from repro.core.cache import switchable_lru_cache
from repro.core.compute import DEVICES, Device
from repro.core.memory import footprint, kv_cache_bytes
from repro.core.psa import Constraint, Parameter, ParameterSet
from repro.core.rewards import (Evaluation, Objective, evaluate_job,
                                slo_attainment, stream_metrics, stream_reward)
from repro.core.simulator import SimResult, SystemConfig
from repro.core.topology import (Cluster, Network, partition_cluster,
                                 sub_network, sub_network_indexed)
from repro.core.workload import (Parallelism, Trace, Wave, WaveSegment,
                                 compose_phases, compose_request_waves,
                                 generate_trace)


@dataclass(frozen=True)
class EnvContext:
    """Everything the env resolves before handing a design point to its
    scenario: the fixed system description plus the per-point config and the
    network/system stacks built from it.  ``backend`` selects the simulation
    backend (``repro.core.backends``) the scenario's ``SimJob`` runs on —
    a registry name (kept a string so envs stay picklable for the process
    pool); ``None`` means the reference event loop."""
    spec: ArchSpec
    n_npus: int
    device: Device
    objective: Objective
    capacity_gb: float
    config: Mapping[str, Any]
    network: Network
    sys_cfg: SystemConfig
    backend: Any = None

    def parallelism(self, n_npus: int | None = None) -> Parallelism:
        """The config's workload-stack knobs resolved against a pool size."""
        c = self.config
        return Parallelism(n_npus if n_npus is not None else self.n_npus,
                           c["dp"], c["sp"], c["pp"],
                           bool(c["weight_sharded"]))

    def reward(self, latency_ms: float) -> float:
        """The env objective applied to one end-to-end latency (scenarios
        with richer metrics — streaming — resolve rewards themselves)."""
        return self.objective.scalar(latency_ms, self.sys_cfg.network)


@runtime_checkable
class Scenario(Protocol):
    """Structural protocol — any frozen, picklable object with these methods
    can drive ``CosmicEnv`` (process-pool workers receive a copy).

    Optional capability: ``sim_job(ctx) -> SimJob | Evaluation`` describes
    the design point's simulator calls declaratively (see
    ``repro.core.backends``).  Scenarios that provide it get population-
    vectorized evaluation for free — ``CosmicEnv.step_batch`` hands the
    surviving unique configs of a batch to the backend's ``simulate_batch``
    (grouped by shared trace) instead of looping ``evaluate``.  All four
    built-ins implement it; ``evaluate`` is then just ``run_sim_job(
    self.sim_job(ctx), ctx.backend)``."""

    name: str

    def psa_params(self) -> list[Parameter]: ...
    def psa_constraints(self, n_npus: int) -> list[Constraint]: ...
    def traces(self, ctx: EnvContext) -> dict[str, Trace]: ...
    def evaluate(self, ctx: EnvContext) -> Evaluation: ...


def scenario_psa(base: ParameterSet, scenario: Scenario,
                 n_npus: int) -> ParameterSet:
    """The base PsA extended with the scenario's searchable knobs — the
    'scenario' stack of the design space."""
    params = scenario.psa_params()
    if not params:
        return base
    return base.extend(params, scenario.psa_constraints(n_npus),
                       name=f"{base.name}+{scenario.name}")


def _invalid(why: str) -> Evaluation:
    return Evaluation(0.0, float("inf"), False, {"why": why})


# ---------------------------------------------------------------------------
# TrainScenario — the pre-scenario engine, verbatim
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainScenario:
    """One homogeneous job on the whole cluster: the engine's original
    behavior (``mode="train"`` training step latency, or ``mode="serve"``
    monolithic prefill+decode serving), reward-identical to the
    pre-scenario code path."""
    batch: int
    seq: int
    mode: str = "train"            # train | serve | inference
    decode_tokens: int = 64
    name: str = "train"

    def psa_params(self) -> list[Parameter]:
        return []

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return []

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        par = ctx.parallelism()
        if self.mode == "serve":
            return {"prefill": generate_trace(ctx.spec, par, batch=self.batch,
                                              seq=self.seq, mode="prefill"),
                    "decode": generate_trace(ctx.spec, par, batch=self.batch,
                                             seq=self.seq, mode="decode")}
        return {self.mode: generate_trace(ctx.spec, par, batch=self.batch,
                                          seq=self.seq, mode=self.mode)}

    def sim_job(self, ctx: EnvContext) -> "SimJob | Evaluation":
        return evaluate_job(ctx.spec, ctx.parallelism(), ctx.sys_cfg,
                            batch=self.batch, seq=self.seq, mode=self.mode,
                            objective=ctx.objective,
                            capacity_gb=ctx.capacity_gb,
                            decode_tokens=self.decode_tokens)

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        return run_sim_job(self.sim_job(ctx), ctx.backend)


# ---------------------------------------------------------------------------
# DisaggServeScenario — prefill/decode disaggregation
# ---------------------------------------------------------------------------

def _decode_pool(n_dec: int, batch: int, decode_batch: int) -> tuple[Parallelism, int, int]:
    """(decode-pool parallelism, waves, resident requests): ``replicas``
    continuous-batching groups of up to ``decode_batch`` requests, each TP
    over its pool share; ``waves`` serial passes drain ``batch`` requests."""
    replicas = min(n_dec, max(1, math.ceil(batch / decode_batch)))
    tp = n_dec // replicas
    par = Parallelism(replicas * tp, dp=replicas, sp=1, pp=1)
    waves = math.ceil(batch / (replicas * decode_batch))
    # no more requests can be in flight than exist
    resident = min(decode_batch * replicas, batch)
    return par, waves, resident


def _serving_wave_trace(spec: ArchSpec, par_pre: Parallelism,
                        par_dec: Parallelism, *,
                        wave_shapes: list[tuple[int, int, int]],
                        releases_ms: list[float],
                        max_inflight: int | None,
                        meta: dict[str, Any],
                        wave_tiers: tuple | None = None,
                        admission: str = "gated",
                        prefill_chunks: int = 1) -> Trace:
    """The pipelined multi-wave disagg trace: each wave is prefill (pool 0)
    -> KV ``xfer`` -> first decode token -> remaining tokens (pool 1,
    op-level ``repeat``).  Decode waves chain (the pool holds one wave's KV
    at a time) while wave k+1's prefill overlaps wave k's decode in the
    event loop; ``max_inflight`` (if given) additionally gates wave w's
    prefill behind wave w-max_inflight's completion, and ``releases_ms``
    gates each wave behind its arrival-process admission time.

    ``wave_shapes`` is one ``(size, seq, decode_tokens)`` per wave —
    heterogeneous request lengths reach the trace here, each wave padded to
    its longest admitted prompt and chained to its longest decode.

    Continuous-batching engine knobs (all default to the classic chained
    behavior):

      ``admission="continuous"``   wave w's decode gates on wave w-1's
                                   FIRST token instead of its completion —
                                   the wave joins the resident batch
                                   mid-wave (per-step admission).
      ``prefill_chunks > 1``       chunked prefill: only the final KV chunk
                                   is on the TTFT critical path (see
                                   ``WaveSegment.transfer_chunks``).
      ``wave_tiers``               per-wave priority tiers (lower = more
                                   interactive); a wave's decode chains on
                                   the last earlier wave of its own or a
                                   higher tier, so interactive waves
                                   preempt batch-tier decode chaining.

    Memoized on every trace-shaping input (the network/collective stacks
    don't shape the trace), so design points differing only in those stacks
    share one composed trace — and its piggybacked simulator plan."""
    return _serving_wave_trace_cached(
        spec, par_pre, par_dec, tuple(tuple(s) for s in wave_shapes),
        tuple(releases_ms), max_inflight,
        str(meta.get("arch", "")), str(meta.get("scenario", "")),
        wave_tiers, admission, prefill_chunks)


def _serving_wave_trace_impl(spec: ArchSpec, par_pre: Parallelism,
                             par_dec: Parallelism, wave_shapes: tuple,
                             releases_ms: tuple, max_inflight: int | None,
                             arch: str, scenario: str,
                             wave_tiers: tuple | None = None,
                             admission: str = "gated",
                             prefill_chunks: int = 1) -> Trace:
    meta = dict(arch=arch, scenario=scenario)
    lanes = max(1, min(par_pre.n_npus, par_dec.n_npus))
    continuous = admission == "continuous"
    # each wave's last segment index (gates reference the EARLIER wave's
    # completion, so a one-token wave's last segment is 1, not 2)
    last_seg = [2 if dec > 1 else 1 for _, _, dec in wave_shapes]
    waves: list[Wave] = []
    for w, (size, seq, decode_tokens) in enumerate(wave_shapes):
        pre = generate_trace(spec, par_pre, batch=size, seq=seq,
                             mode="prefill")
        dec = generate_trace(spec, par_dec, batch=size, seq=seq,
                             mode="decode")
        xb = kv_cache_bytes(spec, batch=size, seq=seq) / lanes
        segs = [WaveSegment(pre, 0, 1, xb, transfer_chunks=prefill_chunks),
                WaveSegment(dec, 1)]
        if decode_tokens > 1:
            segs.append(WaveSegment(dec, 1, decode_tokens - 1))
        gates = []
        prev = w - 1
        if wave_tiers is not None:
            # preemptive chaining: an interactive wave never waits behind a
            # batch-tier wave's decode — it chains on the last earlier wave
            # of its own-or-higher priority (batch tiers still pay full
            # resource contention against the interactive waves' decode)
            prev = next((v for v in range(w - 1, -1, -1)
                         if wave_tiers[v] <= wave_tiers[w]), -1)
        if prev >= 0:
            gates.append((1, prev, 1 if continuous else last_seg[prev]))
        if max_inflight is not None and w >= max_inflight:
            gates.append((0, w - max_inflight, last_seg[w - max_inflight]))
        waves.append(Wave(tuple(segs), release_ms=releases_ms[w],
                          gates=tuple(gates)))
    return compose_request_waves(waves, meta=meta)


_serving_wave_trace_cached = \
    switchable_lru_cache(maxsize=512)(_serving_wave_trace_impl)


def _wave_mark_index(trace: Trace):
    """Flattened wave-mark tail uids + segment offsets, built once and
    piggybacked on the (cached, immutable) trace so the per-evaluation read
    is two fancy gathers instead of thousands of dict lookups."""
    idx = getattr(trace, "_wave_mark_idx", None)
    if idx is None:
        first: list[int] = []
        done: list[int] = []
        off_f = [0]
        off_d = [0]
        for mk in trace.meta["wave_marks"]:
            first.extend(mk["seg_tails"][1])
            done.extend(mk["seg_tails"][-1])
            off_f.append(len(first))
            off_d.append(len(done))
        idx = (np.asarray(first, dtype=np.intp), np.asarray(off_f[:-1]),
               np.asarray(done, dtype=np.intp), np.asarray(off_d[:-1]))
        trace._wave_mark_idx = idx
    return idx


def _wave_times_ms(trace: Trace, res: SimResult) -> list[tuple[float, float]]:
    """Per wave ``(first_token_ms, last_token_ms)`` completion times, read
    off the recorded op finish times through ``meta["wave_marks"]``."""
    fin = res.op_finish_us
    row = getattr(fin, "_row", None)
    if row is not None and trace.meta["wave_marks"]:
        # vectorized backends expose the finish times as one array row:
        # segment-max the tail uids instead of looping dict reads (reduceat
        # takes the max over the same floats, so values are bit-identical)
        uids_f, off_f, uids_d, off_d = _wave_mark_index(trace)
        t_first = np.maximum.reduceat(row[uids_f], off_f) / 1e3
        t_done = np.maximum.reduceat(row[uids_d], off_d) / 1e3
        return list(zip(t_first.tolist(), t_done.tolist()))
    out = []
    for mk in trace.meta["wave_marks"]:
        t_first = max(fin[u] for u in mk["seg_tails"][1]) / 1e3
        t_done = max(fin[u] for u in mk["seg_tails"][-1]) / 1e3
        out.append((t_first, t_done))
    return out


def _compose_memo(pre: Trace, dec: Trace, xfer_bytes: float,
                  meta: dict[str, Any]) -> Trace:
    """compose_phases memoized by input-trace identity: phase traces are
    interned by the trace cache, so repeated design points sharing them get
    the same composed trace (and its piggybacked ``_SimPlan``) back.  The
    memo rides on the prefill trace, dying with it when caches are off."""
    memo = getattr(pre, "_composed", None)
    if memo is None:
        memo = pre._composed = {}
    # entries hold a strong ref to their decode trace, so a live key's id
    # can't be recycled by a different (evicted-and-rebuilt) trace
    key = (id(dec), xfer_bytes)
    entry = memo.get(key)
    if entry is None or entry[0] is not dec:
        tr = compose_phases([(pre, 0), (dec, 1)],
                            transfers=[xfer_bytes], meta=meta)
        memo[key] = entry = (dec, tr)
    return entry[1]


@dataclass(frozen=True)
class DisaggServeScenario:
    """Disaggregated serving: ``prefill_frac`` of the cluster prefills
    prompts, the rest decodes, and finished prompts hand their KV caches
    across a transfer collective bridging the pools.

    The prefill pool is parallelized by the config's workload knobs; the
    decode pool is carved into ``ceil(batch / decode_batch)`` continuous-
    batching replicas, each tensor-parallel over its share of the pool —
    so the search can give prefill its MXU-efficient moderate TP while
    decode shards weight/KV reads as widely as the pool allows.

    ``prefill_frac = 1.0`` degenerates to the monolithic serve path
    (``TrainScenario(mode="serve")``): one pool, one parallelization for
    both phases, no transfer.

    ``pipelined=True`` (default) runs multi-wave loads as ONE pipelined
    multi-wave trace (per-wave prefill/xfer/decode, wave k+1's prefill
    overlapping wave k's decode in the event loop); ``pipelined=False``
    keeps the older analytic composition — one full-batch prefill then
    ``waves * decode_tokens`` serial token steps — for comparison.
    """
    batch: int
    seq: int
    decode_tokens: int = 64
    prefill_fracs: tuple = (0.25, 0.5, 0.625, 0.75, 0.875, 1.0)
    decode_batches: tuple = (4, 8, 16, 32, 64, 128)
    pipelined: bool = True
    name: str = "disagg-serve"

    def psa_params(self) -> list[Parameter]:
        return [
            Parameter("prefill_frac", "scenario", self.prefill_fracs,
                      doc="fraction of the cluster in the prefill pool"),
            Parameter("decode_batch", "scenario", self.decode_batches,
                      doc="requests continuously batched per decode replica"),
        ]

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return []

    def canonical(self, config: Mapping[str, Any]) -> Mapping[str, Any]:
        """Memo-key canonicalization: at ``prefill_frac >= 1.0`` the decode
        pool doesn't exist and ``decode_batch`` is ignored, so all its
        values are one design point — don't re-evaluate them."""
        if float(config.get("prefill_frac", 0.0)) >= 1.0 \
                and "decode_batch" in config:
            return dict(config, decode_batch=self.decode_batches[0])
        return config

    # -- pool sizing -------------------------------------------------------
    def _pools(self, ctx: EnvContext) -> tuple[int, int]:
        frac = float(ctx.config["prefill_frac"])
        n_pre = int(round(frac * ctx.n_npus))
        return n_pre, ctx.n_npus - n_pre

    def _decode_par(self, n_dec: int, decode_batch: int) -> tuple[Parallelism, int, int]:
        return _decode_pool(n_dec, self.batch, decode_batch)

    def _wave_sizes(self, waves: int, resident: int) -> list[int]:
        """Per-wave request counts: full ``resident`` waves + the tail."""
        return [resident] * (waves - 1) + [self.batch - resident * (waves - 1)]

    def _pipelined_trace(self, ctx: EnvContext, par_pre: Parallelism,
                         par_dec: Parallelism, waves: int,
                         resident: int) -> Trace:
        return _serving_wave_trace(
            ctx.spec, par_pre, par_dec,
            wave_shapes=[(size, self.seq, self.decode_tokens)
                         for size in self._wave_sizes(waves, resident)],
            releases_ms=[0.0] * waves, max_inflight=None,
            meta=dict(arch=ctx.spec.name, scenario=self.name))

    def _phase_traces(self, ctx: EnvContext, par_pre: Parallelism,
                      par_dec: Parallelism, resident: int) -> tuple[Trace, Trace, Trace]:
        pre = generate_trace(ctx.spec, par_pre, batch=self.batch,
                             seq=self.seq, mode="prefill")
        dec = generate_trace(ctx.spec, par_dec, batch=resident,
                             seq=self.seq, mode="decode")
        # prefill -> KV transfer -> first decode step, on separate pools
        combined = _compose_memo(
            pre, dec, self._xfer_bytes(ctx, par_pre.n_npus, par_dec.n_npus),
            meta=dict(arch=ctx.spec.name, scenario=self.name))
        return pre, dec, combined

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        if float(ctx.config["prefill_frac"]) >= 1.0:
            return TrainScenario(self.batch, self.seq, "serve",
                                 self.decode_tokens).traces(ctx)
        n_pre, n_dec = self._pools(ctx)
        if n_pre < 1 or n_dec < 1:
            raise ValueError(f"degenerate pool split {n_pre}/{n_dec} for "
                             f"prefill_frac={ctx.config['prefill_frac']} on "
                             f"{ctx.n_npus} NPUs")
        par_pre = ctx.parallelism(n_pre)
        par_dec, waves, resident = self._decode_par(
            n_dec, int(ctx.config["decode_batch"]))
        if self.pipelined:
            sizes = self._wave_sizes(waves, resident)
            pre = generate_trace(ctx.spec, par_pre, batch=sizes[0],
                                 seq=self.seq, mode="prefill")
            dec = generate_trace(ctx.spec, par_dec, batch=sizes[0],
                                 seq=self.seq, mode="decode")
            combined = self._pipelined_trace(ctx, par_pre, par_dec, waves,
                                             resident)
            return {"prefill": pre, "decode": dec, "combined": combined}
        pre, dec, combined = self._phase_traces(ctx, par_pre, par_dec,
                                                resident)
        return {"prefill": pre, "decode": dec, "combined": combined}

    def _xfer_bytes(self, ctx: EnvContext, n_pre: int, n_dec: int) -> float:
        """KV handoff per transfer lane: the whole batch's caches move, with
        one concurrent lane per (prefill, decode) NPU pair."""
        total = kv_cache_bytes(ctx.spec, batch=self.batch, seq=self.seq)
        return total / max(1, min(n_pre, n_dec))

    def sim_job(self, ctx: EnvContext) -> "SimJob | Evaluation":
        frac = float(ctx.config["prefill_frac"])
        if frac >= 1.0:
            # degenerate: one pool serves both phases (the monolithic path)
            def mono(ev: Evaluation) -> Evaluation:
                if ev.valid:
                    ev = replace(ev, detail=dict(ev.detail,
                                                 scenario=self.name,
                                                 monolithic=True))
                return ev

            inner = TrainScenario(self.batch, self.seq, "serve",
                                  self.decode_tokens).sim_job(ctx)
            if not isinstance(inner, SimJob):
                return mono(inner)
            return SimJob(inner.calls, lambda rs: mono(inner.finalize(rs)))
        decode_batch = int(ctx.config["decode_batch"])
        n_pre, n_dec = self._pools(ctx)
        if n_pre < 1 or n_dec < 1:
            return _invalid(f"degenerate pool split {n_pre}/{n_dec}")
        par_pre = ctx.parallelism(n_pre)
        if not par_pre.valid():
            return _invalid(f"prefill parallelization invalid on {n_pre} NPUs")
        fp_pre = footprint(ctx.spec, par_pre, batch=self.batch, seq=self.seq,
                           mode="inference")
        if fp_pre.total_gb > ctx.capacity_gb:
            return _invalid(f"prefill memory {fp_pre.total_gb:.1f}GB "
                            f"> {ctx.capacity_gb}GB")
        par_dec, waves, resident = self._decode_par(n_dec, decode_batch)
        fp_dec = footprint(ctx.spec, par_dec, batch=resident, seq=self.seq,
                           mode="decode")
        if fp_dec.total_gb > ctx.capacity_gb:
            return _invalid(f"decode memory {fp_dec.total_gb:.1f}GB "
                            f"> {ctx.capacity_gb}GB")

        # each pool's collectives are priced on the sub-fabric its NPU
        # slice spans, not the whole cluster (same carving rule as
        # MultiTenantScenario partitions), with each sub-dim's algorithm
        # resolved against its SOURCE physical dim
        pre_pool = (par_pre, *sub_network_indexed(ctx.network, par_pre.n_npus))
        dec_pool = (par_dec, *sub_network_indexed(ctx.network, par_dec.n_npus))
        detail = {
            "scenario": self.name, "prefill_npus": n_pre,
            "decode_npus": par_dec.n_npus, "decode_tp": par_dec.tp,
            "decode_replicas": par_dec.dp, "decode_batch": decode_batch,
            "waves": waves, "pipelined": self.pipelined,
            "prefill_gb": fp_pre.total_gb, "decode_gb": fp_dec.total_gb,
        }
        if self.pipelined:
            tr = self._pipelined_trace(ctx, par_pre, par_dec, waves, resident)

            def fin_pipe(results: list[SimResult]) -> Evaluation:
                res = results[0]
                t_first, t_done = _wave_times_ms(tr, res)[0]
                latency_ms = res.latency_ms
                detail.update(
                    ttft_ms=t_first,
                    p50_token_latency_ms=(t_done - t_first)
                    / max(self.decode_tokens - 1, 1))
                return Evaluation(ctx.reward(latency_ms), latency_ms, True,
                                  detail)

            return SimJob((SimCall(tr, ctx.sys_cfg, par_pre,
                                   pools={0: pre_pool, 1: dec_pool},
                                   record_finish=True),), fin_pipe)

        _, dec_tr, combined = self._phase_traces(ctx, par_pre, par_dec,
                                                 resident)

        def fin_analytic(results: list[SimResult]) -> Evaluation:
            first, step = results
            t_token_ms = step.latency_ms
            latency_ms = first.latency_ms \
                + (self.decode_tokens * waves - 1) * t_token_ms
            detail.update(ttft_ms=first.latency_ms - t_token_ms,
                          p50_token_latency_ms=t_token_ms)
            return Evaluation(ctx.reward(latency_ms), latency_ms, True,
                              detail)

        return SimJob((SimCall(combined, ctx.sys_cfg, par_pre,
                               pools={0: pre_pool, 1: dec_pool}),
                       SimCall(dec_tr, ctx.sys_cfg, par_dec,
                               pools={0: dec_pool})), fin_analytic)

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        return run_sim_job(self.sim_job(ctx), ctx.backend)


# ---------------------------------------------------------------------------
# RequestStreamScenario — arrival-process serving with queueing
# ---------------------------------------------------------------------------

def _arrivals_impl(gaps_ms: tuple, n_requests: int, rate_rps: float,
                   seed: int) -> tuple[float, ...]:
    if gaps_ms:
        gaps = [float(gaps_ms[i % len(gaps_ms)]) for i in range(n_requests)]
    else:
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1000.0 / rate_rps, n_requests).tolist()
    t, out = 0.0, []
    for g in gaps:
        t += g
        out.append(t)
    return tuple(out)


_arrivals_cached = switchable_lru_cache(maxsize=64)(_arrivals_impl)


def _request_shapes_impl(n: int, seq: int, decode_tokens: int,
                         prompt_lens: tuple, decode_lens: tuple,
                         prompt_len_range: tuple, decode_len_range: tuple,
                         seed: int) -> tuple[tuple[int, int], ...]:
    """Per-request ``(prompt_len, decode_len)`` pairs: replayed traces win
    over seeded uniform ranges, which win over the homogeneous defaults."""
    def resolve(replay: tuple, lo_hi: tuple, fixed: int,
                tag: int, what: str) -> list[int]:
        if replay:
            out = [int(replay[i % len(replay)]) for i in range(n)]
        elif lo_hi:
            lo, hi = int(lo_hi[0]), int(lo_hi[1])
            if not 1 <= lo <= hi:
                raise ValueError(f"{what} range ({lo}, {hi}) must satisfy "
                                 f"1 <= lo <= hi")
            # a distinct stream per (seed, field) so lengths don't perturb
            # the arrival process draws
            rng = np.random.default_rng([seed, tag])
            out = [int(v) for v in rng.integers(lo, hi + 1, size=n)]
        else:
            out = [int(fixed)] * n
        if min(out) < 1:
            raise ValueError(f"{what} lengths must be >= 1, got {min(out)}")
        return out

    prompts = resolve(prompt_lens, prompt_len_range, seq, 0x9E, "prompt")
    decodes = resolve(decode_lens, decode_len_range, decode_tokens, 0x51,
                      "decode")
    return tuple(zip(prompts, decodes))


_request_shapes_cached = switchable_lru_cache(maxsize=64)(_request_shapes_impl)


@switchable_lru_cache(maxsize=1024)
def _form_waves_cached(arrivals: tuple, window_ms: float,
                       cap: int) -> tuple[tuple[tuple[int, ...], float], ...]:
    """Queueing/admission memo: the wave grouping depends only on the
    (cached) arrival process and two scenario knobs, so a population that
    shares them — the common case in a search batch — forms waves once."""
    waves: list[tuple[tuple[int, ...], float]] = []
    cur: list[int] = []
    deadline = 0.0
    for i, t in enumerate(arrivals):
        if cur and t > deadline:
            waves.append((tuple(cur), deadline))
            cur = []
        cur.append(i)
        if len(cur) == 1:
            deadline = t + window_ms
        if len(cur) == cap:
            waves.append((tuple(cur), t))
            cur = []
    if cur:
        waves.append((tuple(cur), deadline))
    return tuple(waves)


@switchable_lru_cache(maxsize=1024)
def _wave_shapes_cached(shapes: tuple, waves: tuple) -> tuple:
    return tuple((len(idxs), max(shapes[i][0] for i in idxs),
                  max(shapes[i][1] for i in idxs)) for idxs, _ in waves)


@switchable_lru_cache(maxsize=1024)
def _wave_request_index(waves: tuple) -> tuple:
    """Flattened admitted-request indices + per-wave counts for the
    vectorized streaming-metrics pass."""
    cat = np.asarray([i for idxs, _ in waves for i in idxs], dtype=np.intp)
    counts = np.asarray([len(idxs) for idxs, _ in waves], dtype=np.intp)
    return cat, counts


def _request_tiers_impl(n: int, priorities: tuple, frac: float,
                        seed: int) -> tuple[int, ...]:
    if priorities:
        return tuple(int(priorities[i % len(priorities)]) for i in range(n))
    if frac <= 0.0:
        return (1,) * n
    # a distinct stream per (seed, field), like the shape draws, so tiers
    # don't perturb the arrival/length processes
    rng = np.random.default_rng([seed, 0x7E])
    return tuple(int(v) for v in (rng.random(n) >= frac))


_request_tiers_cached = switchable_lru_cache(maxsize=64)(_request_tiers_impl)


@switchable_lru_cache(maxsize=1024)
def _form_waves_tiered(arrivals: tuple, tiers: tuple, window_ms: float,
                       cap: int) -> tuple[tuple[tuple[int, ...], float, int], ...]:
    """Per-tier admission queues merged by release time: each priority tier
    forms its own waves (an interactive request never waits for a batch-tier
    wave to fill), tagged with the tier for the preemption gates.  Returns
    ``((indices, release_ms, tier), ...)`` sorted by (release, tier)."""
    out: list[tuple[tuple[int, ...], float, int]] = []
    for tier in sorted(set(tiers)):
        idxs = tuple(i for i, t in enumerate(tiers) if t == tier)
        sub = tuple(arrivals[i] for i in idxs)
        for w_idxs, rel in _form_waves_cached(sub, window_ms, cap):
            out.append((tuple(idxs[j] for j in w_idxs), rel, tier))
    out.sort(key=lambda w: (w[1], w[2]))
    return tuple(out)


def _per_request_times(waves, wave_shapes, shapes, arrivals,
                       wt) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized per-request ``(ttft, tpot, latency)`` arrays from the
    per-wave ``(first_token, last_token)`` times, flattened in (wave,
    admitted-index) order: same arithmetic as the per-request loop it
    replaces (one subtract / one multiply-add per request, identical
    operand order).  Shared by the single-engine finalize and the fleet
    layer's per-replica concatenation."""
    t_first = np.asarray([t for t, _ in wt])
    t_done = np.asarray([t for _, t in wt])
    wave_dec = np.asarray([d for _, _, d in wave_shapes])
    tpot_w = (t_done - t_first) / np.maximum(wave_dec - 1, 1)
    cat, counts = _wave_request_index(tuple(waves))
    dec_r = np.asarray([d for _, d in shapes])[cat]
    t_first_r = np.repeat(t_first, counts)
    tpot_r = np.repeat(tpot_w, counts)
    # a request finishes after ITS decode length at the wave's
    # token cadence (== t_done for the wave's longest request)
    done_r = np.where(dec_r == np.repeat(wave_dec, counts),
                      np.repeat(t_done, counts),
                      t_first_r + tpot_r * (dec_r - 1))
    arr_r = np.asarray(arrivals)[cat]
    return t_first_r - arr_r, tpot_r, done_r - arr_r


def _kv_inflight_cap(spec: ArchSpec, par_dec: Parallelism, resident: int,
                     full_seq: int, headroom: float, capacity_gb: float,
                     static_gb: float) -> int:
    """KV paging-pressure admission cap: how many waves' resident caches fit
    the decode pool's free HBM (capacity minus the non-KV footprint
    ``static_gb`` — weights + activations) at ``headroom`` occupancy.  One
    wave's cache is priced per decode NPU at its full post-decode length
    (prompt + decode tokens; batch shards over the pool's replicas, KV over
    its TP)."""
    per_wave_gb = kv_cache_bytes(spec, batch=resident / par_dec.dp,
                                 seq=full_seq, tp=par_dec.tp) / 1e9
    free_gb = capacity_gb - static_gb
    return max(1, int((free_gb * headroom) // max(per_wave_gb, 1e-12)))


@dataclass(frozen=True)
class RequestStreamScenario:
    """Serving a request STREAM instead of one analytic batch: requests
    arrive by a Poisson process (``rate_rps``) or a replayable inter-arrival
    trace (``arrival_gaps_ms``, cycled over ``n_requests``), queue, and are
    admitted in waves under a searchable batching window; admitted waves run
    through disaggregated prefill/decode pools as ONE pipelined multi-wave
    trace (per-wave prefill -> KV ``xfer`` -> decode, wave k+1's prefill
    overlapping wave k's decode on separate pool resources, with release
    delays carrying the arrival times into the event loop).

    Searchable scenario knobs (alongside the workload/collective/network
    stacks):

      ``batch_window_ms``  how long an open wave waits for more requests —
                           trades queueing delay (TTFT) against batching
                           efficiency; a wave also closes when it reaches
                           ``max_batch`` requests.
      ``max_inflight``     admission cap: wave w's prefill is gated behind
                           wave w-max_inflight's completion.
      ``prefill_frac``     prefill/decode pool split (as DisaggServe).
      ``decode_batch``     continuous-batching replica size (as DisaggServe).

    Heterogeneous request lengths: by default every request is ``seq``
    prompt tokens and ``decode_tokens`` output tokens, but per-request
    lengths can be drawn from a seeded uniform distribution
    (``prompt_len_range`` / ``decode_len_range``, inclusive ``(lo, hi)``)
    or replayed from a trace (``prompt_lens`` / ``decode_lens``, cycled
    over ``n_requests``).  Each admitted wave is padded to its longest
    prompt and chains to its longest decode; a request's completion time is
    its own decode length times the wave's token cadence.

    Continuous-batching engine knobs are opt-in: each empty choice tuple
    below contributes no PsA parameter and leaves the wave model
    bit-identical to the classic chained behavior.  Non-empty tuples expose
    (as scenario-stack knobs) ``admission`` (gated vs continuous mid-wave
    join), ``prefill_chunks`` (chunked-prefill KV streaming),
    ``preempt`` (priority-tier decode preemption; pair with
    ``priority_frac`` or replayed ``priorities``), and ``kv_headroom``
    (KV paging pressure throttling ``max_inflight`` against free HBM).

    Rewards are streaming metrics: ``objective="goodput"`` maximizes
    requests meeting BOTH SLOs per second; any classic objective applies to
    the p99 end-to-end request latency.  TTFT/TPOT p50/p99 are always in
    ``Evaluation.detail``."""
    # class marker: this scenario resolves STREAM_OBJECTIVES ("goodput")
    # itself — CosmicEnv rejects those objectives for scenarios without it
    supports_stream_objectives: ClassVar[bool] = True

    n_requests: int = 64
    seq: int = 2048
    decode_tokens: int = 64
    rate_rps: float = 8.0
    arrival_gaps_ms: tuple = ()      # replayable inter-arrival gaps (ms)
    seed: int = 0
    prompt_len_range: tuple = ()     # (lo, hi) seeded per-request prompt lens
    decode_len_range: tuple = ()     # (lo, hi) seeded per-request decode lens
    prompt_lens: tuple = ()          # replayed per-request prompt lens
    decode_lens: tuple = ()          # replayed per-request decode lens
    max_batch: int = 32              # hard cap on requests per wave
    ttft_slo_ms: float = 4000.0
    tpot_slo_ms: float = 200.0
    batch_windows_ms: tuple = (0.0, 50.0, 200.0, 500.0, 1000.0)
    max_inflights: tuple = (1, 2, 4, 8)
    prefill_fracs: tuple = (0.25, 0.5, 0.625, 0.75, 0.875)
    decode_batches: tuple = (4, 8, 16, 32)
    # -- continuous-batching engine knobs (opt-in; empty = classic model) --
    arrival_times_ms: tuple = ()     # explicit arrival times (fleet routing
    #                                  replay; wins over gaps/rate)
    priority_frac: float = 0.0       # fraction of interactive (tier-0) reqs
    priorities: tuple = ()           # replayed per-request tiers (0 = hi)
    admissions: tuple = ()           # e.g. ("gated", "continuous")
    prefill_chunk_choices: tuple = ()  # e.g. (1, 2, 4)
    preempt_choices: tuple = ()      # e.g. (0, 1)
    kv_headrooms: tuple = ()         # e.g. (0.5, 0.8) of free HBM for KV
    name: str = "request-stream"

    def psa_params(self) -> list[Parameter]:
        params = [
            Parameter("batch_window_ms", "scenario", self.batch_windows_ms,
                      doc="max wait for an open admission wave to fill"),
            Parameter("max_inflight", "scenario", self.max_inflights,
                      doc="admission cap on waves in flight"),
            Parameter("prefill_frac", "scenario", self.prefill_fracs,
                      doc="fraction of the cluster in the prefill pool"),
            Parameter("decode_batch", "scenario", self.decode_batches,
                      doc="requests continuously batched per decode replica"),
        ]
        if self.admissions:
            params.append(Parameter(
                "admission", "scenario", self.admissions,
                doc="gated: wave chains on predecessor completion; "
                    "continuous: joins the resident batch mid-wave"))
        if self.prefill_chunk_choices:
            params.append(Parameter(
                "prefill_chunks", "scenario", self.prefill_chunk_choices,
                doc="KV chunks streamed during prefill — only the last is "
                    "on the TTFT critical path"))
        if self.preempt_choices:
            params.append(Parameter(
                "preempt", "scenario", self.preempt_choices,
                doc="1: interactive (tier-0) waves preempt batch-tier "
                    "decode chaining"))
        if self.kv_headrooms:
            params.append(Parameter(
                "kv_headroom", "scenario", self.kv_headrooms,
                doc="fraction of free HBM usable by resident KV — throttles "
                    "max_inflight under paging pressure"))
        return params

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return []

    # -- arrival process ---------------------------------------------------
    def arrivals_ms(self) -> tuple[float, ...]:
        """Request arrival times: deterministic given the scenario fields
        (explicit times, replayed gaps, or seeded exponential gaps for a
        Poisson process).  Memoized — arrivals are identical for every
        design point of a search, so the hot path shouldn't redraw them per
        evaluation."""
        if self.arrival_times_ms:
            if len(self.arrival_times_ms) != self.n_requests:
                raise ValueError(
                    f"arrival_times_ms has {len(self.arrival_times_ms)} "
                    f"entries for n_requests={self.n_requests}")
            return tuple(float(t) for t in self.arrival_times_ms)
        return _arrivals_cached(self.arrival_gaps_ms, self.n_requests,
                                self.rate_rps, self.seed)

    def request_shapes(self) -> tuple[tuple[int, int], ...]:
        """Per-request ``(prompt_len, decode_len)``: deterministic given the
        scenario fields (replayed traces, seeded ranges, or the homogeneous
        ``(seq, decode_tokens)`` defaults).  Memoized like the arrivals."""
        return _request_shapes_cached(
            self.n_requests, self.seq, self.decode_tokens, self.prompt_lens,
            self.decode_lens, self.prompt_len_range, self.decode_len_range,
            self.seed)

    def heterogeneous(self) -> bool:
        return bool(self.prompt_len_range or self.decode_len_range
                    or self.prompt_lens or self.decode_lens)

    def request_tiers(self) -> tuple[int, ...]:
        """Per-request priority tier (0 = interactive, 1 = batch): replayed
        (``priorities``, cycled) or seeded Bernoulli(``priority_frac``) on a
        stream distinct from the arrival/shape draws.  The all-one-tier
        default keeps wave formation and gating bit-identical to the
        pre-tier path."""
        return _request_tiers_cached(self.n_requests, self.priorities,
                                     self.priority_frac, self.seed)

    def engine_extended(self) -> bool:
        """True when any opt-in continuous-batching knob is exposed."""
        return bool(self.admissions or self.prefill_chunk_choices
                    or self.preempt_choices or self.kv_headrooms)

    def _engine_knobs(self, config: Mapping[str, Any]) -> tuple[str, int, bool]:
        """(admission, prefill_chunks, preempt) resolved from a design
        point, defaulting to the classic chained model when the knobs
        aren't in the search space."""
        return (str(config.get("admission", "gated")),
                int(config.get("prefill_chunks", 1)),
                bool(int(config.get("preempt", 0))))

    def _admitted(self, ctx: EnvContext, resident: int,
                  preempt: bool) -> tuple[tuple, tuple | None]:
        """(waves, wave_tiers): per-tier admission queues when preemption is
        on and the stream is tier-mixed, the classic single queue (tiers
        None) otherwise."""
        window = float(ctx.config["batch_window_ms"])
        tiers = self.request_tiers()
        if preempt and len(set(tiers)) > 1:
            tw = _form_waves_tiered(self.arrivals_ms(), tiers, window,
                                    max(1, resident))
            return (tuple((idxs, rel) for idxs, rel, _ in tw),
                    tuple(t for _, _, t in tw))
        return self.form_waves(window, max_batch=resident), None

    def _wave_shapes(self, waves) -> tuple:
        """Per-wave ``(size, seq, decode_tokens)``: each wave pads to its
        longest admitted prompt and chains to its longest decode.  Memoized
        with the wave grouping itself (see ``_wave_shapes_cached``)."""
        return _wave_shapes_cached(self.request_shapes(), tuple(waves))

    def form_waves(self, window_ms: float,
                   max_batch: int | None = None) -> tuple:
        """Queueing/admission: group arrivals into waves of request indices.
        A wave opens at its first request, releases at ``open + window_ms``
        or the instant it fills to the admission cap; each ``(indices,
        release_ms)`` becomes one wave of the pipelined trace.

        ``max_batch`` overrides the scenario cap — ``evaluate`` passes the
        decode pool's resident capacity (``replicas * decode_batch``, itself
        capped by the scenario ``max_batch``) so an admitted wave never
        exceeds what the decode pool can actually hold.  Memoized per
        ``(arrivals, window, cap)`` — see ``_form_waves_cached``."""
        cap = self.max_batch if max_batch is None else max(1, max_batch)
        return _form_waves_cached(self.arrivals_ms(), window_ms, cap)

    # -- pools (same carving as DisaggServeScenario) -----------------------
    def _pools(self, ctx: EnvContext) -> tuple[int, int]:
        frac = float(ctx.config["prefill_frac"])
        n_pre = int(round(frac * ctx.n_npus))
        return n_pre, ctx.n_npus - n_pre

    def _stream_trace(self, ctx: EnvContext, par_pre: Parallelism,
                      par_dec: Parallelism,
                      waves: list[tuple[list[int], float]], *,
                      max_inflight: int,
                      wave_tiers: tuple | None = None,
                      admission: str = "gated",
                      prefill_chunks: int = 1) -> Trace:
        return _serving_wave_trace(
            ctx.spec, par_pre, par_dec,
            wave_shapes=self._wave_shapes(waves),
            releases_ms=[rel for _, rel in waves],
            max_inflight=max_inflight,
            meta=dict(arch=ctx.spec.name, scenario=self.name),
            wave_tiers=wave_tiers, admission=admission,
            prefill_chunks=prefill_chunks)

    def _resolved(self, ctx: EnvContext):
        n_pre, n_dec = self._pools(ctx)
        if n_pre < 1 or n_dec < 1:
            raise ValueError(f"degenerate pool split {n_pre}/{n_dec}")
        par_pre = ctx.parallelism(n_pre)
        par_dec, _, resident = _decode_pool(n_dec, self.max_batch,
                                            int(ctx.config["decode_batch"]))
        return par_pre, par_dec, resident

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        par_pre, par_dec, resident = self._resolved(ctx)
        admission, prefill_chunks, preempt = self._engine_knobs(ctx.config)
        waves, wave_tiers = self._admitted(ctx, resident, preempt)
        return {"stream": self._stream_trace(
            ctx, par_pre, par_dec, waves,
            max_inflight=int(ctx.config["max_inflight"]),
            wave_tiers=wave_tiers, admission=admission,
            prefill_chunks=prefill_chunks)}

    def stream_call(self, ctx: EnvContext):
        """The engine core behind ``sim_job``, reusable per fleet replica:
        resolve pools, gate memory, admit waves, build the one pipelined
        SimCall.  Returns ``(call, request_times, detail, last_arrival_ms)``
        where ``request_times(res)`` maps the call's ``SimResult`` to
        per-request ``(ttft, tpot, latency)`` arrays — or an ``Evaluation``
        when a validity gate trips."""
        try:
            par_pre, par_dec, resident = self._resolved(ctx)
        except ValueError as e:
            return _invalid(str(e))
        if not par_pre.valid():
            return _invalid(f"prefill parallelization invalid on "
                            f"{par_pre.n_npus} NPUs")
        shapes = self.request_shapes()
        max_seq = max(p for p, _ in shapes)   # == self.seq when homogeneous
        fp_pre = footprint(ctx.spec, par_pre, batch=self.max_batch,
                           seq=max_seq, mode="inference")
        if fp_pre.total_gb > ctx.capacity_gb:
            return _invalid(f"prefill memory {fp_pre.total_gb:.1f}GB "
                            f"> {ctx.capacity_gb}GB")
        fp_dec = footprint(ctx.spec, par_dec, batch=resident, seq=max_seq,
                           mode="decode")
        if fp_dec.total_gb > ctx.capacity_gb:
            return _invalid(f"decode memory {fp_dec.total_gb:.1f}GB "
                            f"> {ctx.capacity_gb}GB")

        admission, prefill_chunks, preempt = self._engine_knobs(ctx.config)
        max_inflight = int(ctx.config["max_inflight"])
        kv_headroom = ctx.config.get("kv_headroom")
        kv_cap = None
        if kv_headroom is not None:
            kv_cap = _kv_inflight_cap(
                ctx.spec, par_dec, resident,
                max_seq + max(d for _, d in shapes), float(kv_headroom),
                ctx.capacity_gb, fp_dec.total_gb - fp_dec.kv_cache_gb)
            max_inflight = min(max_inflight, kv_cap)

        waves, wave_tiers = self._admitted(ctx, resident, preempt)
        tr = self._stream_trace(ctx, par_pre, par_dec, waves,
                                max_inflight=max_inflight,
                                wave_tiers=wave_tiers, admission=admission,
                                prefill_chunks=prefill_chunks)
        pre_pool = (par_pre, *sub_network_indexed(ctx.network, par_pre.n_npus))
        dec_pool = (par_dec, *sub_network_indexed(ctx.network, par_dec.n_npus))
        arrivals = self.arrivals_ms()
        wave_shapes = self._wave_shapes(waves)

        def request_times(res: SimResult):
            return _per_request_times(waves, wave_shapes, shapes, arrivals,
                                      _wave_times_ms(tr, res))

        detail = {
            "scenario": self.name, "prefill_npus": par_pre.n_npus,
            "decode_npus": par_dec.n_npus, "decode_tp": par_dec.tp,
            "decode_replicas": par_dec.dp,
            "decode_batch": int(ctx.config["decode_batch"]),
            "batch_window_ms": float(ctx.config["batch_window_ms"]),
            "max_inflight": int(ctx.config["max_inflight"]),
            "waves": len(waves),
            "wave_sizes": [len(idxs) for idxs, _ in waves],
            "prefill_gb": fp_pre.total_gb, "decode_gb": fp_dec.total_gb,
            **({"prompt_len_mean":
                sum(p for p, _ in shapes) / len(shapes),
                "prompt_len_max": max_seq,
                "decode_len_mean":
                sum(d for _, d in shapes) / len(shapes),
                "decode_len_max": max(d for _, d in shapes)}
               if self.heterogeneous() else {}),
            **({"admission": admission, "prefill_chunks": prefill_chunks,
                "preempt": int(preempt),
                "effective_max_inflight": max_inflight,
                **({"kv_inflight_cap": kv_cap} if kv_cap is not None
                   else {})}
               if self.engine_extended() else {}),
        }
        call = SimCall(tr, ctx.sys_cfg, par_pre,
                       pools={0: pre_pool, 1: dec_pool}, record_finish=True)
        return call, request_times, detail, arrivals[-1]

    def sim_job(self, ctx: EnvContext) -> "SimJob | Evaluation":
        got = self.stream_call(ctx)
        if isinstance(got, Evaluation):
            return got
        call, request_times, detail, last_arrival_ms = got

        def fin(results: list[SimResult]) -> Evaluation:
            res = results[0]
            ttfts, tpots, lats = request_times(res)
            horizon_ms = max(res.latency_ms, last_arrival_ms)
            m = stream_metrics(ttfts, tpots, lats,
                               ttft_slo_ms=self.ttft_slo_ms,
                               tpot_slo_ms=self.tpot_slo_ms,
                               horizon_ms=horizon_ms)
            r = stream_reward(ctx.objective, m, ctx.sys_cfg.network)
            return Evaluation(r, m.latency_p99_ms, True, {
                **detail, "makespan_ms": res.latency_ms, **m.detail(),
            })

        return SimJob((call,), fin)

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        return run_sim_job(self.sim_job(ctx), ctx.backend)


# ---------------------------------------------------------------------------
# MultiTenantScenario — N workloads on disjoint heterogeneous partitions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Tenant:
    """One workload sharing the cluster: an architecture, its batch/seq, a
    latency SLO, and an importance weight.  ``device_name`` installs a
    different compute device in this tenant's partition (heterogeneous
    clusters); empty inherits the env device."""
    name: str
    arch: ArchSpec
    batch: int
    seq: int
    phase: str = "train"           # train | serve
    slo_ms: float = 1e4
    weight: float = 1.0
    decode_tokens: int = 64
    device_name: str = ""


def _auto_parallelism(spec: ArchSpec, n: int, batch: int, phase: str,
                      seq: int, capacity_gb: float) -> Parallelism | None:
    """Deterministic per-tenant parallelization: the least tensor sharding
    (fewest collectives) whose footprint fits the capacity gate."""
    mode = "train" if phase == "train" else "inference"
    tp = 1
    while tp <= n:
        if n % tp == 0:
            dp = n // tp
            par = Parallelism(n, dp=dp, sp=1, pp=1,
                              weight_sharded=(phase == "train" and dp > 1))
            if dp <= max(batch, 1) and \
                    footprint(spec, par, batch=batch, seq=seq,
                              mode=mode).total_gb <= capacity_gb:
                return par
        tp *= 2
    return None


@dataclass(frozen=True)
class MultiTenantScenario:
    """N tenants on disjoint partitions of one fabric.  The partition sizes
    are searchable (``tenant_npus``, one slot per tenant, summing to at most
    the cluster); each partition runs its tenant's workload on its own
    sub-network and device.  Reward is importance-weighted SLO attainment;
    oversubscribed or infeasible partitions gate to reward 0.  NOTE: the
    SLO objective is intrinsic to the scenario — ``ctx.objective`` is not
    consulted (per-tenant latencies and weighted goodput are in ``detail``
    for callers wanting other aggregations)."""
    tenants: tuple[Tenant, ...]
    size_choices: tuple = (32, 64, 128, 256, 512, 1024)
    name: str = "multi-tenant"

    def psa_params(self) -> list[Parameter]:
        return [Parameter("tenant_npus", "scenario", self.size_choices,
                          ndim=len(self.tenants),
                          doc="NPUs owned by each tenant's partition")]

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return [Constraint("sum_le", ("tenant_npus",), n_npus,
                           name=f"sum(tenant_npus) <= {n_npus}")]

    def _cluster(self, ctx: EnvContext, sizes: tuple[int, ...]) -> Cluster:
        devices = [DEVICES[t.device_name] if t.device_name else ctx.device
                   for t in self.tenants]
        return partition_cluster(ctx.network, sizes, devices,
                                 names=[t.name for t in self.tenants])

    def _sizes(self, ctx: EnvContext) -> tuple[int, ...]:
        v = ctx.config["tenant_npus"]
        return tuple(int(x) for x in (v if isinstance(v, (tuple, list)) else (v,)))

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        out: dict[str, Trace] = {}
        for t, size in zip(self.tenants, self._sizes(ctx)):
            par = _auto_parallelism(t.arch, size, t.batch, t.phase, t.seq,
                                    ctx.capacity_gb)
            if par is not None:
                out[t.name] = generate_trace(
                    t.arch, par, batch=t.batch, seq=t.seq,
                    mode="train" if t.phase == "train" else "prefill")
        return out

    def _tenant_calls(self, ctx: EnvContext, t: Tenant, network: Network,
                      device: Device, par: Parallelism) -> list[SimCall]:
        """One tenant's simulator calls on its partition's sub-fabric —
        prefill + decode for serving tenants, one training step otherwise
        (``_tenant_latency`` is the matching results combiner)."""
        sys_cfg = replace(ctx.sys_cfg, network=network, device=device)
        if t.phase == "serve":
            return [SimCall(generate_trace(t.arch, par, batch=t.batch,
                                           seq=t.seq, mode="prefill"),
                            sys_cfg, par),
                    SimCall(generate_trace(t.arch, par, batch=t.batch,
                                           seq=t.seq, mode="decode"),
                            sys_cfg, par)]
        return [SimCall(generate_trace(t.arch, par, batch=t.batch, seq=t.seq,
                                       mode="train"), sys_cfg, par)]

    @staticmethod
    def _tenant_latency(t: Tenant, results: list[SimResult]) -> float:
        if t.phase == "serve":
            pre, dec = results
            return pre.latency_ms + t.decode_tokens * dec.latency_ms
        return results[0].latency_ms

    def sim_job(self, ctx: EnvContext) -> "SimJob | Evaluation":
        sizes = self._sizes(ctx)
        if len(sizes) != len(self.tenants):
            return _invalid(f"need {len(self.tenants)} partition sizes, "
                            f"got {len(sizes)}")
        if sum(sizes) > ctx.n_npus:
            return _invalid(f"partitions {list(sizes)} oversubscribe "
                            f"{ctx.n_npus}-NPU cluster")
        cluster = self._cluster(ctx, sizes)
        calls: list[SimCall] = []
        slices: list[tuple[Tenant, Any, Parallelism, int, int]] = []
        for t, part in zip(self.tenants, cluster.partitions):
            par = _auto_parallelism(t.arch, part.n_npus, t.batch, t.phase,
                                    t.seq, ctx.capacity_gb)
            if par is None:
                return _invalid(f"tenant {t.name!r} infeasible on "
                                f"{part.n_npus} NPUs")
            tcalls = self._tenant_calls(ctx, t, part.network, part.device,
                                        par)
            slices.append((t, part, par, len(calls), len(tcalls)))
            calls.extend(tcalls)

        def fin(results: list[SimResult]) -> Evaluation:
            per_tenant: dict[str, dict[str, float]] = {}
            attained, weight_sum, goodput = 0.0, 0.0, 0.0
            worst = 0.0
            for t, part, par, off, n in slices:
                lat = self._tenant_latency(t, results[off:off + n])
                att = slo_attainment(lat, t.slo_ms)
                tput = t.batch * t.seq / max(lat, 1e-9)  # tokens/ms
                attained += t.weight * att
                goodput += t.weight * tput * (1.0 if lat <= t.slo_ms else 0.0)
                weight_sum += t.weight
                worst = max(worst, lat)
                per_tenant[t.name] = {
                    "npus": part.n_npus, "range": part.npu_range(),
                    "latency_ms": lat, "slo_ms": t.slo_ms, "attainment": att,
                    "tp": par.tp, "dp": par.dp,
                }
            reward = attained / max(weight_sum, 1e-9)
            return Evaluation(reward, worst, True, {
                "scenario": self.name, "tenants": per_tenant,
                "weighted_goodput_tok_per_ms": goodput,
                "cluster": cluster.describe(),
            })

        return SimJob(tuple(calls), fin)

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        return run_sim_job(self.sim_job(ctx), ctx.backend)


# ---------------------------------------------------------------------------
# Scenario registry — construct-from-dict front door for StudySpec / CLI
# ---------------------------------------------------------------------------

SCENARIO_REGISTRY: dict[str, Callable[..., Scenario]] = {}


def register_scenario(kind: str, builder: Callable[..., Scenario], *,
                      replace_existing: bool = False) -> None:
    """Register a scenario kind.  ``builder(**params)`` must return a
    ``Scenario``; params arrive JSON-shaped (lists, dicts, scalars)."""
    if not replace_existing and kind in SCENARIO_REGISTRY:
        raise ValueError(f"scenario kind {kind!r} already registered")
    SCENARIO_REGISTRY[kind] = builder


def build_scenario(kind: str, params: Mapping[str, Any] | None = None) -> Scenario:
    """Instantiate a registered scenario kind from JSON-shaped params."""
    try:
        builder = SCENARIO_REGISTRY[kind]
    except KeyError:
        raise ValueError(f"unknown scenario kind {kind!r}; "
                         f"known: {sorted(SCENARIO_REGISTRY)}") from None
    return builder(**dict(params or {}))


def list_scenarios() -> dict[str, str]:
    """kind -> one-line description (the builder's scenario docstring)."""
    out = {}
    for kind, builder in SCENARIO_REGISTRY.items():
        cls = getattr(builder, "scenario_cls", None)
        doc = (cls.__doc__ or builder.__doc__ or "").strip().splitlines()
        out[kind] = doc[0] if doc else ""
    return out


def _tuplify(v: Any) -> Any:
    """JSON arrays -> tuples, recursively (scenario dataclasses use tuples
    for every sequence field so instances stay frozen/hashable)."""
    if isinstance(v, (list, tuple)):
        return tuple(_tuplify(x) for x in v)
    return v


def dataclass_scenario_builder(cls) -> Callable[..., Scenario]:
    """A construct-from-dict builder for a scenario dataclass: validates
    parameter names and coerces JSON arrays to the tuples the frozen
    dataclasses expect."""
    names = {f.name for f in dataclasses.fields(cls)}

    def build(**params) -> Scenario:
        unknown = sorted(set(params) - names)
        if unknown:
            raise ValueError(
                f"unknown {cls.__name__} scenario params {unknown}; "
                f"known: {sorted(names - {'name'})}")
        return cls(**{k: _tuplify(v) for k, v in params.items()})

    build.scenario_cls = cls
    return build


_multi_tenant_fields = dataclass_scenario_builder(MultiTenantScenario)


def _build_multi_tenant(**params) -> MultiTenantScenario:
    """Multi-tenant builder: resolves ``tenants`` entries given as dicts
    whose ``arch`` is an ``ARCHS`` registry name (the JSON form), then
    delegates validation/coercion to the generic dataclass builder."""
    from repro.configs import ARCHS

    tenants = []
    for i, t in enumerate(params.pop("tenants", ()) or ()):
        if isinstance(t, Tenant):
            tenants.append(t)
            continue
        t = dict(t)
        if "arch" not in t:
            raise ValueError(f"tenant {i} ({t.get('name', '?')!r}) is "
                             f"missing 'arch' — an ARCHS registry name")
        arch = t.pop("arch")
        if isinstance(arch, str) and arch not in ARCHS:
            raise ValueError(f"tenant {i} ({t.get('name', '?')!r}) names "
                             f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
        known = {f.name for f in dataclasses.fields(Tenant)}
        unknown = sorted(set(t) - known)
        if unknown:
            raise ValueError(
                f"tenant {i} ({t.get('name', '?')!r}) has unknown "
                f"key(s) {unknown}; known: {sorted(known)}")
        tenants.append(Tenant(arch=ARCHS[arch] if isinstance(arch, str)
                              else arch, **t))
    return _multi_tenant_fields(tenants=tuple(tenants), **params)


_build_multi_tenant.scenario_cls = MultiTenantScenario

register_scenario("train", dataclass_scenario_builder(TrainScenario))
register_scenario("disagg-serve",
                  dataclass_scenario_builder(DisaggServeScenario))
register_scenario("request-stream",
                  dataclass_scenario_builder(RequestStreamScenario))
register_scenario("multi-tenant", _build_multi_tenant)

# the fleet subsystem (repro.core.fleet) registers its scenario on import;
# importing it here — after every name it needs is defined — makes the
# "fleet" kind resolvable wherever the scenario registry is
from repro.core import fleet as _fleet  # noqa: E402,F401  (cycle-closing)
