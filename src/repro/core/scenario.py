"""The scenario layer: what workload shape is the cluster being designed for?

COSMIC's co-design loop is scenario-agnostic — the paper evaluates training,
serving, and mixed clusters with the same PsA/agent machinery.  A
``Scenario`` packages everything workload-shape-specific behind three
methods:

  * ``psa_params()`` / ``psa_constraints(n_npus)`` — the searchable knobs
    this scenario contributes to the PsA (stack ``"scenario"``), searched by
    agents alongside the workload/collective/network stacks;
  * ``traces(ctx)`` — the symbolic phase traces behind one design point
    (inspection/debug);
  * ``evaluate(ctx)`` — design point -> ``Evaluation`` (reward, latency,
    validity gate), where ``ctx`` is the env-resolved ``EnvContext``.

Three built-ins:

  ``TrainScenario``        one homogeneous training (or monolithic-serving)
                           job — bit-identical to the pre-scenario engine.
  ``DisaggServeScenario``  disaggregated serving: separate prefill and
                           decode NPU pools sized by a searchable
                           ``prefill_frac``, a KV-cache transfer collective
                           between pools, and decode continuous batching
                           with a searchable ``decode_batch``.
  ``MultiTenantScenario``  N workloads on disjoint (possibly heterogeneous)
                           cluster partitions whose sizes are searchable;
                           reward is weighted SLO attainment.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Mapping, Protocol, runtime_checkable

from repro.configs.base import ArchSpec
from repro.core.compute import DEVICES, Device
from repro.core.memory import footprint, kv_cache_bytes
from repro.core.psa import Constraint, Parameter, ParameterSet
from repro.core.rewards import REWARDS, Evaluation, evaluate, slo_attainment
from repro.core.simulator import SystemConfig, simulate
from repro.core.topology import (Cluster, Network, partition_cluster,
                                 sub_network)
from repro.core.workload import (Parallelism, Trace, compose_phases,
                                 generate_trace)


@dataclass(frozen=True)
class EnvContext:
    """Everything the env resolves before handing a design point to its
    scenario: the fixed system description plus the per-point config and the
    network/system stacks built from it."""
    spec: ArchSpec
    n_npus: int
    device: Device
    objective: str
    capacity_gb: float
    config: Mapping[str, Any]
    network: Network
    sys_cfg: SystemConfig

    def parallelism(self, n_npus: int | None = None) -> Parallelism:
        """The config's workload-stack knobs resolved against a pool size."""
        c = self.config
        return Parallelism(n_npus if n_npus is not None else self.n_npus,
                           c["dp"], c["sp"], c["pp"],
                           bool(c["weight_sharded"]))


@runtime_checkable
class Scenario(Protocol):
    """Structural protocol — any frozen, picklable object with these methods
    can drive ``CosmicEnv`` (process-pool workers receive a copy)."""

    name: str

    def psa_params(self) -> list[Parameter]: ...
    def psa_constraints(self, n_npus: int) -> list[Constraint]: ...
    def traces(self, ctx: EnvContext) -> dict[str, Trace]: ...
    def evaluate(self, ctx: EnvContext) -> Evaluation: ...


def scenario_psa(base: ParameterSet, scenario: Scenario,
                 n_npus: int) -> ParameterSet:
    """The base PsA extended with the scenario's searchable knobs — the
    'scenario' stack of the design space."""
    params = scenario.psa_params()
    if not params:
        return base
    return base.extend(params, scenario.psa_constraints(n_npus),
                       name=f"{base.name}+{scenario.name}")


def _invalid(why: str) -> Evaluation:
    return Evaluation(0.0, float("inf"), False, {"why": why})


# ---------------------------------------------------------------------------
# TrainScenario — the pre-scenario engine, verbatim
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainScenario:
    """One homogeneous job on the whole cluster: the engine's original
    behavior (``mode="train"`` training step latency, or ``mode="serve"``
    monolithic prefill+decode serving), reward-identical to the
    pre-scenario code path."""
    batch: int
    seq: int
    mode: str = "train"            # train | serve | inference
    decode_tokens: int = 64
    name: str = "train"

    def psa_params(self) -> list[Parameter]:
        return []

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return []

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        par = ctx.parallelism()
        if self.mode == "serve":
            return {"prefill": generate_trace(ctx.spec, par, batch=self.batch,
                                              seq=self.seq, mode="prefill"),
                    "decode": generate_trace(ctx.spec, par, batch=self.batch,
                                             seq=self.seq, mode="decode")}
        return {self.mode: generate_trace(ctx.spec, par, batch=self.batch,
                                          seq=self.seq, mode=self.mode)}

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        return evaluate(ctx.spec, ctx.parallelism(), ctx.sys_cfg,
                        batch=self.batch, seq=self.seq, mode=self.mode,
                        objective=ctx.objective, capacity_gb=ctx.capacity_gb,
                        decode_tokens=self.decode_tokens)


# ---------------------------------------------------------------------------
# DisaggServeScenario — prefill/decode disaggregation
# ---------------------------------------------------------------------------

def _compose_memo(pre: Trace, dec: Trace, xfer_bytes: float,
                  meta: dict[str, Any]) -> Trace:
    """compose_phases memoized by input-trace identity: phase traces are
    interned by the trace cache, so repeated design points sharing them get
    the same composed trace (and its piggybacked ``_SimPlan``) back.  The
    memo rides on the prefill trace, dying with it when caches are off."""
    memo = getattr(pre, "_composed", None)
    if memo is None:
        memo = pre._composed = {}
    # entries hold a strong ref to their decode trace, so a live key's id
    # can't be recycled by a different (evicted-and-rebuilt) trace
    key = (id(dec), xfer_bytes)
    entry = memo.get(key)
    if entry is None or entry[0] is not dec:
        tr = compose_phases([(pre, 0), (dec, 1)],
                            transfers=[xfer_bytes], meta=meta)
        memo[key] = entry = (dec, tr)
    return entry[1]


@dataclass(frozen=True)
class DisaggServeScenario:
    """Disaggregated serving: ``prefill_frac`` of the cluster prefills
    prompts, the rest decodes, and finished prompts hand their KV caches
    across a transfer collective bridging the pools.

    The prefill pool is parallelized by the config's workload knobs; the
    decode pool is carved into ``ceil(batch / decode_batch)`` continuous-
    batching replicas, each tensor-parallel over its share of the pool —
    so the search can give prefill its MXU-efficient moderate TP while
    decode shards weight/KV reads as widely as the pool allows.

    ``prefill_frac = 1.0`` degenerates to the monolithic serve path
    (``TrainScenario(mode="serve")``): one pool, one parallelization for
    both phases, no transfer.
    """
    batch: int
    seq: int
    decode_tokens: int = 64
    prefill_fracs: tuple = (0.25, 0.5, 0.625, 0.75, 0.875, 1.0)
    decode_batches: tuple = (4, 8, 16, 32, 64, 128)
    name: str = "disagg-serve"

    def psa_params(self) -> list[Parameter]:
        return [
            Parameter("prefill_frac", "scenario", self.prefill_fracs,
                      doc="fraction of the cluster in the prefill pool"),
            Parameter("decode_batch", "scenario", self.decode_batches,
                      doc="requests continuously batched per decode replica"),
        ]

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return []

    def canonical(self, config: Mapping[str, Any]) -> Mapping[str, Any]:
        """Memo-key canonicalization: at ``prefill_frac >= 1.0`` the decode
        pool doesn't exist and ``decode_batch`` is ignored, so all its
        values are one design point — don't re-evaluate them."""
        if float(config.get("prefill_frac", 0.0)) >= 1.0 \
                and "decode_batch" in config:
            return dict(config, decode_batch=self.decode_batches[0])
        return config

    # -- pool sizing -------------------------------------------------------
    def _pools(self, ctx: EnvContext) -> tuple[int, int]:
        frac = float(ctx.config["prefill_frac"])
        n_pre = int(round(frac * ctx.n_npus))
        return n_pre, ctx.n_npus - n_pre

    def _decode_par(self, n_dec: int, decode_batch: int) -> tuple[Parallelism, int, int]:
        """(decode-pool parallelism, waves, resident requests): ``replicas``
        continuous-batching groups of up to ``decode_batch`` requests, each
        TP over its pool share."""
        replicas = min(n_dec, max(1, math.ceil(self.batch / decode_batch)))
        tp = n_dec // replicas
        par = Parallelism(replicas * tp, dp=replicas, sp=1, pp=1)
        waves = math.ceil(self.batch / (replicas * decode_batch))
        # no more requests can be in flight than exist
        resident = min(decode_batch * replicas, self.batch)
        return par, waves, resident

    def _phase_traces(self, ctx: EnvContext, par_pre: Parallelism,
                      par_dec: Parallelism, resident: int) -> tuple[Trace, Trace, Trace]:
        pre = generate_trace(ctx.spec, par_pre, batch=self.batch,
                             seq=self.seq, mode="prefill")
        dec = generate_trace(ctx.spec, par_dec, batch=resident,
                             seq=self.seq, mode="decode")
        # prefill -> KV transfer -> first decode step, on separate pools
        combined = _compose_memo(
            pre, dec, self._xfer_bytes(ctx, par_pre.n_npus, par_dec.n_npus),
            meta=dict(arch=ctx.spec.name, scenario=self.name))
        return pre, dec, combined

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        if float(ctx.config["prefill_frac"]) >= 1.0:
            return TrainScenario(self.batch, self.seq, "serve",
                                 self.decode_tokens).traces(ctx)
        n_pre, n_dec = self._pools(ctx)
        if n_pre < 1 or n_dec < 1:
            raise ValueError(f"degenerate pool split {n_pre}/{n_dec} for "
                             f"prefill_frac={ctx.config['prefill_frac']} on "
                             f"{ctx.n_npus} NPUs")
        par_dec, _, resident = self._decode_par(n_dec,
                                                int(ctx.config["decode_batch"]))
        pre, dec, combined = self._phase_traces(ctx, ctx.parallelism(n_pre),
                                                par_dec, resident)
        return {"prefill": pre, "decode": dec, "combined": combined}

    def _xfer_bytes(self, ctx: EnvContext, n_pre: int, n_dec: int) -> float:
        """KV handoff per transfer lane: the whole batch's caches move, with
        one concurrent lane per (prefill, decode) NPU pair."""
        total = kv_cache_bytes(ctx.spec, batch=self.batch, seq=self.seq)
        return total / max(1, min(n_pre, n_dec))

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        frac = float(ctx.config["prefill_frac"])
        if frac >= 1.0:
            # degenerate: one pool serves both phases (the monolithic path)
            ev = TrainScenario(self.batch, self.seq, "serve",
                               self.decode_tokens).evaluate(ctx)
            if ev.valid:
                ev = replace(ev, detail=dict(ev.detail, scenario=self.name,
                                             monolithic=True))
            return ev
        decode_batch = int(ctx.config["decode_batch"])
        n_pre, n_dec = self._pools(ctx)
        if n_pre < 1 or n_dec < 1:
            return _invalid(f"degenerate pool split {n_pre}/{n_dec}")
        par_pre = ctx.parallelism(n_pre)
        if not par_pre.valid():
            return _invalid(f"prefill parallelization invalid on {n_pre} NPUs")
        fp_pre = footprint(ctx.spec, par_pre, batch=self.batch, seq=self.seq,
                           mode="inference")
        if fp_pre.total_gb > ctx.capacity_gb:
            return _invalid(f"prefill memory {fp_pre.total_gb:.1f}GB "
                            f"> {ctx.capacity_gb}GB")
        par_dec, waves, resident = self._decode_par(n_dec, decode_batch)
        fp_dec = footprint(ctx.spec, par_dec, batch=resident, seq=self.seq,
                           mode="decode")
        if fp_dec.total_gb > ctx.capacity_gb:
            return _invalid(f"decode memory {fp_dec.total_gb:.1f}GB "
                            f"> {ctx.capacity_gb}GB")

        _, dec_tr, combined = self._phase_traces(ctx, par_pre, par_dec,
                                                 resident)
        # each pool's collectives are priced on the sub-fabric its NPU
        # slice spans, not the whole cluster (same carving rule as
        # MultiTenantScenario partitions)
        pre_pool = (par_pre, sub_network(ctx.network, par_pre.n_npus))
        dec_pool = (par_dec, sub_network(ctx.network, par_dec.n_npus))
        first = simulate(combined, ctx.sys_cfg, par_pre,
                         pools={0: pre_pool, 1: dec_pool})
        step = simulate(dec_tr, ctx.sys_cfg, par_dec,
                        pools={0: dec_pool})
        t_token_ms = step.latency_ms
        latency_ms = first.latency_ms \
            + (self.decode_tokens * waves - 1) * t_token_ms
        r = REWARDS[ctx.objective](latency_ms, ctx.sys_cfg.network)
        return Evaluation(r, latency_ms, True, {
            "scenario": self.name, "prefill_npus": n_pre,
            "decode_npus": par_dec.n_npus, "decode_tp": par_dec.tp,
            "decode_replicas": par_dec.dp, "decode_batch": decode_batch,
            "waves": waves, "ttft_ms": first.latency_ms - t_token_ms,
            "p50_token_latency_ms": t_token_ms,
            "prefill_gb": fp_pre.total_gb, "decode_gb": fp_dec.total_gb,
        })


# ---------------------------------------------------------------------------
# MultiTenantScenario — N workloads on disjoint heterogeneous partitions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Tenant:
    """One workload sharing the cluster: an architecture, its batch/seq, a
    latency SLO, and an importance weight.  ``device_name`` installs a
    different compute device in this tenant's partition (heterogeneous
    clusters); empty inherits the env device."""
    name: str
    arch: ArchSpec
    batch: int
    seq: int
    phase: str = "train"           # train | serve
    slo_ms: float = 1e4
    weight: float = 1.0
    decode_tokens: int = 64
    device_name: str = ""


def _auto_parallelism(spec: ArchSpec, n: int, batch: int, phase: str,
                      seq: int, capacity_gb: float) -> Parallelism | None:
    """Deterministic per-tenant parallelization: the least tensor sharding
    (fewest collectives) whose footprint fits the capacity gate."""
    mode = "train" if phase == "train" else "inference"
    tp = 1
    while tp <= n:
        if n % tp == 0:
            dp = n // tp
            par = Parallelism(n, dp=dp, sp=1, pp=1,
                              weight_sharded=(phase == "train" and dp > 1))
            if dp <= max(batch, 1) and \
                    footprint(spec, par, batch=batch, seq=seq,
                              mode=mode).total_gb <= capacity_gb:
                return par
        tp *= 2
    return None


@dataclass(frozen=True)
class MultiTenantScenario:
    """N tenants on disjoint partitions of one fabric.  The partition sizes
    are searchable (``tenant_npus``, one slot per tenant, summing to at most
    the cluster); each partition runs its tenant's workload on its own
    sub-network and device.  Reward is importance-weighted SLO attainment;
    oversubscribed or infeasible partitions gate to reward 0.  NOTE: the
    SLO objective is intrinsic to the scenario — ``ctx.objective`` is not
    consulted (per-tenant latencies and weighted goodput are in ``detail``
    for callers wanting other aggregations)."""
    tenants: tuple[Tenant, ...]
    size_choices: tuple = (32, 64, 128, 256, 512, 1024)
    name: str = "multi-tenant"

    def psa_params(self) -> list[Parameter]:
        return [Parameter("tenant_npus", "scenario", self.size_choices,
                          ndim=len(self.tenants),
                          doc="NPUs owned by each tenant's partition")]

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        return [Constraint("sum_le", ("tenant_npus",), n_npus,
                           name=f"sum(tenant_npus) <= {n_npus}")]

    def _cluster(self, ctx: EnvContext, sizes: tuple[int, ...]) -> Cluster:
        devices = [DEVICES[t.device_name] if t.device_name else ctx.device
                   for t in self.tenants]
        return partition_cluster(ctx.network, sizes, devices,
                                 names=[t.name for t in self.tenants])

    def _sizes(self, ctx: EnvContext) -> tuple[int, ...]:
        v = ctx.config["tenant_npus"]
        return tuple(int(x) for x in (v if isinstance(v, (tuple, list)) else (v,)))

    def traces(self, ctx: EnvContext) -> dict[str, Trace]:
        out: dict[str, Trace] = {}
        for t, size in zip(self.tenants, self._sizes(ctx)):
            par = _auto_parallelism(t.arch, size, t.batch, t.phase, t.seq,
                                    ctx.capacity_gb)
            if par is not None:
                out[t.name] = generate_trace(
                    t.arch, par, batch=t.batch, seq=t.seq,
                    mode="train" if t.phase == "train" else "prefill")
        return out

    def _tenant_latency_ms(self, ctx: EnvContext, t: Tenant,
                           network: Network, device: Device,
                           par: Parallelism) -> float:
        sys_cfg = replace(ctx.sys_cfg, network=network, device=device)
        if t.phase == "serve":
            pre = simulate(generate_trace(t.arch, par, batch=t.batch,
                                          seq=t.seq, mode="prefill"),
                           sys_cfg, par)
            dec = simulate(generate_trace(t.arch, par, batch=t.batch,
                                          seq=t.seq, mode="decode"),
                           sys_cfg, par)
            return pre.latency_ms + t.decode_tokens * dec.latency_ms
        tr = generate_trace(t.arch, par, batch=t.batch, seq=t.seq, mode="train")
        return simulate(tr, sys_cfg, par).latency_ms

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        sizes = self._sizes(ctx)
        if len(sizes) != len(self.tenants):
            return _invalid(f"need {len(self.tenants)} partition sizes, "
                            f"got {len(sizes)}")
        if sum(sizes) > ctx.n_npus:
            return _invalid(f"partitions {list(sizes)} oversubscribe "
                            f"{ctx.n_npus}-NPU cluster")
        cluster = self._cluster(ctx, sizes)
        per_tenant: dict[str, dict[str, float]] = {}
        attained, weight_sum, goodput = 0.0, 0.0, 0.0
        worst = 0.0
        for t, part in zip(self.tenants, cluster.partitions):
            par = _auto_parallelism(t.arch, part.n_npus, t.batch, t.phase,
                                    t.seq, ctx.capacity_gb)
            if par is None:
                return _invalid(f"tenant {t.name!r} infeasible on "
                                f"{part.n_npus} NPUs")
            lat = self._tenant_latency_ms(ctx, t, part.network, part.device, par)
            att = slo_attainment(lat, t.slo_ms)
            tput = t.batch * t.seq / max(lat, 1e-9)  # tokens/ms
            attained += t.weight * att
            goodput += t.weight * tput * (1.0 if lat <= t.slo_ms else 0.0)
            weight_sum += t.weight
            worst = max(worst, lat)
            per_tenant[t.name] = {
                "npus": part.n_npus, "range": part.npu_range(),
                "latency_ms": lat, "slo_ms": t.slo_ms, "attainment": att,
                "tp": par.tp, "dp": par.dp,
            }
        reward = attained / max(weight_sum, 1e-9)
        return Evaluation(reward, worst, True, {
            "scenario": self.name, "tenants": per_tenant,
            "weighted_goodput_tok_per_ms": goodput,
            "cluster": cluster.describe(),
        })
