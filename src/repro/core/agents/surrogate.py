"""Surrogate-guided screening agent (CubicML-style).

Every other agent pays one true simulation per design point it looks at.
This one decouples *looking* from *paying*: each generation it draws a
large raw candidate pool from the ``DesignSpace`` (vectorized — 10^4-10^5
decodes cost milliseconds, see ``DesignSpace.raw_decode_batch``), scores
the pool through a cheap learned predictor of the reward surface
(``repro.core.surrogate``), and sends only the top-scoring slice to
``CosmicEnv.step_batch`` for true simulation.  The predictor refits online
as observations arrive, and ``warm_start()`` seeds it from a persistent
eval store's corpus before the first step — so a campaign that already
burned 10^3 simulations hands the next one a trained model for free.

Screening score is UCB-style: ``predicted_mean + explore * predicted_std``
— the uncertainty term keeps the agent from strip-mining one basin the
early model happens to like.  A small ``random_frac`` of every batch
bypasses the model entirely (insurance against a confidently-wrong
surrogate), and a mutant cloud around the elite observed configs keeps the
pool dense near the incumbent basin (raw uniform decodes alone almost
never land next to a good point in a 10^9-point space).

Fully deterministic under a fixed seed: one ``numpy`` Generator drives
pool draws, mutants, and random slots in a fixed order, and each refit
rebuilds the predictor from the same seed — so resuming a study re-runs a
cell bit-identically.
"""
from __future__ import annotations

from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.agents.base import Agent
from repro.core.surrogate import Featurizer, make_surrogate


def _key(config: dict[str, Any]) -> tuple:
    return tuple(sorted(config.items()))


class SurrogateScreeningAgent(Agent):
    name = "surrogate"

    def __init__(self, space, seed: int = 0, model: str = "knn",
                 pool: int = 8192, explore: float = 0.1, warmup: int = 32,
                 elite: int = 4, p_mut: float = 0.15,
                 random_frac: float = 0.0625, max_fit: int = 2048):
        super().__init__(space, seed)
        self.model_name = model
        self.pool = int(pool)
        self.explore = float(explore)
        self.warmup = int(warmup)
        self.elite = int(elite)
        self.p_mut = float(p_mut)
        self.random_frac = float(random_frac)
        self.max_fit = int(max_fit)
        self._model_seed = seed
        self.featurizer = Featurizer(space)
        # training corpus: configs, cached feature rows, rewards
        self._cfgs: list[dict[str, Any]] = []
        self._X: list[np.ndarray] = []
        self._y: list[float] = []
        self._seen: set[tuple] = set()
        self._model: Any = None
        self._dirty = True
        self.warm_start_points = 0

    # -- corpus ------------------------------------------------------------
    def _add(self, config: dict[str, Any], reward: float) -> None:
        self._cfgs.append(config)
        self._X.append(self.featurizer.featurize(config))
        self._y.append(float(reward))
        self._seen.add(_key(config))
        self._dirty = True

    def warm_start(self,
                   records: Iterable[tuple[dict[str, Any], float]]) -> int:
        """Seed the corpus from (config, reward) records of a prior
        campaign (same ``eval_signature()`` => same design space, so a
        record that doesn't featurize raises the Featurizer's loud
        mismatch error).  Warm points train the model and count as seen —
        the search budget goes to new designs — but never claim
        ``best_config``: that must be earned by a simulation this search
        actually ran."""
        n0 = len(self._cfgs)
        for cfg, reward in records:
            cfg = dict(cfg)
            if _key(cfg) in self._seen:
                continue
            self._add(cfg, reward)
        self.warm_start_points += len(self._cfgs) - n0
        return len(self._cfgs) - n0

    def _refit(self) -> None:
        if not self._dirty and self._model is not None:
            return
        X = np.asarray(self._X[-self.max_fit:])
        y = np.asarray(self._y[-self.max_fit:])
        self._model = make_surrogate(self.model_name, seed=self._model_seed)
        self._model.fit(X, y)
        self._dirty = False

    # -- proposals ---------------------------------------------------------
    def propose(self) -> dict[str, Any]:
        return self.propose_batch(1)[0]

    def propose_batch(self, n: int) -> list[dict[str, Any]]:
        if len(self._cfgs) < self.warmup:
            # not enough corpus to trust a fit — spend the round on
            # uniform coverage (this also feeds the first fit a spread-out
            # design, not a cluster)
            return self.space.sample_batch(n, self.rng)
        self._refit()
        # candidate pool: vectorized raw decodes, validity-masked ...
        raw = self.space.raw_decode_batch(self.pool, self.rng)
        cand = raw[self.space.valid_mask(raw)]
        # ... plus a mutant cloud around the elite observed configs
        order = np.argsort(-np.asarray(self._y), kind="stable")
        elites = [self._cfgs[i] for i in order[:max(self.elite, 1)]]
        mutants = np.empty((4 * n, raw.shape[1]), dtype=np.int64)
        for i in range(4 * n):
            m = self.space.mutate(elites[i % len(elites)], self.rng,
                                  self.p_mut)
            mutants[i] = self.space.encode(m)
        cand = np.concatenate([cand, mutants]) if len(cand) else mutants
        # screen: UCB score over the whole pool through the predictor
        mu, sd = self._model.predict(self.featurizer.featurize_vecs(cand))
        score = mu + self.explore * sd
        rank = np.argsort(-score, kind="stable")
        n_rand = min(n, int(round(self.random_frac * n)))
        picked: list[dict[str, Any]] = []
        pk: set[tuple] = set()
        for lo in range(0, len(rank), max(4 * n, 64)):
            for cfg in self.space.decode_batch(
                    cand[rank[lo:lo + max(4 * n, 64)]]):
                k = _key(cfg)
                if k in self._seen or k in pk:
                    continue
                picked.append(cfg)
                pk.add(k)
                if len(picked) >= n - n_rand:
                    break
            if len(picked) >= n - n_rand:
                break
        # random slots: insurance against a confidently-wrong model (and
        # the fill when the screened pool dedupes dry)
        while len(picked) < n:
            picked.append(self.space.sample(self.rng))
        return picked

    def observe(self, config: dict[str, Any], reward: float) -> None:
        super().observe(config, reward)
        self._add(config, reward)

    def observe_batch(self, configs: Sequence[dict[str, Any]],
                      rewards: Sequence[float]) -> None:
        for config, reward in zip(configs, rewards):
            self.observe(config, reward)
