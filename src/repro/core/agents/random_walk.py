"""Random Walker (Pearson, 1905): history-free uniform exploration — the
paper's baseline agent."""
from __future__ import annotations

from typing import Any

from repro.core.agents.base import Agent


class RandomWalker(Agent):
    name = "rw"

    def __init__(self, space, seed: int = 0, population: int = 1):
        super().__init__(space, seed)
        self.population = population  # paper knob: number of walkers (batch)

    def propose(self) -> dict[str, Any]:
        return self.space.sample(self.rng)

    # The inherited propose_batch(n) is n independent walkers; proposals are
    # history-free, so batched and sequential searches coincide at every
    # step for any batch size.
