"""Bayesian optimization: numpy Gaussian-process surrogate (RBF kernel) +
expected-improvement acquisition over a sampled candidate pool.  The paper
randomizes the surrogate's seed; we expose it plus the usual GP knobs."""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.core.agents.base import Agent


def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / (ls * ls))


class BayesianOptimizer(Agent):
    name = "bo"

    def __init__(self, space, seed: int = 0, n_init: int = 16,
                 candidates: int = 128, lengthscale: float = 0.35,
                 noise: float = 1e-4, max_fit: int = 256):
        super().__init__(space, seed)
        self.n_init = n_init
        self.cands = candidates
        self.ls = lengthscale
        self.noise = noise
        self.max_fit = max_fit
        self.X: list[np.ndarray] = []
        self.y: list[float] = []

    def propose(self) -> dict[str, Any]:
        return self._propose_q(1)[0]

    # -- population API: q-batch expected improvement -----------------------
    # One GP fit amortizes over the whole batch (the cubic Cholesky is BO's
    # bottleneck); the top-q pool candidates by EI form the batch.
    def propose_batch(self, n: int) -> list[dict[str, Any]]:
        return self._propose_q(n)

    def _propose_q(self, q: int) -> list[dict[str, Any]]:
        if len(self.X) < self.n_init:
            return [self.space.sample(self.rng) for _ in range(q)]
        X = np.array(self.X[-self.max_fit:])
        y = np.array(self.y[-self.max_fit:])
        mu, sd = y.mean(), y.std() + 1e-9
        yn = (y - mu) / sd
        K = _rbf(X, X, self.ls) + self.noise * np.eye(len(X))
        try:
            L = np.linalg.cholesky(K)
        except np.linalg.LinAlgError:
            return [self.space.sample(self.rng) for _ in range(q)]
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))

        n_pool = max(self.cands, q)
        pool = [self.space.sample(self.rng) for _ in range(n_pool)]
        Z = np.array([self.space.normalize(self.space.encode(c)) for c in pool])
        Ks = _rbf(Z, X, self.ls)
        mean = Ks @ alpha
        v = np.linalg.solve(L, Ks.T)
        var = np.maximum(1.0 - (v * v).sum(0), 1e-12)
        std = np.sqrt(var)
        fbest = yn.max()
        z = (mean - fbest) / std
        ei = std * (z * _ncdf(z) + _npdf(z))
        order = np.argsort(-ei, kind="stable")[:q]
        return [pool[int(i)] for i in order]

    def observe(self, config: dict[str, Any], reward: float) -> None:
        super().observe(config, reward)
        self.X.append(self.space.normalize(self.space.encode(config)))
        self.y.append(reward)


def _ncdf(z):
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def _npdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)
