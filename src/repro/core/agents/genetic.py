"""Genetic algorithm: tournament selection + uniform crossover + mutation,
with constraint repair from the PSS (paper knobs: population size, mutation
probability)."""
from __future__ import annotations

from typing import Any

from repro.core.agents.base import Agent


class GeneticAlgorithm(Agent):
    name = "ga"

    def __init__(self, space, seed: int = 0, population: int = 32,
                 p_mut: float = 0.15, tournament: int = 3):
        super().__init__(space, seed)
        self.pop_size = population
        self.p_mut = p_mut
        self.tournament = tournament
        self.pop: list[tuple[float, dict[str, Any]]] = []

    def _select(self) -> dict[str, Any]:
        idx = self.rng.integers(len(self.pop), size=min(self.tournament, len(self.pop)))
        best = max((self.pop[i] for i in idx), key=lambda t: t[0])
        return best[1]

    def propose(self) -> dict[str, Any]:
        if len(self.pop) < self.pop_size:
            return self.space.sample(self.rng)
        a, b = self._select(), self._select()
        child = self.space.crossover(a, b, self.rng)
        return self.space.mutate(child, self.rng, self.p_mut)

    def observe(self, config: dict[str, Any], reward: float) -> None:
        super().observe(config, reward)
        self.pop.append((reward, config))
        if len(self.pop) > self.pop_size:
            self.pop.sort(key=lambda t: t[0], reverse=True)
            self.pop = self.pop[: self.pop_size]

    # The inherited population API already realizes whole-generation GA:
    # propose only reads the current population (never mid-batch rewards),
    # so propose_batch(n) breeds one generation, and the per-individual
    # trims in observe_batch keep exactly the top-pop_size survivors.
