"""Agent interface.  The PSS hands every agent the same synthesized
DesignSpace — agents are domain-blind by construction (the paper's
'separation of concerns' principle)."""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from repro.core.space import DesignSpace


class Agent:
    name = "agent"

    def __init__(self, space: DesignSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.best_reward = -np.inf
        self.best_config: dict[str, Any] | None = None

    def propose(self) -> dict[str, Any]:
        raise NotImplementedError

    def observe(self, config: dict[str, Any], reward: float) -> None:
        if reward > self.best_reward:
            self.best_reward = reward
            self.best_config = config

    # -- population API ----------------------------------------------------
    # The batched DSE driver asks for a whole population, evaluates it (with
    # memoization / a process pool), then feeds back every reward at once.
    # Defaults fall back to the scalar methods, so ``propose_batch(1)`` /
    # ``observe_batch([c], [r])`` consume the RNG and mutate state exactly
    # like one sequential propose/observe round.

    def propose_batch(self, n: int) -> list[dict[str, Any]]:
        return [self.propose() for _ in range(n)]

    def observe_batch(self, configs: Sequence[dict[str, Any]],
                      rewards: Sequence[float]) -> None:
        for config, reward in zip(configs, rewards):
            self.observe(config, reward)


# the registered agent kinds, importable without the agent modules (StudySpec
# validates agent grids at spec time, before any search machinery loads)
KNOWN_AGENTS = ("rw", "ga", "aco", "bo", "surrogate")

# hyper names each kind's __init__ accepts (beyond space/seed) — the spec
# layer rejects unknown keys at spec time instead of TypeError'ing cells
# deep into a campaign; a sync assert in make_agent keeps this honest
AGENT_HYPER: dict[str, frozenset[str]] = {
    "rw": frozenset({"population"}),
    "ga": frozenset({"population", "p_mut", "tournament"}),
    "aco": frozenset({"ants", "greediness", "evaporation", "deposit"}),
    "bo": frozenset({"n_init", "candidates", "lengthscale", "noise",
                     "max_fit"}),
    "surrogate": frozenset({"model", "pool", "explore", "warmup", "elite",
                            "p_mut", "random_frac", "max_fit"}),
}


def make_agent(kind: str, space: DesignSpace, seed: int = 0, **hyper) -> Agent:
    from repro.core.agents.aco import AntColony
    from repro.core.agents.bayesian import BayesianOptimizer
    from repro.core.agents.genetic import GeneticAlgorithm
    from repro.core.agents.random_walk import RandomWalker
    from repro.core.agents.surrogate import SurrogateScreeningAgent

    kinds = {"rw": RandomWalker, "ga": GeneticAlgorithm,
             "aco": AntColony, "bo": BayesianOptimizer,
             "surrogate": SurrogateScreeningAgent}
    assert set(kinds) == set(KNOWN_AGENTS) == set(AGENT_HYPER), \
        "KNOWN_AGENTS/AGENT_HYPER out of sync with make_agent's registry"
    if kind not in kinds:
        raise ValueError(f"unknown agent kind {kind!r}; "
                         f"known: {sorted(kinds)}")
    return kinds[kind](space, seed=seed, **hyper)
