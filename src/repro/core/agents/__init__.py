from repro.core.agents.base import Agent, make_agent
from repro.core.agents.random_walk import RandomWalker
from repro.core.agents.genetic import GeneticAlgorithm
from repro.core.agents.aco import AntColony
from repro.core.agents.bayesian import BayesianOptimizer

__all__ = ["Agent", "make_agent", "RandomWalker", "GeneticAlgorithm",
           "AntColony", "BayesianOptimizer"]
