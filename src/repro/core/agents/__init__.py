from repro.core.agents.base import (AGENT_HYPER, Agent, KNOWN_AGENTS,
                                    make_agent)
from repro.core.agents.random_walk import RandomWalker
from repro.core.agents.genetic import GeneticAlgorithm
from repro.core.agents.aco import AntColony
from repro.core.agents.bayesian import BayesianOptimizer
from repro.core.agents.surrogate import SurrogateScreeningAgent

__all__ = ["Agent", "make_agent", "KNOWN_AGENTS", "AGENT_HYPER",
           "RandomWalker", "GeneticAlgorithm", "AntColony",
           "BayesianOptimizer", "SurrogateScreeningAgent"]
