"""Ant colony optimization (Dorigo & Di Caro, 1999) over the gene lattice:
pheromone per (gene, choice); paper knobs: number of ants, greediness q0,
evaporation rate rho."""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.agents.base import Agent


class AntColony(Agent):
    name = "aco"

    def __init__(self, space, seed: int = 0, ants: int = 16,
                 greediness: float = 0.2, evaporation: float = 0.05,
                 deposit: float = 1.0):
        super().__init__(space, seed)
        self.q0 = greediness
        self.rho = evaporation
        self.deposit = deposit
        self.ants = ants
        self.tau = [np.ones(len(g.choices)) for g in space.genes]

    def propose(self) -> dict[str, Any]:
        vec = []
        for i, g in enumerate(self.space.genes):
            t = self.tau[i]
            if self.rng.random() < self.q0:
                vec.append(int(np.argmax(t)))
            else:
                p = t / t.sum()
                vec.append(int(self.rng.choice(len(t), p=p)))
        config = self.space.repair(self.space.decode(vec), self.rng)
        if not self.space.is_valid(config):
            config = self.space.sample(self.rng)
        return config

    # The inherited population API already realizes colony semantics: tau is
    # only touched on observe, so propose_batch(n) walks n ants over the
    # same pheromone field and observe_batch evaporates/deposits per ant.

    def observe(self, config: dict[str, Any], reward: float) -> None:
        super().observe(config, reward)
        vec = self.space.encode(config)
        rel = reward / (abs(self.best_reward) + 1e-30) if self.best_reward > 0 else 0.0
        for i, choice in enumerate(vec):
            self.tau[i] *= (1.0 - self.rho)
            # elitist deposit: only near-best ants lay pheromone, weighted
            # superlinearly so mediocre trails fade
            if rel >= 0.8:
                self.tau[i][choice] += self.deposit * rel * rel
            self.tau[i] = np.maximum(self.tau[i], 1e-6)
