"""Static verification, critical-path attribution, and PsA/StudySpec lint.

Three layers, all of which run WITHOUT simulating anything:

  * **Trace/plan verifier** (``verify_trace`` / ``verify_plan``): every
    defect that would hang or crash the event loop — a dependency cycle, a
    dangling dep or resource reference, an unprovisioned pool, a negative
    repeat/delay/cost — is reported as a structured ``AnalysisReport``
    BEFORE a campaign burns hours on it.  The engine's resources are
    unit-capacity single servers, so acyclicity + valid references is a
    *complete* termination criterion for the reference loop: a trace this
    verifier passes cannot deadlock it.  Checks run vectorized over the
    ``_SimPlan``'s flat arrays (the plan is built once per trace and shared
    with simulation, so verification adds no per-op Python pass); the
    report is memoized on the trace, so repeat verifications are free.

  * **Critical-path analysis** (``critical_path``): the longest chain
    through the dependency DAG with per-op slack and per-resource busy-time
    lower bounds.  Both are lower bounds on any schedule's makespan
    (``length_us <= makespan_us``), and the per-category split of the path
    (compute vs collective vs xfer vs gate time) is the per-evaluation
    bottleneck attribution ``simulate(..., analyze=True)`` attaches to
    ``SimResult.analysis`` and ``python -m repro.dse analyze`` tabulates.

  * **PsA/StudySpec lint** (``lint_pset`` / ``lint_study``): constraint-set
    satisfiability (analytic impossibility over sum/product constraints +
    repair-aware sampling probes) and dead-knob detection — searched
    parameters no evaluation path ever reads, found by recording config-key
    accesses while building (not running) a few probe ``SimJob``s.

``preflight`` is the fail-fast gate ``run_study`` applies to the first
plan of every cell; the CLI surfaces all three layers as
``python -m repro.dse lint|analyze``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.simulator import _SimPlan, _sim_plan, plan_durations
from repro.core.space import DesignSpace
from repro.core.workload import Parallelism, Trace

_OP_KINDS = ("comp", "coll", "delay")


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Issue:
    """One finding: a machine-readable code, a human message, and the
    offending op/pool/resource/constraint where attributable."""
    code: str
    message: str
    severity: str = "error"             # "error" | "warning"
    op: int | None = None
    pool: int | None = None
    resource: int | None = None
    constraint: str | None = None


@dataclass(frozen=True)
class AnalysisReport:
    """A static-analysis verdict: what was analyzed, summary facts about
    it (``info``), and the issues found.  ``ok`` means no errors (warnings
    don't fail a run); ``raise_if_issues`` turns errors into a
    ``PlanVerificationError`` carrying the full report."""
    subject: str
    issues: tuple[Issue, ...] = ()
    info: Mapping[str, Any] = field(default_factory=dict)

    @property
    def errors(self) -> tuple[Issue, ...]:
        return tuple(i for i in self.issues if i.severity == "error")

    @property
    def warnings(self) -> tuple[Issue, ...]:
        return tuple(i for i in self.issues if i.severity == "warning")

    @property
    def ok(self) -> bool:
        return not self.errors

    def raise_if_issues(self, *, warnings_fatal: bool = False) -> "AnalysisReport":
        if (self.issues if warnings_fatal else self.errors):
            raise PlanVerificationError(self)
        return self

    def to_dict(self) -> dict[str, Any]:
        return {"subject": self.subject, "ok": self.ok,
                "info": dict(self.info),
                "issues": [dataclasses.asdict(i) for i in self.issues]}

    def format(self) -> str:
        head = f"{self.subject}: " + (
            "ok" if not self.issues else
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s)")
        lines = [head]
        if self.info:
            lines.append("  " + " ".join(f"{k}={v}"
                                         for k, v in self.info.items()))
        for i in self.issues:
            where = " ".join(
                f"{k}={v}" for k, v in (("op", i.op), ("pool", i.pool),
                                        ("resource", i.resource))
                if v is not None)
            lines.append(f"  [{i.severity}] {i.code}: {i.message}"
                         + (f" ({where})" if where else ""))
        return "\n".join(lines)


class PlanVerificationError(RuntimeError):
    """A static check failed; ``.report`` holds the full ``AnalysisReport``
    (also rendered as the exception message)."""

    def __init__(self, report: AnalysisReport) -> None:
        self.report = report
        super().__init__(report.format())


# ---------------------------------------------------------------------------
# Trace / plan verifier
# ---------------------------------------------------------------------------

def _kahn_unfinished(n: int, ndeps0: Sequence[int],
                     children: Sequence[Sequence[int]]) -> list[int]:
    """Uids that can never become ready (on a dependency cycle, or
    downstream of one) — empty iff the dependency graph is a DAG."""
    ndeps = list(ndeps0)
    queue = [u for u in range(n) if ndeps[u] == 0]
    head = 0
    while head < len(queue):
        u = queue[head]
        head += 1
        for c in children[u]:
            ndeps[c] -= 1
            if ndeps[c] == 0:
                queue.append(c)
    if head == n:
        return []
    done = set(queue)
    return [u for u in range(n) if u not in done]


def _plan_arrays(plan: _SimPlan) -> tuple[np.ndarray, np.ndarray]:
    """``(res_of, ndeps0)`` as int64 arrays, cached on the plan's memo dict
    (the plan keeps them as Python lists for the event loop's scalar
    indexing; converting 26k-element lists per verification would eat the
    whole overhead budget)."""
    arrs = plan.pack_memo.get("_analysis_arrays")
    if arrs is None:
        arrs = (np.asarray(plan.res_of, dtype=np.int64),
                np.asarray(plan.ndeps0, dtype=np.int64))
        plan.pack_memo["_analysis_arrays"] = arrs
    return arrs


def _plan_issues(plan: _SimPlan) -> list[Issue]:
    """Structural checks over the plan's flat arrays (vectorized — no
    per-op Python pass on the happy path)."""
    issues: list[Issue] = []
    n = plan.n_ops
    n_res = len(plan.res_names)

    # resource references: every op must map to a provisioned resource
    res_of, _ndeps = _plan_arrays(plan)
    if len(plan.res_pool) != n_res or res_of.size != n:
        issues.append(Issue(
            "res-structure",
            f"resource bookkeeping is inconsistent: {res_of.size} op->"
            f"resource entries / {n_res} names / {len(plan.res_pool)} pools "
            f"for {n} ops"))
    elif n:
        bad = (res_of < 0) | (res_of >= n_res)
        if bad.any():
            u = int(np.argmax(bad))
            issues.append(Issue(
                "dangling-resource",
                f"op {u} demands resource id {int(res_of[u])} but the plan "
                f"only provisions resources 0..{n_res - 1} — the op could "
                f"never be scheduled (guaranteed deadlock)",
                op=u, resource=int(res_of[u])))

    # dependency references + acyclicity
    ndeps = _ndeps
    deps = np.asarray(plan.deps_flat, dtype=np.int64)
    if ndeps.size != n or (n and (ndeps < 0).any()) \
            or int(ndeps.sum()) != deps.size or len(plan.children) != n:
        issues.append(Issue(
            "dep-structure",
            "dependency bookkeeping is inconsistent "
            "(ndeps0 / children / deps_flat disagree)"))
        return issues
    owner = np.repeat(np.arange(n, dtype=np.int64), ndeps)
    out_of_range = (deps < 0) | (deps >= n)
    if out_of_range.any():
        i = int(np.argmax(out_of_range))
        issues.append(Issue(
            "dangling-dep",
            f"op {int(owner[i])} depends on uid {int(deps[i])}, outside "
            f"0..{n - 1} — it would never be released", op=int(owner[i])))
    elif (deps == owner).any():
        i = int(np.argmax(deps == owner))
        issues.append(Issue("dep-cycle",
                            f"op {int(owner[i])} depends on itself",
                            op=int(owner[i])))
    elif deps.size and not bool((deps < owner).all()):
        # TraceBuilder only ever emits backward deps, so this fast check IS
        # the acyclicity proof for builder traces; anything else gets the
        # full Kahn toposort
        stuck = _kahn_unfinished(n, plan.ndeps0, plan.children)
        if stuck:
            sample = ", ".join(str(u) for u in stuck[:6])
            issues.append(Issue(
                "dep-cycle",
                f"dependency cycle: {len(stuck)} op(s) can never become "
                f"ready (e.g. {sample}) — the event loop would deadlock at "
                f"{n - len(stuck)}/{n} ops", op=stuck[0]))

    # repeat / delay / cost sanity (compute repeats fold into flops/bytes)
    cr = plan.coll_repeat
    if cr.size:
        bad = ~np.isfinite(cr) | (cr < 1)
        if bad.any():
            i = int(np.argmax(bad))
            issues.append(Issue(
                "bad-repeat",
                f"collective op {int(plan.coll_uids[i])} has repeat "
                f"{cr[i]!r} (must be a finite count >= 1)",
                op=int(plan.coll_uids[i])))
    for what, arr in (("flops", plan.comp_flops), ("bytes", plan.comp_bytes)):
        if arr.size:
            bad = ~np.isfinite(arr) | (arr < 0)
            if bad.any():
                i = int(np.argmax(bad))
                issues.append(Issue(
                    "bad-cost",
                    f"compute op {int(plan.comp_uids[i])} has {what} "
                    f"{arr[i]!r} (must be finite and >= 0)",
                    op=int(plan.comp_uids[i])))
    for uid, delay in plan.delay_ops:
        if not (np.isfinite(delay) and delay >= 0):
            issues.append(Issue(
                "bad-delay",
                f"delay op {uid} has delay_us {delay!r} (must be finite "
                f"and >= 0)", op=uid))
            break
    for ci, (pool, _group, _coll, size) in enumerate(plan.coll_shapes):
        if not (np.isfinite(size) and size >= 0):
            uid = int(plan.coll_uids[int(np.argmax(plan.coll_class == ci))])
            issues.append(Issue(
                "bad-cost",
                f"collective op {uid} has size_bytes {size!r} (must be "
                f"finite and >= 0)", op=uid, pool=pool))
    return issues


def _diagnose_trace(trace: Trace) -> list[Issue]:
    """Precise per-op diagnosis for traces whose plan cannot even be built
    (non-dense uids, wildly out-of-range deps).  Slow path: only runs on
    defective traces."""
    issues: list[Issue] = []
    n = len(trace.ops)
    for i, op in enumerate(trace.ops):
        if op.uid != i:
            issues.append(Issue(
                "bad-uid",
                f"ops[{i}] has uid {op.uid} — the scheduler requires dense "
                f"uids (0..{n - 1} in list order; build traces with "
                f"TraceBuilder)", op=i))
            break
    for op in trace.ops:
        if op.kind not in _OP_KINDS:
            issues.append(Issue(
                "bad-kind", f"op {op.uid} has unknown kind {op.kind!r}; "
                f"known: {_OP_KINDS}", op=op.uid))
            break
    for op in trace.ops:
        bad = [d for d in op.deps if not 0 <= d < n]
        if bad:
            issues.append(Issue(
                "dangling-dep",
                f"op {op.uid} depends on uid {bad[0]}, outside 0..{n - 1} — "
                f"it would never be released", op=op.uid))
            break
    return issues


def _example_op_on_pool(plan: _SimPlan, pool: int) -> int | None:
    rp = np.asarray(plan.res_pool, dtype=np.int64)
    ro = np.asarray(plan.res_of, dtype=np.int64)
    mask = rp[ro] == pool
    return int(np.argmax(mask)) if mask.any() else None


def _context_issues(plan: _SimPlan, cfg: Any, par: Parallelism | None,
                    pools: Mapping[int, Any] | None) -> list[Issue]:
    """Design-point-dependent feasibility: each pool the trace schedules
    onto must be provisioned with a placement that fits its network."""
    issues: list[Issue] = []
    for p in plan.pools:
        entry = par if pools is None else pools.get(p, par)
        if pools is not None and p not in pools:
            issues.append(Issue(
                "pool-unmapped",
                f"trace schedules ops onto pool {p} but the pools mapping "
                f"only provisions {sorted(pools)} — pool {p} silently falls "
                f"back to the global parallelism",
                severity="warning", pool=p, op=_example_op_on_pool(plan, p)))
        if isinstance(entry, tuple):    # (Par, Net) or (Par, Net, dim_map)
            par_p, net_p = entry[0], entry[1]
        else:
            par_p, net_p = entry, (cfg.network if cfg is not None else None)
        if par_p is None:
            continue
        if net_p is not None:
            capacity = 1
            for d in net_p.dims:
                capacity *= d.npus
            if par_p.n_npus > capacity:
                issues.append(Issue(
                    "pool-capacity",
                    f"pool {p} demands {par_p.n_npus} NPUs but its network "
                    f"provides {capacity} — an infeasible placement "
                    f"(collectives would be priced on links that don't "
                    f"exist)", pool=p, op=_example_op_on_pool(plan, p)))
        if not par_p.valid():
            issues.append(Issue(
                "bad-parallelism",
                f"pool {p}: dp*sp*pp = {par_p.dp * par_p.sp * par_p.pp} "
                f"does not evenly divide n_npus = {par_p.n_npus}", pool=p))
    return issues


def verify_plan(plan: _SimPlan, subject: str = "plan") -> AnalysisReport:
    """Statically verify one ``_SimPlan``'s structure (no design-point
    context).  For the common entry point see ``verify_trace``."""
    return AnalysisReport(
        subject=subject, issues=tuple(_plan_issues(plan)),
        info={"n_ops": plan.n_ops, "n_resources": len(plan.res_names),
              "n_pools": len(plan.pools),
              "n_deps": int(np.asarray(plan.deps_flat).size)})


def verify_trace(trace: Trace, cfg: Any = None,
                 par: Parallelism | None = None,
                 pools: Mapping[int, Any] | None = None) -> AnalysisReport:
    """Statically verify a trace's scheduling plan.

    The structural verdict (references, acyclicity, repeat/delay/cost
    sanity) is memoized on the trace — traces are interned by the WTG
    cache, so a campaign pays it once per distinct trace.  Passing the
    design-point context (``cfg``/``par``/``pools``, the ``simulate()``
    arguments) adds pool-feasibility checks on top.

    A structurally clean plan provably cannot deadlock the reference event
    loop: every resource is a unit-capacity single server, so valid
    references + an acyclic dependency DAG guarantee all ops finish."""
    rep = getattr(trace, "_verify_report", None)
    if rep is None:
        try:
            plan = _sim_plan(trace)
        except (ValueError, IndexError, TypeError, KeyError) as e:
            issues = _diagnose_trace(trace)
            if not issues:
                issues = [Issue("plan-error",
                                f"scheduling-plan construction failed: {e}")]
            rep = AnalysisReport(subject=_subject(trace),
                                 issues=tuple(issues),
                                 info={"n_ops": len(trace.ops)})
        else:
            rep = verify_plan(plan, subject=_subject(trace))
        trace._verify_report = rep
    if cfg is not None or par is not None or pools is not None:
        plan = getattr(trace, "_sim_plan", None)
        if plan is not None:
            extra = _context_issues(plan, cfg, par, pools)
            if extra:
                rep = dataclasses.replace(rep,
                                          issues=rep.issues + tuple(extra))
    return rep


def _subject(trace: Trace) -> str:
    return f"trace[{len(trace.ops)} ops]"


# ---------------------------------------------------------------------------
# Critical-path analysis
# ---------------------------------------------------------------------------

# bottleneck categories a resource (and through it, an op) falls into
_CATEGORIES = ("compute", "collective", "xfer", "gate")


def _res_categories(plan: _SimPlan) -> np.ndarray:
    cats = np.empty(len(plan.res_names), dtype=np.int64)
    for r, name in enumerate(plan.res_names):
        cats[r] = (0 if name == "compute"
                   else 3 if name.startswith("_delay")
                   else 2 if name == "xfer" else 1)
    return cats


@dataclass(frozen=True)
class CriticalPath:
    """The longest chain through the dependency DAG under one design
    point's durations — a lower bound on every schedule's makespan.

    ``slack_us[u]`` is how much op ``u`` can slip without lengthening the
    path (zero-slack ops lie on a critical path);  ``breakdown_us`` splits
    the reported path's time into compute / collective / xfer / gate;
    ``resource_busy_us`` is each resource's total demand — its max is the
    other makespan lower bound (a resource can't serve more than one op at
    a time)."""
    length_us: float
    path: tuple[int, ...]
    slack_us: np.ndarray
    breakdown_us: dict[str, float]
    resource_busy_us: dict[str, float]
    n_critical: int

    @property
    def resource_lb_us(self) -> float:
        return max(self.resource_busy_us.values(), default=0.0)

    def binding_resource(self) -> str:
        """The busiest resource's label — the capacity bound's witness."""
        if not self.resource_busy_us:
            return "none"
        return max(self.resource_busy_us, key=self.resource_busy_us.get)

    def summary(self, makespan_us: float | None = None) -> dict[str, Any]:
        """The attribution dict ``SimResult.analysis`` carries."""
        total = sum(self.breakdown_us.values())
        out: dict[str, Any] = {
            "critical_path_us": self.length_us,
            "path_ops": len(self.path),
            "n_critical_ops": self.n_critical,
            "breakdown_us": dict(self.breakdown_us),
            "breakdown_frac": {k: (v / total if total else 0.0)
                               for k, v in self.breakdown_us.items()},
            "resource_lb_us": self.resource_lb_us,
            "binding_resource": self.binding_resource(),
        }
        if makespan_us is not None:
            out["makespan_us"] = makespan_us
            out["cp_frac_of_makespan"] = \
                self.length_us / makespan_us if makespan_us else 1.0
            # which lower bound explains the schedule: the dependency chain
            # or the busiest resource's capacity
            out["bound"] = ("dependency-path"
                            if self.length_us >= self.resource_lb_us
                            else f"resource:{self.binding_resource()}")
        return out


def critical_path(plan: _SimPlan, dur: np.ndarray) -> CriticalPath:
    """Longest path + per-op slack over the dependency DAG.

    Requires a verified plan (raises on a cyclic one).  Durations are the
    per-op vector ``plan_durations`` produces for one design point."""
    n = plan.n_ops
    dur = np.asarray(dur, dtype=np.float64)
    res_of, ndeps = _plan_arrays(plan)
    deps = np.asarray(plan.deps_flat, dtype=np.int64)
    backward = not deps.size or bool(
        (deps < np.repeat(np.arange(n, dtype=np.int64), ndeps)).all())
    if backward:
        order: Sequence[int] = range(n)
    else:
        stuck = _kahn_unfinished(n, plan.ndeps0, plan.children)
        if stuck:
            raise PlanVerificationError(verify_plan(plan))
        ndeps_left = list(plan.ndeps0)
        order = [u for u in range(n) if ndeps_left[u] == 0]
        head = 0
        while head < len(order):
            for c in plan.children[order[head]]:
                ndeps_left[c] -= 1
                if ndeps_left[c] == 0:
                    order.append(c)  # type: ignore[attr-defined]
            head += 1

    children = plan.children
    d = dur.tolist()
    est = [0.0] * n
    finish = [0.0] * n
    for u in order:
        f = est[u] + d[u]
        finish[u] = f
        for c in children[u]:
            if f > est[c]:
                est[c] = f
    length = max(finish, default=0.0)

    # backward pass: latest finish under the fixed path length
    lat = [length] * n
    for u in reversed(list(order)):
        m = lat[u]
        for c in children[u]:
            v = lat[c] - d[c]
            if v < m:
                m = v
        lat[u] = m
    slack = np.asarray(lat) - dur - np.asarray(est)

    # walk one critical chain back from the latest-finishing sink
    path: list[int] = []
    if n:
        fin = np.asarray(finish)
        offsets = np.concatenate(([0], np.cumsum(ndeps)))
        u = int(np.argmax(fin))
        path.append(u)
        while True:
            seg = deps[offsets[u]:offsets[u + 1]]
            if not seg.size:
                break
            u = int(seg[int(np.argmax(fin[seg]))])
            path.append(u)
        path.reverse()

    cats = _res_categories(plan)[res_of]
    pa = np.asarray(path, dtype=np.intp)
    sums = np.bincount(cats[pa], weights=dur[pa], minlength=4) if len(path) \
        else np.zeros(4)
    busy = np.bincount(res_of, weights=dur, minlength=len(plan.res_names))
    resource_busy = {
        f"pool{plan.res_pool[r]}:{plan.res_names[r]}": float(busy[r])
        for r in range(len(plan.res_names))
        if not plan.res_names[r].startswith("_delay")}
    tol = max(length, 1.0) * 1e-9
    return CriticalPath(
        length_us=float(length), path=tuple(path), slack_us=slack,
        breakdown_us=dict(zip(_CATEGORIES, (float(s) for s in sums))),
        resource_busy_us=resource_busy,
        n_critical=int((slack <= tol).sum()))


def analyze_job(job: Any, backend: "str | Any | None" = None
                ) -> tuple[Any, list[dict[str, Any]]]:
    """Run one scenario ``SimJob`` with per-call critical-path attribution:
    ``(finalized evaluation, one summary dict per call)``.  A non-``SimJob``
    input (a gated-invalid ``Evaluation``) passes through with no
    summaries."""
    from repro.core.backends.base import SimJob
    from repro.core.simulator import simulate

    if not isinstance(job, SimJob):
        return job, []
    results = []
    summaries = []
    for c in job.calls:
        res = simulate(c.trace, c.cfg, c.par, pools=c.pools,
                       record_per_op=c.record_per_op,
                       record_finish=c.record_finish,
                       backend=backend, analyze=True)
        results.append(res)
        summaries.append(res.analysis)
    return job.finalize(results), summaries


def aggregate_summaries(summaries: Sequence[Mapping[str, Any]]
                        ) -> dict[str, Any] | None:
    """Fold per-call attribution summaries into one design-point view
    (calls chain — disaggregated phases — so times add)."""
    summaries = [s for s in summaries if s]
    if not summaries:
        return None
    breakdown = {k: sum(s["breakdown_us"].get(k, 0.0) for s in summaries)
                 for k in _CATEGORIES}
    total = sum(breakdown.values())
    makespan = sum(s.get("makespan_us", 0.0) for s in summaries)
    cp = sum(s["critical_path_us"] for s in summaries)
    dominant = max(summaries,
                   key=lambda s: s.get("makespan_us", s["critical_path_us"]))
    return {"calls": len(summaries), "makespan_us": makespan,
            "critical_path_us": cp,
            "cp_frac_of_makespan": cp / makespan if makespan else 1.0,
            "breakdown_us": breakdown,
            "breakdown_frac": {k: (v / total if total else 0.0)
                               for k, v in breakdown.items()},
            "bound": dominant.get("bound", "dependency-path"),
            "binding_resource": dominant.get("binding_resource", "none")}


# ---------------------------------------------------------------------------
# PsA / StudySpec lint
# ---------------------------------------------------------------------------

class _RecordingConfig(dict):
    """A config dict that records which keys the evaluation path reads —
    the dead-knob probe wraps ``ctx.config`` in one while BUILDING (not
    running) a ``SimJob``."""

    def __init__(self, data: Mapping[str, Any], seen: set) -> None:
        super().__init__(data)
        self._seen = seen

    def __getitem__(self, key):
        self._seen.add(key)
        return super().__getitem__(key)

    def get(self, key, default=None):
        self._seen.add(key)
        return super().get(key, default)


def _numeric_choices(pset, slot: str) -> "tuple[float, ...] | None":
    """The numeric value set one constraint slot can take (respecting
    ``fixed``), or None when it isn't numeric."""
    base, idx = (slot[:-1].split("[") if "[" in slot else (slot, None))
    try:
        p = pset.by_name(base)
    except (KeyError, ValueError):
        return None
    if base in pset.fixed:
        v = pset.fixed[base]
        v = v[int(idx)] if idx is not None and isinstance(v, tuple) else v
        vals: tuple = (v,)
    else:
        vals = tuple(p.choices)
    if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
               for v in vals):
        return None
    return vals


def _unsat_constraints(pset) -> list[Issue]:
    """Analytic impossibility over the declared constraint set: individual
    constraints no slot assignment can satisfy, and same-slot pairs with
    incompatible targets (e.g. ``product_eq N`` vs ``product_le N/2``)."""
    issues: list[Issue] = []
    resolved = []  # (constraint, sorted slot tuple, numeric target | None)
    for c in pset.constraints:
        if c.kind == "predicate":
            continue
        target = None if isinstance(c.target, str) else c.target
        slots = tuple(sorted(pset.expand_constraint_params(c)))
        choices = [_numeric_choices(pset, s) for s in slots]
        if target is None or any(ch is None for ch in choices):
            continue
        resolved.append((c, slots, target, choices))
        if c.kind == "sum_le":
            lo = sum(min(ch) for ch in choices)
            if lo > target:
                issues.append(Issue(
                    "constraint-unsat",
                    f"{c.describe()} is unsatisfiable: the smallest "
                    f"possible sum over {list(slots)} is {lo} > "
                    f"{target} (oversubscribed budget)",
                    constraint=c.describe()))
        elif c.kind == "product_le":
            lo = 1.0
            for ch in choices:
                lo *= min(ch)
            if lo > target:
                issues.append(Issue(
                    "constraint-unsat",
                    f"{c.describe()} is unsatisfiable: the smallest "
                    f"possible product is {lo} > {target}",
                    constraint=c.describe()))
        elif c.kind == "product_eq":
            reachable = {1.0}
            for ch in choices:
                reachable = {r * v for r in reachable for v in set(ch)}
                if len(reachable) > 65536:
                    reachable = set()
                    break
            if reachable and target not in reachable:
                issues.append(Issue(
                    "constraint-unsat",
                    f"{c.describe()} is unsatisfiable: no assignment of "
                    f"{list(slots)} multiplies to {target}",
                    constraint=c.describe()))
    # incompatible same-slot pairs
    for i, (a, slots_a, ta, _) in enumerate(resolved):
        for b, slots_b, tb, _ in resolved[i + 1:]:
            if slots_a != slots_b:
                continue
            pair = f"{a.describe()} vs {b.describe()}"
            if a.kind == "product_eq" and b.kind == "product_eq" and ta != tb:
                issues.append(Issue(
                    "constraint-unsat",
                    f"unsatisfiable constraint pair: {pair} (two exact "
                    f"products over the same slots)", constraint=pair))
            for eq, le in ((a, b), (b, a)):
                if eq.kind == "product_eq" and le.kind == "product_le":
                    t_eq = ta if eq is a else tb
                    t_le = tb if eq is a else ta
                    if t_eq > t_le:
                        issues.append(Issue(
                            "constraint-unsat",
                            f"unsatisfiable constraint pair: {pair} "
                            f"(required product {t_eq} exceeds the cap "
                            f"{t_le})", constraint=pair))
    return issues


def _dead_knobs(env: Any, pset, configs: Sequence[dict]) -> list[Issue]:
    """Searched parameters no evaluation path reads: build (don't run) each
    probe config's ``SimJob`` with a recording config and union the keys
    the env/scenario touched."""
    from repro.core.backends.base import SimJob  # noqa: F401 (probe path)

    seen: set = set()
    for cfg in configs:
        rec = _RecordingConfig(cfg, seen)
        try:
            ctx = env.context(rec)
            env.scenario.sim_job(ctx)
        except Exception as e:  # a probe crash is a finding, not a crash
            return [Issue("probe-error",
                          f"dead-knob probe failed while building a "
                          f"SimJob: {e}", severity="warning")]
    return [Issue(
        "dead-knob",
        f"searched parameter {p.name!r} is never read by the evaluation "
        f"path — its {p.cardinality()} choices only dilute the search",
        constraint=p.name)
        for p in pset.searched_params() if p.name not in seen]


def lint_pset(pset, env: Any = None, *, probes: int = 256,
              eval_probes: int = 2, seed: int = 0) -> AnalysisReport:
    """Lint one ``ParameterSet``/``DesignSpace``: constraint-set
    satisfiability (analytic + sampling with the repair path, i.e. exactly
    what agents rely on) and — given an env — dead-knob detection."""
    issues: list[Issue] = list(_unsat_constraints(pset))
    space = DesignSpace(pset)
    rng = np.random.default_rng(seed)
    info = {"params": len(pset.params),
            "searched": len(pset.searched_params()),
            "genes": space.n_genes(),
            "constraints": len(pset.constraints),
            "cardinality": f"{pset.cardinality():.3g}"}
    configs: list[dict] = []
    if not issues:
        try:
            for _ in range(eval_probes):
                configs.append(space.sample(rng))
        except RuntimeError as e:
            rates = space.constraint_violation_rates(rng, tries=probes)
            always = sorted(name for name, r in rates.items() if r >= 1.0)
            hint = (f" Constraint(s) no raw sample ever satisfies: "
                    f"{always}." if always else "")
            issues.append(Issue("constraint-unsat", f"{e}{hint}",
                                constraint=always[0] if always else None))
    if env is not None and configs:
        issues.extend(_dead_knobs(env, pset, configs))
    return AnalysisReport(subject=f"pset[{pset.name}]",
                          issues=tuple(issues), info=info)


def preflight(env: Any, pset, seed: int = 0, tries: int = 4
              ) -> AnalysisReport | None:
    """Sample a design point and statically verify the scheduling plan(s)
    its ``SimJob`` would run — the always-on fail-fast gate ``run_study``
    applies to each cell before searching.  Returns the merged report for
    the first config that yields a ``SimJob`` (structural verdicts are
    memoized per trace, so this is ~free when traces are shared), or None
    when every probe gated invalid (nothing to verify)."""
    from repro.core.backends.base import SimJob

    space = DesignSpace(pset)
    rng = np.random.default_rng(seed)
    for _ in range(tries):
        try:
            cfg = space.sample(rng)
        except RuntimeError as e:
            # surfaces as a clean CLI error instead of a mid-search traceback
            raise ValueError(str(e)) from None
        job = env.scenario.sim_job(env.context(cfg))
        if not isinstance(job, SimJob):
            continue
        reports = [verify_trace(c.trace, c.cfg, c.par, c.pools)
                   for c in job.calls]
        issues = tuple(i for r in reports for i in r.issues)
        info = {"calls": len(reports),
                "n_ops": sum(r.info.get("n_ops", 0) for r in reports)}
        return AnalysisReport(subject="cell preflight", issues=issues,
                              info=info)
    return None


def lint_study(spec) -> AnalysisReport:
    """Lint a ``StudySpec`` without running it: resolve every registry
    (arch / system / scenario / objective / backend — the spec constructor
    already validated them), lint the assembled PsA, statically verify a
    probe design point's scheduling plan, and report campaign shape/cost."""
    pset = spec.build_pset()
    env = spec.build_env()
    rep = lint_pset(pset, env=env)
    issues = list(rep.issues)
    cells = spec.cells()
    info = dict(rep.info)
    info.update({
        "cells": len(cells),
        "evaluations_max": sum((a.steps or spec.steps) for _, a, _ in cells),
        "backend": spec.backend,
    })
    try:
        plan_rep = preflight(env, pset, seed=int(spec.seeds[0]))
    except ValueError as e:
        plan_rep = None
        issues.append(Issue("constraint-unsat", str(e)))
    if plan_rep is not None:
        issues.extend(plan_rep.issues)
        info["trace_ops"] = plan_rep.info.get("n_ops", 0)
        info["sim_calls"] = plan_rep.info.get("calls", 0)
    # scenarios can contribute shape facts of their own (e.g. the fleet
    # scenario reports its replica count so the lint output shows the
    # campaign's cost multiplier: replicas x trace ops)
    hook = getattr(env.scenario, "lint_info", None)
    if callable(hook):
        info.update(hook())
    return AnalysisReport(
        subject=f"study[{spec.name}] {spec.arch} on {spec.system}, "
                f"scenario={spec.scenario}, objective={spec.objective}",
        issues=tuple(issues), info=info)
