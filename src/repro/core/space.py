"""Parameter Set Scheduler (PSS): PsA schema -> agent-ready design space.

The paper's PSS "automatically establishes the abstraction layer between
agents and the design space" (Section 4.3): it synthesizes the action space
(one categorical gene per scalar slot), encodes/decodes configurations,
samples valid points under the declared constraints, and repairs invalid
proposals — so agents never need domain knowledge and experts never touch
agent internals.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.psa import Constraint, Parameter, ParameterSet


@dataclass(frozen=True)
class Gene:
    """One scalar slot of the action space."""
    slot: str          # e.g. 'dp' or 'coll_algo[2]'
    param: str         # owning parameter name
    dim: int           # slot index within a multidim parameter
    choices: tuple


class DesignSpace:
    """The synthesized action space for one ParameterSet."""

    def __init__(self, pset: ParameterSet):
        self.pset = pset
        self.genes: list[Gene] = []
        for p in pset.params:
            if p.name in pset.fixed:
                continue
            for d in range(p.ndim):
                self.genes.append(Gene(p.slots[d], p.name, d, p.choices))
        self._index = {g.slot: i for i, g in enumerate(self.genes)}
        # per-gene metadata resolved once (encode/decode sit on the agents'
        # batched hot path): owning Parameter, scalar-slot flag, choice index
        self._gene_param = [pset.by_name(g.param) for g in self.genes]
        self._gene_scalar = [p.ndim == 1 for p in self._gene_param]
        self._gene_choice_idx = [{v: i for i, v in enumerate(g.choices)}
                                 for g in self.genes]
        # batch-sampling tables: per-gene cardinality vector and object-dtype
        # choice arrays (object dtype so a vectorized gather hands back the
        # ORIGINAL python values — decode_batch must be bit-identical to
        # decode, numpy scalar types included)
        self._gene_sizes = np.array([len(g.choices) for g in self.genes],
                                    dtype=np.int64)
        self._gene_values: list[np.ndarray] = []
        for g in self.genes:
            arr = np.empty(len(g.choices), dtype=object)
            arr[:] = g.choices
            self._gene_values.append(arr)

    # -- config <-> vector ----------------------------------------------
    def n_genes(self) -> int:
        return len(self.genes)

    def encode(self, config: dict[str, Any]) -> np.ndarray:
        """config -> integer index vector (one index per gene)."""
        vec = np.zeros(len(self.genes), dtype=np.int64)
        for i, g in enumerate(self.genes):
            val = config[g.param] if g.dim == 0 and self._gene_scalar[i] \
                else config[g.param][g.dim]
            vec[i] = self._gene_choice_idx[i][val]
        return vec

    def decode(self, vec: Sequence[int]) -> dict[str, Any]:
        config: dict[str, Any] = dict(self.pset.fixed)
        tmp: dict[str, list] = {}
        for i, g in enumerate(self.genes):
            val = g.choices[int(vec[i]) % len(g.choices)]
            if self._gene_scalar[i]:
                config[g.param] = val
            else:
                tmp.setdefault(g.param, [None] * self._gene_param[i].ndim)[g.dim] = val
        for k, v in tmp.items():
            config[k] = tuple(v)
        return config

    def normalize(self, vec: Sequence[int]) -> np.ndarray:
        """index vector -> [0,1]^n floats (for BO surrogates)."""
        out = np.zeros(len(self.genes))
        for i, g in enumerate(self.genes):
            out[i] = vec[i] / max(len(g.choices) - 1, 1)
        return out

    # -- validity ----------------------------------------------------------
    def _slot_value(self, config: dict[str, Any], slot: str):
        if "[" in slot:
            base, idx = slot[:-1].split("[")
            return config[base][int(idx)]
        return config[slot]

    def is_valid(self, config: dict[str, Any]) -> bool:
        for c in self.pset.constraints:
            if not self._check(config, c):
                return False
        return True

    def violations(self, config: dict[str, Any]) -> list[str]:
        return [c.describe() for c in self.pset.constraints if not self._check(config, c)]

    def _check(self, config: dict[str, Any], c: Constraint) -> bool:
        if c.kind == "predicate":
            return bool(c.fn(config))
        slots = self.pset.expand_constraint_params(c)
        target = config[c.target] if isinstance(c.target, str) else c.target
        if c.kind == "sum_le":
            return sum(self._slot_value(config, s) for s in slots) <= target
        prod = 1
        for s in slots:
            prod *= self._slot_value(config, s)
        if c.kind == "product_eq":
            return prod == target
        if c.kind == "product_le":
            return prod <= target
        raise ValueError(c.kind)

    # -- batch sampling ------------------------------------------------------
    # The raw-decode probe machinery (PR-7 lint) vectorized: one broadcast
    # ``rng.integers(0, sizes, size=(n, G))`` block is draw-for-draw
    # identical to n repeated config-major scalar loops
    # ``[int(rng.integers(len(g.choices))) for g in genes]`` (numpy's
    # bounded-integer path consumes the bit stream element by element in
    # C order), so batched and scalar probes share one seed policy.

    def raw_decode_batch(self, n: int, rng: np.random.Generator) -> np.ndarray:
        """(n, n_genes) raw index matrix — unconstrained uniform decodes.
        Stream-identical to n repeated scalar raw draws from the same rng."""
        if not self.genes:
            return np.zeros((n, 0), dtype=np.int64)
        return rng.integers(0, self._gene_sizes, size=(n, len(self.genes)),
                            dtype=np.int64)

    def decode_batch(self, vecs: np.ndarray) -> list[dict[str, Any]]:
        """Vectorized ``decode`` over an (n, n_genes) index matrix: one
        object-dtype gather per gene, then per-row dict assembly.  Row i is
        bit-identical to ``decode(vecs[i])``."""
        vecs = np.asarray(vecs, dtype=np.int64) % self._gene_sizes
        cols = [self._gene_values[i][vecs[:, i]]
                for i in range(len(self.genes))]
        out: list[dict[str, Any]] = []
        for r in range(vecs.shape[0]):
            config: dict[str, Any] = dict(self.pset.fixed)
            tmp: dict[str, list] = {}
            for i, g in enumerate(self.genes):
                if self._gene_scalar[i]:
                    config[g.param] = cols[i][r]
                else:
                    tmp.setdefault(
                        g.param,
                        [None] * self._gene_param[i].ndim)[g.dim] = cols[i][r]
            for k, v in tmp.items():
                config[k] = tuple(v)
            out.append(config)
        return out

    def _slot_column(self, vecs: np.ndarray, slot: str) -> "np.ndarray | None":
        """One slot's numeric value column over an index matrix; None when
        the slot's values are non-numeric (vectorized checks then fall back
        to the scalar path)."""
        if slot in self._index:
            gi = self._index[slot]
            vals = self.genes[gi].choices
            if not all(isinstance(v, (int, float)) for v in vals):
                return None
            return np.asarray(vals, dtype=np.float64)[vecs[:, gi]]
        # a fixed (pinned) slot: constant column
        base, idx = (slot[:-1].split("[") + ["0"])[:2] if "[" in slot \
            else (slot, None)
        if base not in self.pset.fixed:
            return None
        v = self.pset.fixed[base]
        if idx is not None:
            v = v[int(idx)]
        if not isinstance(v, (int, float)):
            return None
        return np.full(vecs.shape[0], float(v))

    def constraint_mask(self, vecs: np.ndarray, c: Constraint) -> np.ndarray:
        """Vectorized ``_check`` over an (n, n_genes) index matrix: True per
        row where the constraint holds.  product/sum constraints over
        numeric slots run as column arithmetic; predicate constraints (and
        non-numeric slots) fall back to per-row decode + scalar check."""
        n = vecs.shape[0]
        if c.kind != "predicate":
            cols = [self._slot_column(vecs, s)
                    for s in self.pset.expand_constraint_params(c)]
            target = self._slot_column(vecs, c.target) \
                if isinstance(c.target, str) else np.full(n, float(c.target))
            if target is not None and all(col is not None for col in cols):
                stacked = np.stack(cols) if cols else np.zeros((0, n))
                if c.kind == "sum_le":
                    return stacked.sum(axis=0) <= target
                prod = stacked.prod(axis=0) if cols else np.ones(n)
                if c.kind == "product_eq":
                    return prod == target
                if c.kind == "product_le":
                    return prod <= target
                raise ValueError(c.kind)
        return np.array([self._check(cfg, c)
                         for cfg in self.decode_batch(vecs)], dtype=bool)

    def valid_mask(self, vecs: np.ndarray) -> np.ndarray:
        """Row-wise ``is_valid`` over an (n, n_genes) index matrix."""
        mask = np.ones(vecs.shape[0], dtype=bool)
        for c in self.pset.constraints:
            mask &= self.constraint_mask(vecs, c)
        return mask

    def sample_batch(self, n: int,
                     rng: np.random.Generator) -> list[dict[str, Any]]:
        """n valid samples, vectorized where it counts — drawing a 10^5
        screening pool must not dominate a search generation.

        Seed policy (documented + pinned by test): the raw decodes come
        from ONE broadcast integer block that consumes the rng exactly like
        n repeated scalar ``sample`` raw draws; a row whose raw decode
        already satisfies every constraint is returned as-is — so over a
        constraint-free space ``sample_batch(n, rng)`` is bit-identical to
        ``[space.sample(rng) for _ in range(n)]``.  Rows that need work go
        through ``sample``'s own repair-then-resample path per row (in row
        order, after the block), so constrained spaces stay deterministic
        per (seed, n) but diverge from the interleaved scalar stream."""
        vecs = self.raw_decode_batch(n, rng)
        mask = self.valid_mask(vecs)
        out: list[dict[str, Any] | None] = [None] * n
        if mask.any():
            decoded = self.decode_batch(vecs[mask])
            for j, i in enumerate(np.flatnonzero(mask)):
                out[i] = decoded[j]
        for i in np.flatnonzero(~mask):
            cfg = self.repair(self.decode(vecs[i]), rng)
            out[i] = cfg if self.is_valid(cfg) else self.sample(rng)
        return out  # type: ignore[return-value]

    # -- sampling / repair ---------------------------------------------------
    def sample(self, rng: np.random.Generator, max_tries: int = 512) -> dict[str, Any]:
        """Uniform valid sample: rejection + constraint-aware repair.

        An infeasible (or near-infeasible) space raises with the constraints
        that kept failing, so a bad PsA restriction — e.g. a StudySpec
        pinning values no constraint-satisfying config can contain — is
        debuggable instead of a bare 'could not sample'."""
        fail_counts: dict[str, int] = {}
        for _ in range(max_tries):
            vec = [int(rng.integers(len(g.choices))) for g in self.genes]
            config = self.decode(vec)
            config = self.repair(config, rng)
            violated = self.violations(config)
            if not violated:
                return config
            for v in violated:
                fail_counts[v] = fail_counts.get(v, 0) + 1
        worst = sorted(fail_counts.items(), key=lambda kv: -kv[1])
        detail = "; ".join(f"{name} (violated in {n}/{max_tries} tries)"
                           for name, n in worst[:4])
        raise RuntimeError(
            f"could not sample a valid config for {self.pset.name} in "
            f"{max_tries} tries — persistent constraint violations: {detail}."
            f" Check the fixed/pinned values against these constraints.")

    def constraint_violation_rates(self, rng: np.random.Generator,
                                   tries: int = 256) -> dict[str, float]:
        """Per-constraint violation fraction over raw uniform decodes (no
        repair) — the satisfiability probe ``repro.core.analysis.lint_pset``
        uses to tell an unsatisfiable constraint (rate 1.0) from one the
        repair path merely has to work at."""
        vecs = self.raw_decode_batch(tries, rng)  # stream-identical to the
        counts: dict[str, int] = {}               # old scalar probe loop
        for c in self.pset.constraints:
            counts[c.describe()] = int(tries - self.constraint_mask(vecs, c).sum())
        return {name: n / max(tries, 1) for name, n in counts.items()}

    def repair(self, config: dict[str, Any], rng: np.random.Generator,
               max_tries: int = 64) -> dict[str, Any]:
        """Project a config toward the feasible set by resampling the slots
        participating in each violated constraint."""
        config = dict(config)
        for c in self.pset.constraints:
            tries = 0
            while not self._check(config, c) and tries < max_tries:
                tries += 1
                slots = [s for s in self.pset.expand_constraint_params(c)
                         if self._slot_mutable(s)]
                if not slots:
                    break
                if c.kind in ("product_eq", "product_le") and self._try_factor_repair(config, c, rng):
                    continue
                if c.kind == "sum_le" and self._try_sum_repair(config, c, rng):
                    continue
                s = slots[int(rng.integers(len(slots)))]
                self._set_slot(config, s, self._random_choice(s, rng))
        return config

    def _slot_mutable(self, slot: str) -> bool:
        base = slot.split("[")[0]
        return base not in self.pset.fixed and base in {g.param for g in self.genes}

    def _random_choice(self, slot: str, rng: np.random.Generator):
        g = self.genes[self._index[slot]]
        return g.choices[int(rng.integers(len(g.choices)))]

    def _set_slot(self, config: dict[str, Any], slot: str, value):
        if "[" in slot:
            base, idx = slot[:-1].split("[")
            vals = list(config[base])
            vals[int(idx)] = value
            config[base] = tuple(vals)
        else:
            config[slot] = value

    def _try_factor_repair(self, config: dict[str, Any], c: Constraint,
                           rng: np.random.Generator) -> bool:
        """Exact repair for product constraints over power-of-two-ish slots:
        sample a random factorization of the target across the slots."""
        target = config[c.target] if isinstance(c.target, str) else c.target
        slots = [s for s in self.pset.expand_constraint_params(c) if self._slot_mutable(s)]
        if not slots or target <= 0:
            return False
        for _ in range(32):
            vals = {}
            rem = target
            order = list(slots)
            rng.shuffle(order)
            ok = True
            for i, s in enumerate(order):
                g = self.genes[self._index[s]]
                divisors = [v for v in g.choices
                            if isinstance(v, int) and v >= 1 and rem % v == 0]
                if c.kind == "product_le":
                    divisors = [v for v in g.choices
                                if isinstance(v, int) and 1 <= v <= rem]
                if not divisors:
                    ok = False
                    break
                v = divisors[int(rng.integers(len(divisors)))]
                vals[s] = v
                if c.kind == "product_eq":
                    if i == len(order) - 1 and rem // v != 1:
                        # force the last slot to close the product if possible
                        if rem in g.choices:
                            vals[s] = rem
                            v = rem
                        else:
                            ok = False
                            break
                    rem //= v
                else:
                    rem = max(rem // v, 1)
            if ok:
                for s, v in vals.items():
                    self._set_slot(config, s, v)
                if self._check(config, c):
                    return True
        return False

    def _try_sum_repair(self, config: dict[str, Any], c: Constraint,
                        rng: np.random.Generator) -> bool:
        """Exact repair for sum budgets (partition sizes): greedily resample
        each slot from the choices that still fit the remaining budget."""
        target = config[c.target] if isinstance(c.target, str) else c.target
        all_slots = self.pset.expand_constraint_params(c)
        slots = [s for s in all_slots if self._slot_mutable(s)]
        if not slots:
            return False
        # immutable (fixed) slots spend budget the repair can't touch
        budget = target - sum(
            v for s in all_slots if not self._slot_mutable(s)
            and isinstance((v := self._slot_value(config, s)), (int, float)))
        for _ in range(32):
            rem = budget
            vals = {}
            order = list(slots)
            rng.shuffle(order)
            ok = True
            for s in order:
                g = self.genes[self._index[s]]
                fitting = [v for v in g.choices
                           if isinstance(v, (int, float)) and v <= rem]
                if not fitting:
                    ok = False
                    break
                v = fitting[int(rng.integers(len(fitting)))]
                vals[s] = v
                rem -= v
            if ok:
                for s, v in vals.items():
                    self._set_slot(config, s, v)
                if self._check(config, c):
                    return True
        return False

    # -- neighborhood (for GA mutation / local search) -----------------------
    def mutate(self, config: dict[str, Any], rng: np.random.Generator,
               p_mut: float = 0.15) -> dict[str, Any]:
        vec = self.encode(config)
        for i, g in enumerate(self.genes):
            if rng.random() < p_mut:
                vec[i] = int(rng.integers(len(g.choices)))
        out = self.repair(self.decode(vec), rng)
        return out if self.is_valid(out) else self.sample(rng)

    def crossover(self, a: dict[str, Any], b: dict[str, Any],
                  rng: np.random.Generator) -> dict[str, Any]:
        va, vb = self.encode(a), self.encode(b)
        mask = rng.integers(0, 2, size=len(va)).astype(bool)
        child = np.where(mask, va, vb)
        out = self.repair(self.decode(child), rng)
        return out if self.is_valid(out) else self.sample(rng)


def constrained_parallelization_count(n_npus: int, dims: int = 4) -> int:
    """#(d_1..d_dims) power-of-two with product == n_npus — the paper's '286
    possible combinations' for 4 parallelization dims over 1024 NPUs."""
    k = int(math.log2(n_npus))
    return math.comb(k + dims - 1, dims - 1)
