"""Learned performance surrogates over eval-store corpora (CubicML-style).

Every agent in the repo pays one simulation per design point, which caps a
campaign at ~10^3-10^4 evaluations.  This module is the other half of the
trade: a cheap learned predictor of the reward surface, trained on the
(design point -> reward) corpora the eval stores already accumulate, that
can screen 10^4-10^5 candidate configurations per generation so only the
most promising slice pays a true simulation.

Three layers:

* **Featurization** — ``Featurizer`` turns a ``DesignSpace`` into a
  deterministic, signature-stable vector encoding: numeric knobs whose
  choice sets span a multiplicative range (parallelism degrees, NPUs per
  dim, chunks, bandwidths) are log2-scaled then min-max normalized over
  their declared choices; other numeric knobs are min-max normalized
  linearly; categorical knobs are one-hot over the PsA choice tuple.
  Scenario/engine/fleet stack parameters contribute features only when
  searched — pinned parameters have no genes, so they never leak into the
  encoding.  ``feature_signature()`` hashes the schema; datasets record it
  and every consumer checks it, so a corpus built for a different design
  space fails loudly instead of silently misfeaturizing.

* **Dataset building** — ``build_dataset`` ingests (config, reward)
  records from any source; ``store_records`` reads the JSONL persistent
  eval stores (``repro.core.study.PersistentEvalStore`` files, keyed by
  ``StudySpec.eval_signature()``, torn-tail tolerant) and
  ``env_store_records`` reads a live in-memory ``CosmicEnv.eval_store``.

* **Predictors** — ``SURROGATE_REGISTRY`` holds small, pure-numpy, seeded
  models with a common fit/predict/uncertainty surface:
  ``ridge`` (random-Fourier-feature ridge regression with a Bayesian
  predictive variance) and ``knn`` (distance-weighted k-nearest-neighbour —
  the tree-free bagging alternative).  ``holdout_fidelity`` reports how
  well a model ranks unseen design points (Spearman rank correlation,
  top-k recall) — the number that decides whether a surrogate is safe to
  screen with.

The search-side consumer is ``repro.core.agents.surrogate``.
"""
from __future__ import annotations

import hashlib
import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

from repro.core.space import DesignSpace

# ---------------------------------------------------------------------------
# Featurization
# ---------------------------------------------------------------------------

# numeric choice sets spanning at least this multiplicative range are
# log2-scaled (parallelism degrees, npus/bandwidth per dim, chunk counts);
# narrower ones (fractions, small enums) stay linear
_LOG_SCALE_RATIO = 8.0


def _gene_encoding(choices: tuple) -> tuple[str, int]:
    """(kind, width) for one gene's choice tuple.  kind: 'log2' | 'linear'
    | 'onehot' | 'const' (single choice — zero-width, schema-recorded)."""
    if len(choices) == 1:
        return "const", 0
    numeric = all(isinstance(v, (int, float)) and not isinstance(v, bool)
                  for v in choices)
    if not numeric:
        return "onehot", len(choices)
    vals = [float(v) for v in choices]
    if min(vals) > 0 and max(vals) / min(vals) >= _LOG_SCALE_RATIO:
        return "log2", 1
    return "linear", 1


class Featurizer:
    """Deterministic design-point -> feature-vector encoding for one
    ``DesignSpace``.  The encoding depends only on the space's gene list
    (slot order, choice tuples), so two processes building a Featurizer
    from equal ParameterSets produce identical vectors and signatures."""

    def __init__(self, space: DesignSpace,
                 expect_signature: "str | None" = None):
        self.space = space
        self._tables: list[np.ndarray] = []   # per gene: (n_choices, width)
        self.feature_names: list[str] = []
        schema: list[list] = []
        for g in space.genes:
            kind, width = _gene_encoding(g.choices)
            schema.append([g.slot, [str(v) for v in g.choices], kind])
            if kind == "onehot":
                tab = np.eye(len(g.choices))
                self.feature_names.extend(f"{g.slot}={v}" for v in g.choices)
            elif kind == "const":
                tab = np.zeros((len(g.choices), 0))
            else:
                vals = np.array([float(v) for v in g.choices])
                if kind == "log2":
                    vals = np.log2(vals)
                lo, hi = vals.min(), vals.max()
                tab = ((vals - lo) / (hi - lo))[:, None]
                self.feature_names.append(f"{g.slot}:{kind}")
            self._tables.append(tab)
        self._schema = schema
        self.signature = hashlib.sha256(
            json.dumps(schema, separators=(",", ":")).encode()
        ).hexdigest()[:16]
        if expect_signature is not None and expect_signature != self.signature:
            raise ValueError(
                f"feature-signature mismatch: this design space encodes as "
                f"{self.signature}, expected {expect_signature} — the "
                f"corpus was built for a different ParameterSet (changed "
                f"choices, pins, or scenario knobs)")
        self._offsets = np.cumsum([0] + [t.shape[1] for t in self._tables])
        self.n_features = int(self._offsets[-1])

    def feature_signature(self) -> str:
        return self.signature

    # -- encoding ---------------------------------------------------------
    def featurize_vecs(self, vecs: np.ndarray) -> np.ndarray:
        """(n, n_genes) index matrix -> (n, n_features) float matrix, fully
        vectorized (one gather per gene) — the screening-pool hot path."""
        vecs = np.asarray(vecs, dtype=np.int64)
        out = np.empty((vecs.shape[0], self.n_features))
        for i, tab in enumerate(self._tables):
            if tab.shape[1]:
                out[:, self._offsets[i]:self._offsets[i + 1]] = \
                    tab[vecs[:, i]]
        return out

    def featurize_configs(self,
                          configs: Sequence[Mapping[str, Any]]) -> np.ndarray:
        """Config dicts -> (n, n_features).  A config holding a value the
        schema has never seen (a different design space) fails loudly with
        the signature in the message."""
        vecs = np.empty((len(configs), len(self.space.genes)), dtype=np.int64)
        for r, cfg in enumerate(configs):
            try:
                vecs[r] = self.space.encode(dict(cfg))
            except KeyError as e:
                raise ValueError(
                    f"config cannot be featurized under schema "
                    f"{self.signature}: value/parameter {e} is not in this "
                    f"design space's choices — the record was built for a "
                    f"different ParameterSet") from None
        return self.featurize_vecs(vecs)

    def featurize(self, config: Mapping[str, Any]) -> np.ndarray:
        return self.featurize_configs([config])[0]


# ---------------------------------------------------------------------------
# Dataset building — in-memory env stores + JSONL persistent stores
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SurrogateDataset:
    """A featurized (design point -> reward) corpus, stamped with the
    feature schema it was encoded under."""
    X: np.ndarray
    y: np.ndarray
    configs: tuple
    feature_signature: str

    @property
    def n(self) -> int:
        return len(self.y)


def build_dataset(featurizer: Featurizer,
                  records: Iterable[tuple[Mapping[str, Any], float]]
                  ) -> SurrogateDataset:
    """Featurize (config, reward) records into a training corpus."""
    records = list(records)
    configs = tuple(dict(cfg) for cfg, _ in records)
    X = featurizer.featurize_configs(configs) if records \
        else np.zeros((0, featurizer.n_features))
    y = np.array([float(r) for _, r in records])
    return SurrogateDataset(X=X, y=y, configs=configs,
                            feature_signature=featurizer.signature)


def _freeze_value(v: Any) -> Any:
    return tuple(_freeze_value(x) for x in v) if isinstance(v, list) else v


def store_records(path: "str | Path", signature: "str | None" = None
                  ) -> list[tuple[dict[str, Any], float]]:
    """(config, reward) records from a JSONL persistent eval store
    (``PersistentEvalStore`` format), filtered to one
    ``StudySpec.eval_signature()`` when given.  Torn tails and malformed
    lines are skipped — the store is a cache, not a ledger.  JSON lists in
    configs are re-frozen to tuples so records round-trip through
    ``DesignSpace.encode``."""
    from repro.core.study import iter_jsonl_lenient

    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"eval store {path} does not exist")
    out: list[tuple[dict[str, Any], float]] = []
    for rec in iter_jsonl_lenient(path):
        cfg = rec.get("config")
        if not isinstance(cfg, dict) or "reward" not in rec:
            continue
        if signature is not None and rec.get("sig") != signature:
            continue
        out.append(({k: _freeze_value(v) for k, v in cfg.items()},
                    float(rec["reward"])))
    return out


def env_store_records(store: Mapping[tuple, Any]
                      ) -> list[tuple[dict[str, Any], float]]:
    """(config, reward) records from a live in-memory eval store — either a
    shared ``CosmicEnv.eval_store`` (keys ``(env_signature, config_pairs)``)
    or a private memo (keys are the bare config pairs)."""
    out: list[tuple[dict[str, Any], float]] = []
    for key, ev in store.items():
        pairs = key
        if len(key) == 2 and not _looks_like_pairs(key):
            pairs = key[1]
        if not _looks_like_pairs(pairs):
            continue
        out.append((dict(pairs), float(ev.reward)))
    return out


def _looks_like_pairs(obj: Any) -> bool:
    return isinstance(obj, tuple) and len(obj) > 0 and all(
        isinstance(p, tuple) and len(p) == 2 and isinstance(p[0], str)
        for p in obj)


# ---------------------------------------------------------------------------
# Predictors
# ---------------------------------------------------------------------------

def _log_transform(y: np.ndarray) -> np.ndarray:
    """Rewards span orders of magnitude (and invalid points are exactly 0):
    fit in log space so ranking isn't dominated by the heavy tail.  The
    transform is monotone, so predicted scores stay rank-faithful to the
    raw reward."""
    return np.log(np.maximum(y, 0.0) + 1e-12)


class RidgeRFF:
    """Ridge regression on random Fourier features — a linear-cost GP
    stand-in.  fit: O(n·D + D^3) for D random features; predict gives a
    Bayesian predictive mean and epistemic std.  Seeded: the random feature
    bank is a pure function of (seed, n_features, lengthscale)."""

    name = "ridge"

    def __init__(self, seed: int = 0, n_features: int = 256,
                 lengthscale: "float | None" = None, l2: float = 1e-2,
                 log_target: bool = True):
        self.seed = seed
        self.n_features = n_features
        self.lengthscale = lengthscale
        self.l2 = l2
        self.log_target = log_target
        self._fitted = False

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RidgeRFF":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        t = _log_transform(y) if self.log_target else y
        self._xm = X.mean(axis=0)
        xs = X.std(axis=0)
        xs[xs == 0] = 1.0
        self._xs = xs
        self._tm, ts = t.mean(), t.std()
        self._ts = ts if ts > 0 else 1.0
        rng = np.random.default_rng(self.seed)
        d = self.n_features
        # default lengthscale ~ sqrt(dim): standardized points sit ~sqrt(2d)
        # apart, so a unit lengthscale would see every pair as infinitely
        # far and the kernel would flatline
        ls = self.lengthscale if self.lengthscale is not None \
            else math.sqrt(max(X.shape[1], 1))
        self._W = rng.normal(0.0, 1.0 / ls, (X.shape[1], d))
        self._b = rng.uniform(0.0, 2.0 * math.pi, d)
        phi = self._phi(X)
        tn = (t - self._tm) / self._ts
        A = phi.T @ phi + self.l2 * np.eye(d)
        self._L = np.linalg.cholesky(A)
        self._alpha = np.linalg.solve(
            self._L.T, np.linalg.solve(self._L, phi.T @ tn))
        resid = phi @ self._alpha - tn
        self._sigma2 = float(resid @ resid) / max(len(tn), 1) + 1e-6
        self._fitted = True
        return self

    def _phi(self, X: np.ndarray) -> np.ndarray:
        Z = (np.asarray(X, dtype=np.float64) - self._xm) / self._xs
        return math.sqrt(2.0 / self.n_features) * np.cos(Z @ self._W + self._b)

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) per row.  Scores live in the model's (transformed,
        standardized-then-unstandardized) target space — monotone in the
        raw reward, which is all screening and rank fidelity need."""
        assert self._fitted, "predict() before fit()"
        out_m = np.empty(len(X))
        out_s = np.empty(len(X))
        for lo in range(0, len(X), 16384):   # bound memory on 10^5 pools
            phi = self._phi(X[lo:lo + 16384])
            out_m[lo:lo + 16384] = phi @ self._alpha * self._ts + self._tm
            v = np.linalg.solve(self._L, phi.T)
            out_s[lo:lo + 16384] = self._ts * np.sqrt(
                self._sigma2 * np.maximum((v * v).sum(axis=0), 1e-12))
        return out_m, out_s


class KNNSurrogate:
    """Distance-weighted k-nearest-neighbour with ARD feature relevance —
    the assumption-free alternative (no linearity, no feature bank).  Each
    feature dimension is scaled by its |Spearman| correlation with the
    target on the training set, so distances concentrate on the knobs that
    actually move the reward (in a ~45-dim one-hot-heavy encoding, an
    unweighted metric drowns the 3-4 load-bearing knobs in categorical
    noise — measured ρ 0.20 → 0.65+ on a 10^3-point gpt3-13b corpus).
    Default target is the in-corpus reward RANK: monotone (so screening
    order is unchanged) and immune to the reward's heavy tail + the
    invalid-point mass at exactly 0.  std is a heuristic: neighbour
    disagreement plus a distance term, so far-from-data candidates read as
    uncertain."""

    name = "knn"

    def __init__(self, seed: int = 0, k: int = 8, target: str = "rank",
                 ard: bool = True):
        self.seed = seed     # unused (deterministic), kept for the registry
        self.k = k
        assert target in ("rank", "log", "raw"), target
        self.target = target
        self.ard = ard
        self._fitted = False

    def fit(self, X: np.ndarray, y: np.ndarray) -> "KNNSurrogate":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self._xm = X.mean(axis=0)
        xs = X.std(axis=0)
        xs[xs == 0] = 1.0
        self._xs = xs
        Z = (X - self._xm) / xs
        t = {"rank": lambda v: _rankdata(v), "log": _log_transform,
             "raw": lambda v: v}[self.target](y)
        if self.ard and len(y) >= 8:
            # + a floor so a zero-relevance feature still breaks distance
            # ties (and an early small-corpus fit isn't all floor)
            w = np.array([abs(spearman(Z[:, j], t)) if xs0 > 0 else 0.0
                          for j, xs0 in enumerate(Z.std(axis=0))])
            w = np.where(np.isnan(w), 0.0, w) + 0.02
        else:
            w = np.ones(X.shape[1])
        self._w = w
        self._X = Z * w
        self._x2 = (self._X * self._X).sum(axis=1)
        self._t = t
        self._tstd = float(self._t.std()) or 1.0
        self._fitted = True
        return self

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        assert self._fitted, "predict() before fit()"
        k = min(self.k, len(self._t))
        out_m = np.empty(len(X))
        out_s = np.empty(len(X))
        for lo in range(0, len(X), 4096):
            Z = ((np.asarray(X[lo:lo + 4096], dtype=np.float64)
                  - self._xm) / self._xs) * self._w
            # |a-b|^2 via the matmul identity — O(chunk x train) memory,
            # never the 3-D broadcast (that's GBs on a 10^4 screening pool)
            d2 = ((Z * Z).sum(axis=1)[:, None] + self._x2[None, :]
                  - 2.0 * (Z @ self._X.T))
            np.maximum(d2, 0.0, out=d2)
            idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
            dk = np.sqrt(np.take_along_axis(d2, idx, axis=1))
            w = 1.0 / (dk + 1e-6)
            w /= w.sum(axis=1, keepdims=True)
            tk = self._t[idx]
            mean = (w * tk).sum(axis=1)
            var = (w * (tk - mean[:, None]) ** 2).sum(axis=1)
            out_m[lo:lo + 4096] = mean
            out_s[lo:lo + 4096] = np.sqrt(var) \
                + dk.mean(axis=1) * 0.1 * self._tstd
        return out_m, out_s


SURROGATE_REGISTRY: dict[str, Callable[..., Any]] = {
    "ridge": RidgeRFF,
    "knn": KNNSurrogate,
}


def make_surrogate(name: str, seed: int = 0, **kw) -> Any:
    if name not in SURROGATE_REGISTRY:
        raise ValueError(f"unknown surrogate model {name!r}; "
                         f"known: {sorted(SURROGATE_REGISTRY)}")
    return SURROGATE_REGISTRY[name](seed=seed, **kw)


def list_surrogates() -> dict[str, str]:
    return {name: (cls.__doc__ or "").strip().splitlines()[0]
            for name, cls in SURROGATE_REGISTRY.items()}


# ---------------------------------------------------------------------------
# Fidelity reporting
# ---------------------------------------------------------------------------

def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties share their mean rank) — enough Spearman
    machinery to stay scipy-free."""
    x = np.asarray(x, dtype=np.float64)
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x))
    ranks[order] = np.arange(1, len(x) + 1, dtype=np.float64)
    xs = x[order]
    i = 0
    while i < len(xs):
        j = i
        while j + 1 < len(xs) and xs[j + 1] == xs[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation (Pearson over average ranks)."""
    if len(a) < 2:
        return float("nan")
    ra, rb = _rankdata(a), _rankdata(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = math.sqrt(float(ra @ ra) * float(rb @ rb))
    return float(ra @ rb) / denom if denom > 0 else float("nan")


def holdout_fidelity(model_name: str, X: np.ndarray, y: np.ndarray, *,
                     holdout_frac: float = 0.25, top_frac: float = 0.1,
                     seed: int = 0, **model_kw) -> dict[str, Any]:
    """Fit on a shuffled train split, score the held-out rest: Spearman
    rank correlation between predicted score and true reward, plus top-k
    recall (fraction of the holdout's true top-k the predictor also ranks
    top-k — the quantity screening actually relies on)."""
    X = np.asarray(X)
    y = np.asarray(y, dtype=np.float64)
    n = len(y)
    if n < 8:
        raise ValueError(f"fidelity report needs >= 8 points, got {n}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_hold = max(2, int(round(holdout_frac * n)))
    hold, train = perm[:n_hold], perm[n_hold:]
    model = make_surrogate(model_name, seed=seed, **model_kw)
    model.fit(X[train], y[train])
    pred, _ = model.predict(X[hold])
    rho = spearman(pred, y[hold])
    k = max(1, int(round(top_frac * n_hold)))
    true_top = set(np.argsort(-y[hold], kind="stable")[:k].tolist())
    pred_top = set(np.argsort(-pred, kind="stable")[:k].tolist())
    return {"model": model_name, "n_train": int(len(train)),
            "n_holdout": int(n_hold), "spearman": rho,
            "top_k": k, "topk_recall": len(true_top & pred_top) / k}
