"""Optimization objectives (paper Section 5.4).

Both rewards regularize raw ML latency by a network-resource denominator so
the agent can't just buy infinite bandwidth:

  reward_bw   = 1 / sqrt((Latency * sum(BW per dim) - 1)^2)
  reward_cost = 1 / sqrt((Latency * NetworkCost    - 1)^2)

(the paper's minus-one offset avoids division blow-ups on degenerate
configs).  A memory footprint above the capacity gate makes the design
invalid: reward 0.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.memory import footprint
from repro.core.simulator import SimResult, SystemConfig, simulate
from repro.core.topology import Network
from repro.core.workload import Parallelism, Trace, generate_trace


@dataclass(frozen=True)
class Evaluation:
    """One design point's outcome.  Frozen because the env's evaluation memo
    hands the same instance to every duplicate design point; treat `detail`
    as read-only too."""
    reward: float
    latency_ms: float
    valid: bool
    detail: dict[str, Any]


def reward_perf_per_bw(latency_ms: float, net: Network) -> float:
    x = latency_ms * net.bw_per_npu() - 1.0
    return 1.0 / math.sqrt(x * x + 1e-12)


def reward_perf_per_cost(latency_ms: float, net: Network) -> float:
    x = latency_ms * (net.dollar_cost() / 1e6) - 1.0
    return 1.0 / math.sqrt(x * x + 1e-12)


def reward_latency(latency_ms: float, net: Network) -> float:
    return 1.0 / max(latency_ms, 1e-9)


REWARDS: dict[str, Callable[[float, Network], float]] = {
    "perf_per_bw": reward_perf_per_bw,
    "perf_per_cost": reward_perf_per_cost,
    "latency": reward_latency,
}


def slo_attainment(latency_ms: float, slo_ms: float) -> float:
    """Soft SLO attainment in [0, 1]: 1 when the latency meets the SLO,
    degrading proportionally when it misses (multi-tenant objective)."""
    if latency_ms <= 0 or math.isinf(latency_ms):
        return 0.0
    return min(1.0, slo_ms / latency_ms)


# ---------------------------------------------------------------------------
# Streaming (request-stream serving) objectives
# ---------------------------------------------------------------------------

# objectives a streaming scenario resolves itself instead of through REWARDS
# (their reward is a function of per-request metrics, not one latency)
STREAM_OBJECTIVES = ("goodput",)


def percentile(values: list[float], p: float) -> float:
    """Numpy's default linear-interpolated percentile over a per-request
    metric list; 0.0 on empty input (np.percentile raises there)."""
    if not values:
        return 0.0
    return float(np.percentile(values, p))


@dataclass(frozen=True)
class StreamMetrics:
    """Per-request serving metrics aggregated over one simulated request
    stream: time-to-first-token and time-per-output-token percentiles, plus
    goodput — requests meeting BOTH SLOs, per second of simulated horizon."""
    n_requests: int
    n_ok: int
    ttft_p50_ms: float
    ttft_p99_ms: float
    tpot_p50_ms: float
    tpot_p99_ms: float
    latency_p99_ms: float       # end-to-end (arrival -> last token)
    goodput_rps: float
    horizon_ms: float

    def detail(self) -> dict[str, float]:
        return {
            "n_requests": self.n_requests, "n_ok": self.n_ok,
            "ttft_p50_ms": self.ttft_p50_ms, "ttft_p99_ms": self.ttft_p99_ms,
            "tpot_p50_ms": self.tpot_p50_ms, "tpot_p99_ms": self.tpot_p99_ms,
            "latency_p99_ms": self.latency_p99_ms,
            "goodput_rps": self.goodput_rps, "horizon_ms": self.horizon_ms,
        }


def stream_metrics(ttft_ms: list[float], tpot_ms: list[float],
                   latency_ms: list[float], *, ttft_slo_ms: float,
                   tpot_slo_ms: float, horizon_ms: float) -> StreamMetrics:
    """Aggregate per-request TTFT/TPOT/e2e-latency lists into percentiles
    and SLO goodput.  ``horizon_ms`` is the simulated span the goodput rate
    is normalized over (last completion or last arrival, whichever later)."""
    n_ok = sum(1 for t, p in zip(ttft_ms, tpot_ms)
               if t <= ttft_slo_ms and p <= tpot_slo_ms)
    return StreamMetrics(
        n_requests=len(ttft_ms), n_ok=n_ok,
        ttft_p50_ms=percentile(ttft_ms, 50), ttft_p99_ms=percentile(ttft_ms, 99),
        tpot_p50_ms=percentile(tpot_ms, 50), tpot_p99_ms=percentile(tpot_ms, 99),
        latency_p99_ms=percentile(latency_ms, 99),
        goodput_rps=n_ok / max(horizon_ms / 1e3, 1e-9),
        horizon_ms=horizon_ms,
    )


def stream_reward(objective: str, metrics: StreamMetrics,
                  net: Network) -> float:
    """Resolve a streaming scenario's reward: ``goodput`` maximizes SLO-
    meeting requests/sec; any ``REWARDS`` objective is applied to the p99
    end-to-end request latency (so e.g. ``perf_per_cost`` still regularizes
    by the network spend)."""
    if objective == "goodput":
        return metrics.goodput_rps
    return REWARDS[objective](metrics.latency_p99_ms, net)


def evaluate(spec: ArchSpec, par: Parallelism, cfg: SystemConfig, *,
             batch: int, seq: int, mode: str = "train",
             objective: str = "perf_per_bw",
             capacity_gb: float = 24.0, decode_tokens: int = 64) -> Evaluation:
    """Full paper pipeline: WTG -> simulate -> reward (+ memory gate)."""
    if not par.valid():
        return Evaluation(0.0, float("inf"), False, {"why": "parallelization invalid"})
    fp = footprint(spec, par, batch=batch, seq=seq, mode=mode)
    if fp.total_gb > capacity_gb:
        return Evaluation(0.0, float("inf"), False,
                          {"why": f"memory {fp.total_gb:.1f}GB > {capacity_gb}GB"})
    if mode == "serve":
        # prefill the prompt once + decode `decode_tokens` new tokens
        pre = simulate(generate_trace(spec, par, batch=batch, seq=seq,
                                      mode="inference"), cfg, par)
        dec = simulate(generate_trace(spec, par, batch=batch, seq=seq,
                                      mode="decode"), cfg, par)
        latency_ms = pre.latency_ms + decode_tokens * dec.latency_ms
        r = REWARDS[objective](latency_ms, cfg.network)
        return Evaluation(r, latency_ms, True, {
            "footprint_gb": fp.total_gb,
            "prefill_ms": pre.latency_ms, "decode_ms": dec.latency_ms,
        })
    trace = generate_trace(spec, par, batch=batch, seq=seq, mode=mode)
    res = simulate(trace, cfg, par)
    r = REWARDS[objective](res.latency_ms, cfg.network)
    return Evaluation(r, res.latency_ms, True, {
        "footprint_gb": fp.total_gb,
        "exposed_comm_us": res.exposed_comm_us,
        "compute_busy_us": res.compute_busy_us,
        "comm_busy_us": res.comm_busy_us,
    })
