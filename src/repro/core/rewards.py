"""Optimization objectives (paper Section 5.4).

Both rewards regularize raw ML latency by a network-resource denominator so
the agent can't just buy infinite bandwidth:

  reward_bw   = 1 / sqrt((Latency * sum(BW per dim) - 1)^2)
  reward_cost = 1 / sqrt((Latency * NetworkCost    - 1)^2)

(the paper's minus-one offset avoids division blow-ups on degenerate
configs).  A memory footprint above the capacity gate makes the design
invalid: reward 0.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.backends import SimCall, SimJob, run_sim_job
from repro.core.memory import footprint
from repro.core.simulator import SimResult, SystemConfig
from repro.core.topology import Network
from repro.core.workload import Parallelism, Trace, generate_trace


@dataclass(frozen=True)
class Evaluation:
    """One design point's outcome.  Frozen because the env's evaluation memo
    hands the same instance to every duplicate design point; treat `detail`
    as read-only too."""
    reward: float
    latency_ms: float
    valid: bool
    detail: dict[str, Any]


def reward_perf_per_bw(latency_ms: float, net: Network) -> float:
    x = latency_ms * net.bw_per_npu() - 1.0
    return 1.0 / math.sqrt(x * x + 1e-12)


def reward_perf_per_cost(latency_ms: float, net: Network) -> float:
    x = latency_ms * (net.dollar_cost() / 1e6) - 1.0
    return 1.0 / math.sqrt(x * x + 1e-12)


def reward_latency(latency_ms: float, net: Network) -> float:
    return 1.0 / max(latency_ms, 1e-9)


REWARDS: dict[str, Callable[[float, Network], float]] = {
    "perf_per_bw": reward_perf_per_bw,
    "perf_per_cost": reward_perf_per_cost,
    "latency": reward_latency,
}


# ---------------------------------------------------------------------------
# Objective registry — first-class objective objects
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Objective:
    """A first-class optimization objective, resolvable by name.

    ``scalar_fn`` rewards one end-to-end latency (every scenario has one);
    ``stream_fn`` rewards per-request ``StreamMetrics`` and is only
    satisfiable by streaming scenarios.  An objective with ONLY a
    ``stream_fn`` is *streaming-required* and is rejected at env/spec
    construction for scenarios that can't produce per-request metrics.

    Composite objectives (latency x cost, goodput per dollar, ...) are just
    new ``Objective`` instances registered with ``register_objective`` —
    neither the env nor the scenarios need to change.  Functions should be
    module-level so envs stay picklable for the process pool."""
    name: str
    scalar_fn: Callable[[float, Network], float] | None = None
    stream_fn: Callable[["StreamMetrics", Network], float] | None = None
    doc: str = ""

    def __post_init__(self):
        if self.scalar_fn is None and self.stream_fn is None:
            raise ValueError(f"objective {self.name!r} needs a scalar_fn "
                             f"and/or a stream_fn")

    @property
    def streaming(self) -> bool:
        """True when this objective REQUIRES per-request stream metrics."""
        return self.scalar_fn is None

    def scalar(self, latency_ms: float, net: Network) -> float:
        if self.scalar_fn is None:
            raise ValueError(f"objective {self.name!r} has no scalar form — "
                             f"it needs a streaming scenario")
        return self.scalar_fn(latency_ms, net)

    def stream(self, metrics: "StreamMetrics", net: Network) -> float:
        """Reward for per-request metrics; scalar-only objectives apply to
        the p99 end-to-end request latency (so e.g. ``perf_per_cost`` still
        regularizes by the network spend)."""
        if self.stream_fn is not None:
            return self.stream_fn(metrics, net)
        return self.scalar_fn(metrics.latency_p99_ms, net)


OBJECTIVES: dict[str, Objective] = {}


def register_objective(obj: Objective, *, replace: bool = False) -> Objective:
    if not replace and obj.name in OBJECTIVES:
        raise ValueError(f"objective {obj.name!r} already registered")
    OBJECTIVES[obj.name] = obj
    return obj


def get_objective(objective: "str | Objective") -> Objective:
    """Resolve an objective by name (or pass an ``Objective`` through —
    ad-hoc composites don't have to be registered)."""
    if isinstance(objective, Objective):
        return objective
    try:
        return OBJECTIVES[objective]
    except KeyError:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"known: {sorted(OBJECTIVES)}") from None


def list_objectives() -> dict[str, Objective]:
    return dict(OBJECTIVES)


def slo_attainment(latency_ms: float, slo_ms: float) -> float:
    """Soft SLO attainment in [0, 1]: 1 when the latency meets the SLO,
    degrading proportionally when it misses (multi-tenant objective)."""
    if latency_ms <= 0 or math.isinf(latency_ms):
        return 0.0
    return min(1.0, slo_ms / latency_ms)


# ---------------------------------------------------------------------------
# Streaming (request-stream serving) objectives
# ---------------------------------------------------------------------------

# kept as a compat alias; the source of truth is Objective.streaming
# (an objective whose reward is a function of per-request metrics, not one
# latency).  Derived after the built-in registrations below.
STREAM_OBJECTIVES: tuple[str, ...] = ()


def percentile(values: "list[float] | np.ndarray", p: float) -> float:
    """Numpy's default linear-interpolated percentile over a per-request
    metric list; 0.0 on empty input (np.percentile raises there)."""
    if len(values) == 0:
        return 0.0
    return float(np.percentile(values, p))


@dataclass(frozen=True)
class StreamMetrics:
    """Per-request serving metrics aggregated over one simulated request
    stream: time-to-first-token and time-per-output-token percentiles, plus
    goodput — requests meeting BOTH SLOs, per second of simulated horizon."""
    n_requests: int
    n_ok: int
    ttft_p50_ms: float
    ttft_p99_ms: float
    tpot_p50_ms: float
    tpot_p99_ms: float
    latency_p99_ms: float       # end-to-end (arrival -> last token)
    goodput_rps: float
    horizon_ms: float
    # dollars of capacity actually provisioned over the horizon (the fleet
    # layer sums replica-seconds per autoscaler decisions); None means a
    # single statically-provisioned network — cost objectives then fall
    # back to ``net.dollar_cost()``, bit-identical to the pre-fleet path
    provisioned_cost: float | None = None

    def detail(self) -> dict[str, float]:
        d = {
            "n_requests": self.n_requests, "n_ok": self.n_ok,
            "ttft_p50_ms": self.ttft_p50_ms, "ttft_p99_ms": self.ttft_p99_ms,
            "tpot_p50_ms": self.tpot_p50_ms, "tpot_p99_ms": self.tpot_p99_ms,
            "latency_p99_ms": self.latency_p99_ms,
            "goodput_rps": self.goodput_rps, "horizon_ms": self.horizon_ms,
        }
        if self.provisioned_cost is not None:
            d["provisioned_cost"] = self.provisioned_cost
        return d


def stream_metrics(ttft_ms: "list[float] | np.ndarray",
                   tpot_ms: "list[float] | np.ndarray",
                   latency_ms: "list[float] | np.ndarray", *,
                   ttft_slo_ms: float, tpot_slo_ms: float,
                   horizon_ms: float) -> StreamMetrics:
    """Aggregate per-request TTFT/TPOT/e2e-latency lists into percentiles
    and SLO goodput.  ``horizon_ms`` is the simulated span the goodput rate
    is normalized over (last completion or last arrival, whichever later).

    Accepts lists or numpy arrays; the SLO count and the paired p50/p99
    reads are vectorized (one ``np.percentile`` call per metric — the same
    linear interpolation per q as separate calls, so values are unchanged)."""
    ttft = np.asarray(ttft_ms, dtype=np.float64)
    tpot = np.asarray(tpot_ms, dtype=np.float64)
    lat = np.asarray(latency_ms, dtype=np.float64)
    n_ok = int(np.count_nonzero((ttft <= ttft_slo_ms)
                                & (tpot <= tpot_slo_ms)))
    t50, t99 = (np.percentile(ttft, (50, 99)) if len(ttft) else (0.0, 0.0))
    p50, p99 = (np.percentile(tpot, (50, 99)) if len(tpot) else (0.0, 0.0))
    return StreamMetrics(
        n_requests=len(ttft), n_ok=n_ok,
        ttft_p50_ms=float(t50), ttft_p99_ms=float(t99),
        tpot_p50_ms=float(p50), tpot_p99_ms=float(p99),
        latency_p99_ms=percentile(lat, 99),
        goodput_rps=n_ok / max(horizon_ms / 1e3, 1e-9),
        horizon_ms=horizon_ms,
    )


def stream_reward(objective: "str | Objective", metrics: StreamMetrics,
                  net: Network) -> float:
    """Resolve a streaming scenario's reward: ``goodput`` maximizes SLO-
    meeting requests/sec; any scalar objective is applied to the p99
    end-to-end request latency (so e.g. ``perf_per_cost`` still regularizes
    by the network spend)."""
    return get_objective(objective).stream(metrics, net)


def reward_goodput(metrics: StreamMetrics, net: Network) -> float:
    return metrics.goodput_rps


def serving_cost(metrics: StreamMetrics, net: Network) -> float:
    """The dollar denominator for cost-normalized streaming objectives.
    Fleet scenarios price the replica-seconds actually provisioned by the
    autoscaler (``metrics.provisioned_cost``); single-engine scenarios have
    no fleet layer and pay the static network cost."""
    if metrics.provisioned_cost is not None:
        return metrics.provisioned_cost
    return net.dollar_cost()


def reward_goodput_per_cost(metrics: StreamMetrics, net: Network) -> float:
    """Composite example: SLO-meeting requests/sec per million dollars of
    provisioned capacity — extensible objectives never touch the env or
    the scenarios."""
    return metrics.goodput_rps / max(serving_cost(metrics, net) / 1e6, 1e-9)


def reward_goodput_per_dollar(metrics: StreamMetrics, net: Network) -> float:
    """The fleet-first-class form of goodput-per-cost: with an autoscaler,
    replicas scaled down during traffic troughs stop costing, so the
    denominator tracks provisioned replica-seconds rather than one static
    ``Network`` price.  Identical to ``goodput_per_cost`` arithmetic — the
    distinction is semantic intent (fleet studies name this one)."""
    return reward_goodput_per_cost(metrics, net)


register_objective(Objective("perf_per_bw", scalar_fn=reward_perf_per_bw,
                             doc="1/|latency * BW-per-NPU - 1| (paper 5.4)"))
register_objective(Objective("perf_per_cost", scalar_fn=reward_perf_per_cost,
                             doc="1/|latency * network-$ - 1| (paper 5.4)"))
register_objective(Objective("latency", scalar_fn=reward_latency,
                             doc="1/latency — raw end-to-end speed"))
register_objective(Objective("goodput", stream_fn=reward_goodput,
                             doc="SLO-meeting requests/sec (streaming only)"))
register_objective(Objective(
    "goodput_per_cost", stream_fn=reward_goodput_per_cost,
    doc="SLO goodput per network $M (streaming only, composite)"))
register_objective(Objective(
    "goodput_per_dollar", stream_fn=reward_goodput_per_dollar,
    doc="SLO goodput per provisioned $M — autoscaler-aware (fleet)"))

STREAM_OBJECTIVES = tuple(n for n, o in OBJECTIVES.items() if o.streaming)


def evaluate_job(spec: ArchSpec, par: Parallelism, cfg: SystemConfig, *,
                 batch: int, seq: int, mode: str = "train",
                 objective: "str | Objective" = "perf_per_bw",
                 capacity_gb: float = 24.0,
                 decode_tokens: int = 64) -> "SimJob | Evaluation":
    """The paper pipeline as a declarative ``SimJob``: validity/memory gates
    resolve immediately to an ``Evaluation``; surviving points return the
    simulator calls plus the reward-finalization closure, executable on any
    simulation backend (and batchable across an agent population)."""
    obj = get_objective(objective)
    if not par.valid():
        return Evaluation(0.0, float("inf"), False, {"why": "parallelization invalid"})
    fp = footprint(spec, par, batch=batch, seq=seq, mode=mode)
    if fp.total_gb > capacity_gb:
        return Evaluation(0.0, float("inf"), False,
                          {"why": f"memory {fp.total_gb:.1f}GB > {capacity_gb}GB"})
    if mode == "serve":
        # prefill the prompt once + decode `decode_tokens` new tokens
        pre_tr = generate_trace(spec, par, batch=batch, seq=seq,
                                mode="inference")
        dec_tr = generate_trace(spec, par, batch=batch, seq=seq,
                                mode="decode")

        def fin_serve(results: list[SimResult]) -> Evaluation:
            pre, dec = results
            latency_ms = pre.latency_ms + decode_tokens * dec.latency_ms
            r = obj.scalar(latency_ms, cfg.network)
            return Evaluation(r, latency_ms, True, {
                "footprint_gb": fp.total_gb,
                "prefill_ms": pre.latency_ms, "decode_ms": dec.latency_ms,
            })

        return SimJob((SimCall(pre_tr, cfg, par), SimCall(dec_tr, cfg, par)),
                      fin_serve)
    trace = generate_trace(spec, par, batch=batch, seq=seq, mode=mode)

    def fin(results: list[SimResult]) -> Evaluation:
        res = results[0]
        r = obj.scalar(res.latency_ms, cfg.network)
        return Evaluation(r, res.latency_ms, True, {
            "footprint_gb": fp.total_gb,
            "exposed_comm_us": res.exposed_comm_us,
            "compute_busy_us": res.compute_busy_us,
            "comm_busy_us": res.comm_busy_us,
        })

    return SimJob((SimCall(trace, cfg, par),), fin)


def evaluate(spec: ArchSpec, par: Parallelism, cfg: SystemConfig, *,
             batch: int, seq: int, mode: str = "train",
             objective: "str | Objective" = "perf_per_bw",
             capacity_gb: float = 24.0, decode_tokens: int = 64,
             backend: "str | None" = None) -> Evaluation:
    """Full paper pipeline: WTG -> simulate -> reward (+ memory gate), on
    the selected simulation backend (default: reference)."""
    return run_sim_job(
        evaluate_job(spec, par, cfg, batch=batch, seq=seq, mode=mode,
                     objective=objective, capacity_gb=capacity_gb,
                     decode_tokens=decode_tokens), backend)
