"""Simulation front door + the design-point-independent scheduling plan.

Resources: one compute stream (roofline device model) + one communication
engine per parallelism group (tp/dp/ep/pp), each mapped onto the network
dims it spans.  Ready ops queue on their resource; the queue discipline is
the paper's Collective 'Scheduling Policy' knob (LIFO favours the freshest
— critical-path — collectives, FIFO drains in issue order).  Compute/comm
overlap falls out of the scheduler, so exposed communication is measured,
not assumed.

HOW a trace is scheduled is a swappable backend (``repro.core.backends``):
``simulate()`` below is a thin delegate onto the selected ``SimBackend``
(default: the reference discrete-event heapq loop, bit-identical to the
original in-module implementation).  This module keeps what every backend
shares — the ``SystemConfig``/``SimResult`` value objects, the per-trace
``_SimPlan`` (dependency counts, children lists, per-op resource ids,
compute-op shape arrays, built once per ``Trace`` and reused across every
design point that shares it), and the per-design-point duration pass
(numpy-vectorized roofline for compute ops, the memoized collective cost
model for comm ops with each group's sub-network resolved once per call
rather than once per op).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from repro.core.cache import switchable_lru_cache
from repro.core.collectives import (ALGO_IDS, COLL_KIND_IDS, TOPO_KIND_IDS,
                                    multidim_collective_time_us,
                                    multidim_collective_time_vec)
from repro.core.compute import Device
from repro.core.topology import Network, TopoDim, carve_dims
from repro.core.workload import Op, Parallelism, Trace


SCHED_POLICIES = ("fifo", "lifo")


@dataclass(frozen=True)
class SystemConfig:
    """The Collective + Network + Compute stacks of one design point."""
    network: Network
    device: Device
    coll_algo: tuple[str, ...]          # per network dim
    chunks: int = 1
    sched_policy: str = "fifo"          # lifo | fifo
    multidim_coll: str = "baseline"     # baseline | blueconnect
    # cross-partition transfer engine (multi-pool scenarios: KV-cache
    # handoff between disaggregated pools).  None rides the outermost —
    # scale-out — network dim's link speed.
    xfer_bw: float | None = None        # GB/s per transfer lane
    xfer_latency_us: float = 5.0

    def __post_init__(self) -> None:
        # a typo'd policy used to silently schedule as FIFO (the duration
        # pass only checked == "lifo"); fail at construction instead
        if self.sched_policy not in SCHED_POLICIES:
            raise ValueError(f"unknown sched_policy "
                             f"{self.sched_policy!r}; "
                             f"known: {SCHED_POLICIES}")


@switchable_lru_cache(maxsize=4096)
def group_dims(net: Network, par: Parallelism) -> dict[str, tuple[tuple[int, TopoDim], ...]]:
    """Map parallelism groups onto network dimensions, innermost first:
    TP gets the inner (fastest) dims, then EP(=TP group), SP, DP, PP.

    Each carved dim is returned with the physical dim index it came from
    (``carve_dims`` contract), so DP/PP collectives riding outer dims are
    priced with the collective algorithms the agent configured for THOSE
    dims — not the inner dims' algorithms.  When a group covers part of a
    dim, a virtual TopoDim with the residual group size (same kind/bw)
    approximates the sub-ring/sub-switch.  A group factor sharing no
    divisor with any dim (non-power-of-two pools from disaggregated/
    partitioned scenarios) becomes a virtual dim at the outermost —
    slowest — tier so its collectives are never free.

    Memoized on ``(net, par)`` (both frozen): populations revisit the same
    mapping thousands of times per generation.  The returned dict is shared
    across hits — treat it (and its tuple values) as immutable."""
    sizes = {"tp": par.tp, "sp": par.sp, "dp": par.dp, "pp": par.pp}
    cap = [d.npus for d in net.dims]  # consumed across groups, in order
    out: dict[str, tuple[tuple[int, TopoDim], ...]] = {
        grp: tuple(carve_dims(net.dims, cap, sizes[grp]))
        for grp in ("tp", "sp", "dp", "pp")
    }
    out["ep"] = out["tp"]  # expert-parallel collectives ride the TP group
    return out


@dataclass
class SimResult:
    makespan_us: float
    compute_busy_us: float              # pool-0 compute stream (back-compat)
    comm_busy_us: dict[str, float]
    exposed_comm_us: float
    per_op_us: dict[int, float] = field(default_factory=dict)
    pool_compute_us: dict[int, float] = field(default_factory=dict)
    # op completion times (same opt-in as per_op_us): the request-stream
    # scenario reads per-wave first-token / last-token finish times off this
    op_finish_us: dict[int, float] = field(default_factory=dict)
    # opt-in (``simulate(..., analyze=True)``): critical-path bottleneck
    # attribution over the dependency DAG — see
    # ``repro.core.analysis.CriticalPath.summary`` for the keys
    analysis: dict[str, Any] | None = None

    @property
    def latency_ms(self) -> float:
        return self.makespan_us / 1e3


@switchable_lru_cache(maxsize=16384)
def _group_net_cached(coll_algo: tuple[str, ...],
                      carved: tuple[tuple[int, TopoDim], ...],
                      ) -> tuple[Network, tuple[str, ...]] | None:
    if not carved:
        return None
    n_alg = len(coll_algo)
    algos = tuple(coll_algo[min(i, n_alg - 1)] if n_alg else "ring"
                  for i, _ in carved)
    return Network(tuple(d for _, d in carved)), algos


def _group_net(cfg: SystemConfig,
               carved: Sequence[tuple[int, TopoDim]]) -> tuple[Network, tuple[str, ...]] | None:
    """Resolve one parallelism group's sub-network + per-dim algorithms.

    ``carved`` pairs each dim with its source physical dim index, so the
    group's collectives use ``cfg.coll_algo[src_idx]`` — the algorithm the
    agent chose for that physical dim — instead of slicing from position 0
    (which handed DP/PP groups the inner dims' algorithms).  Residual
    virtual dims carry the outermost dim's index and therefore inherit its
    algorithm; indices beyond the configured tuple clamp to its last entry.

    Memoized on ``(cfg.coll_algo, carved)`` — everything else on the config
    is irrelevant to the resolution, so design points differing only in
    chunks/policy/device hit the same entry."""
    return _group_net_cached(cfg.coll_algo, tuple(carved))


@dataclass
class _SimPlan:
    """Design-point-independent scheduling structure of one trace.

    Ops carry dense uids (0..n-1 in issue order), so dependency bookkeeping
    lives in flat lists instead of dicts.  Resources are small integer ids;
    id 0 is always pool 0's compute stream.  Every pool gets its own compute
    stream and comm engines; cross-partition ``xfer`` collectives share one
    transfer resource; ``delay`` ops (arrival releases in request-stream
    traces) each get a private timer resource so they never serialize.

    Comm ops are condensed into duration CLASSES — the distinct
    ``(pool, group, coll, size)`` shapes (layers repeat shapes, so a trace
    with thousands of collectives typically has a few dozen classes): the
    per-design-point duration pass prices each class once and scatters,
    instead of walking every op through a memo dict."""
    n_ops: int
    res_names: list[str]                # per resource id: "compute" | group
    res_pool: list[int]                 # per resource id: owning pool
    res_of: list[int]                   # per op: resource id
    ndeps0: list[int]
    children: list[list[int]]
    roots: list[int]
    # every op's deps concatenated in uid order (CSR values; ``ndeps0`` is
    # the row-length vector) — the static verifier / critical-path pass
    # (``repro.core.analysis``) runs vectorized over this instead of
    # re-walking the op list
    deps_flat: np.ndarray
    comp_uids: np.ndarray
    comp_flops: np.ndarray
    comp_bytes: np.ndarray
    coll_shapes: list[tuple[int, str, str, float]]  # per class: (pool, group, coll, size)
    coll_uids: np.ndarray               # comm-op uids
    coll_class: np.ndarray              # per comm op: coll_shapes index
    coll_repeat: np.ndarray             # per comm op: back-to-back repeats
    delay_ops: list[tuple[int, float]]  # (uid, delay_us)
    pools: tuple[int, ...]
    # per-design-point packed duration tables, memoized on the plan keyed by
    # (network, coll_algo, pool entries) — see _pack_class_tables
    pack_memo: dict = field(default_factory=dict, repr=False)


def _sim_plan(trace: Trace) -> _SimPlan:
    plan = getattr(trace, "_sim_plan", None)
    if plan is not None:
        return plan
    n = len(trace.ops)
    if any(op.uid != i for i, op in enumerate(trace.ops)):
        raise ValueError("simulate() requires dense op uids (0..n-1 in list "
                         "order) — build traces with TraceBuilder")
    res_names = ["compute"]
    res_pool = [0]
    res_index: dict[tuple[int, str], int] = {(0, "compute"): 0}
    res_of = [0] * n
    ndeps0 = [0] * n
    children: list[list[int]] = [[] for _ in range(n)]
    roots: list[int] = []
    comp_idx: list[int] = []
    comp_flops: list[float] = []
    comp_bytes: list[float] = []
    class_index: dict[tuple[int, str, str, float], int] = {}
    coll_shapes: list[tuple[int, str, str, float]] = []
    coll_uids: list[int] = []
    coll_class: list[int] = []
    coll_repeat: list[int] = []
    delay_ops: list[tuple[int, float]] = []
    pools: set[int] = {0}
    deps_flat: list[int] = []

    def resource(pool: int, name: str) -> int:
        rid = res_index.get((pool, name))
        if rid is None:
            rid = len(res_names)
            res_index[(pool, name)] = rid
            res_names.append(name)
            res_pool.append(pool)
        return rid

    for op in trace.ops:
        pools.add(op.pool)
        if op.kind == "comp":
            res_of[op.uid] = resource(op.pool, "compute")
            comp_idx.append(op.uid)
            # the roofline is linear in (flops, bytes), so an op repeated
            # back-to-back k times is exactly one op scaled by k
            comp_flops.append(op.flops * op.repeat)
            comp_bytes.append(op.bytes * op.repeat)
        elif op.kind == "delay":
            # a pure time offset (request release): private resource so
            # concurrent delays never queue on each other
            res_of[op.uid] = resource(op.pool, f"_delay{op.uid}")
            delay_ops.append((op.uid, op.delay_us))
        else:
            # the transfer engine bridges partitions: one shared resource
            pool = 0 if op.group == "xfer" else op.pool
            res_of[op.uid] = resource(pool, op.group)
            key = (op.pool, op.group, op.coll, op.size_bytes)
            cls = class_index.get(key)
            if cls is None:
                cls = class_index[key] = len(coll_shapes)
                coll_shapes.append(key)
            coll_uids.append(op.uid)
            coll_class.append(cls)
            coll_repeat.append(op.repeat)
        ndeps0[op.uid] = len(op.deps)
        if not op.deps:
            roots.append(op.uid)
        deps_flat.extend(op.deps)
        for d in op.deps:
            children[d].append(op.uid)
    plan = _SimPlan(n_ops=n, res_names=res_names, res_pool=res_pool,
                    res_of=res_of, ndeps0=ndeps0, children=children,
                    roots=roots,
                    deps_flat=np.array(deps_flat, dtype=np.intp),
                    comp_uids=np.array(comp_idx, dtype=np.intp),
                    comp_flops=np.array(comp_flops, dtype=np.float64),
                    comp_bytes=np.array(comp_bytes, dtype=np.float64),
                    coll_shapes=coll_shapes,
                    coll_uids=np.array(coll_uids, dtype=np.intp),
                    coll_class=np.array(coll_class, dtype=np.intp),
                    coll_repeat=np.array(coll_repeat, dtype=np.float64),
                    delay_ops=delay_ops,
                    pools=tuple(sorted(pools)))
    trace._sim_plan = plan  # traces are cached + immutable; piggyback the plan
    return plan


def _xfer_time_us(cfg: SystemConfig, size_bytes: float) -> float:
    """Cross-partition transfer: latency + bytes over the transfer lane
    (callers pre-divide the payload by the number of parallel lanes)."""
    bw = cfg.xfer_bw if cfg.xfer_bw is not None else cfg.network.dims[-1].bw
    return cfg.xfer_latency_us + (size_bytes / bw) * 1e-3


def _op_durations(plan: _SimPlan, cfg: SystemConfig,
                  gdims_by_pool: dict[int, dict[str, list[tuple[int, TopoDim]]]]) -> np.ndarray:
    """Duration of every op: vectorized roofline for the compute ops, the
    memoized collective model priced once per duration CLASS and scattered
    to the comm ops (a repeat of k back-to-back identical collectives pays
    k full latency+bandwidth terms)."""
    arr = np.zeros(plan.n_ops, dtype=np.float64)
    if len(plan.comp_uids):
        arr[plan.comp_uids] = cfg.device.op_times_us(plan.comp_flops,
                                                     plan.comp_bytes)
    if plan.coll_shapes:
        group_nets = {(pool, g): _group_net(cfg, carved)
                      for pool, gdims in gdims_by_pool.items()
                      for g, carved in gdims.items()}
        chunks, mode = cfg.chunks, cfg.multidim_coll
        class_t = np.empty(len(plan.coll_shapes), dtype=np.float64)
        for cls, (pool, group, coll, size) in enumerate(plan.coll_shapes):
            if group == "xfer":
                t = _xfer_time_us(cfg, size)
            else:
                resolved = group_nets.get((pool, group))
                if resolved is None:
                    t = 0.0
                else:
                    sub, algos = resolved
                    t = multidim_collective_time_us(coll, size, sub, algos,
                                                    chunks=chunks, mode=mode)
            class_t[cls] = t
        arr[plan.coll_uids] = class_t[plan.coll_class] * plan.coll_repeat
    for uid, delay_us in plan.delay_ops:
        arr[uid] = delay_us
    return arr


def _pool_entries(plan: _SimPlan, par: Parallelism,
                  pools: dict[int, Any] | None) -> tuple[tuple[int, Any], ...]:
    """Canonical, hashable form of the ``pools`` argument: one resolved
    entry per pool the plan actually uses (pool values are Parallelism /
    (Par, Net) / (Par, Net, dim_map) — all frozen/hashable)."""
    if pools is None:
        return tuple((p, par) for p in plan.pools)
    return tuple((p, pools.get(p, par)) for p in plan.pools)


@switchable_lru_cache(maxsize=4096)
def _pool_group_dims_cached(network: Network,
                            entries: tuple[tuple[int, Any], ...],
                            ) -> dict[int, dict[str, tuple[tuple[int, TopoDim], ...]]]:
    gdims_by_pool = {}
    for p, entry in entries:
        dim_map: tuple[int, ...] | None = None
        if isinstance(entry, tuple):
            if len(entry) == 3:
                par_p, net_p, dim_map = entry
            else:
                par_p, net_p = entry
        else:
            par_p, net_p = entry, network
        gd = group_dims(net_p, par_p)
        if dim_map:
            # carve indices are relative to the pool's sub-fabric; translate
            # them to the parent fabric's physical dims for algo resolution
            last = len(dim_map) - 1
            gd = {g: tuple((dim_map[min(i, last)], d) for i, d in v)
                  for g, v in gd.items()}
        gdims_by_pool[p] = gd
    return gdims_by_pool


def pool_group_dims(plan: _SimPlan, cfg: SystemConfig, par: Parallelism,
                    pools: dict[int, Any] | None) -> dict[int, dict[str, tuple[tuple[int, TopoDim], ...]]]:
    """Resolve every pool's parallelism-group -> carved-dims mapping.

    ``pools`` maps pool id -> that partition's Parallelism (default: every
    pool is parallelized by ``par`` on ``cfg.network``).  A ``(Parallelism,
    Network)`` value prices the pool's collectives on the sub-fabric its NPU
    slice actually spans instead of the whole cluster; a ``(Parallelism,
    Network, dim_map)`` value (``topology.sub_network_indexed``)
    additionally maps each sub-fabric dim back to its source physical dim so
    ``cfg.coll_algo`` is resolved against the dims the pool's traffic
    actually rides.

    Memoized on ``(cfg.network, resolved pool entries)`` — populations reuse
    a handful of carvings across thousands of calls.  The returned mapping
    is shared across hits; treat it as immutable."""
    return _pool_group_dims_cached(cfg.network, _pool_entries(plan, par, pools))


def plan_durations(trace: Trace, cfg: SystemConfig, par: Parallelism,
                   pools: dict[int, Any] | None = None) -> tuple[_SimPlan, np.ndarray]:
    """The shared per-design-point half of every backend: the (cached)
    scheduling plan plus this config's per-op durations (float64)."""
    plan = _sim_plan(trace)
    return plan, _op_durations(plan, cfg, pool_group_dims(plan, cfg, par,
                                                          pools))


# ---------------------------------------------------------------------------
# Batched duration pass: price a whole population in one vectorized shot
# ---------------------------------------------------------------------------

def _class_static(plan: _SimPlan) -> dict[str, np.ndarray]:
    """Design-point-independent per-class arrays (collective kind ids, class
    payload sizes, the xfer mask) plus the delay-op scatter arrays — built
    once per plan and reused by every batch."""
    st = plan.pack_memo.get("static")
    if st is None:
        C = len(plan.coll_shapes)
        kind_id = np.zeros(C, dtype=np.int32)
        size = np.zeros(C, dtype=np.float64)
        is_xfer = np.zeros(C, dtype=bool)
        for i, (_pool, group, coll, sz) in enumerate(plan.coll_shapes):
            # xfer classes price on the transfer lane, not the collective
            # model; kind id 0 is a dead gather behind the is_xfer mask
            kind_id[i] = 0 if group == "xfer" else COLL_KIND_IDS[coll]
            size[i] = sz
            is_xfer[i] = group == "xfer"
        delay_uids = np.array([u for u, _ in plan.delay_ops], dtype=np.intp)
        # permutation mapping op uid -> slot in the concatenated
        # [zero | comp | coll | delay] duration-source axis: the batched
        # pass GATHERS per-op durations through it instead of scattering
        # three uid groups (XLA CPU scatters are an order of magnitude
        # slower than one contiguous-row gather); slot 0 stays 0.0 for ops
        # with no duration source
        src = np.zeros(plan.n_ops, dtype=np.int32)
        base = 1
        for uids in (plan.comp_uids, plan.coll_uids, delay_uids):
            src[np.asarray(uids, dtype=np.intp)] = \
                base + np.arange(len(uids), dtype=np.int32)
            base += len(uids)
        st = {
            "kind_id": kind_id, "size": size, "is_xfer": is_xfer,
            "delay_uids": delay_uids,
            "delay_us": np.array([d for _, d in plan.delay_ops],
                                 dtype=np.float64),
            "src_of_op": src,
        }
        plan.pack_memo["static"] = st
    return st


def _pack_class_tables(plan: _SimPlan, cfg: SystemConfig, par: Parallelism,
                       pools: dict[int, Any] | None) -> dict[str, np.ndarray]:
    """One design point's per-class dim tables, padded to this key's max
    dim count: ``(C, D)`` arrays of npus / bw / latency_us / hierarchical
    payload scale (float64) and topo-kind / algo ids (int32).

    The carving is resolved once per ``(network, coll_algo, pool entries)``
    and memoized on the plan — population members differing only in
    chunks / mode / device / policy hit the same entry, and generations
    revisit the same few entries.  Padded slots hold ``npus = 1`` (carved
    dims always span >= 2 NPUs), which the vectorized collective evaluator
    prices to an exact 0.  The ``scale`` column is the scalar path's
    sequential-division payload shrinking, so pricing from these tables is
    bit-identical to the memoized scalar model."""
    entries = _pool_entries(plan, par, pools)
    key = (cfg.network, cfg.coll_algo, entries)
    cached = plan.pack_memo.get(key)
    if cached is not None:
        return cached
    gdims = _pool_group_dims_cached(cfg.network, entries)
    C = len(plan.coll_shapes)
    rows: list[tuple[tuple[TopoDim, str], ...]] = []
    D = 1
    for pool, group, _coll, _sz in plan.coll_shapes:
        resolved = None
        if group != "xfer":
            carved = gdims.get(pool, {}).get(group)
            if carved:
                resolved = _group_net(cfg, carved)
        if resolved is None:
            rows.append(())
            continue
        sub, algos = resolved
        row = tuple(zip(sub.dims, algos))
        rows.append(row)
        D = max(D, len(row))
    npus = np.ones((C, D), dtype=np.float64)
    bw = np.ones((C, D), dtype=np.float64)
    lat = np.zeros((C, D), dtype=np.float64)
    scale = np.ones((C, D), dtype=np.float64)
    topo = np.zeros((C, D), dtype=np.int32)
    algo = np.zeros((C, D), dtype=np.int32)
    for i, row in enumerate(rows):
        a2a = plan.coll_shapes[i][2] == "all_to_all"
        s = 1.0
        for j, (d, a) in enumerate(row):
            npus[i, j] = d.npus
            bw[i, j] = d.bw
            lat[i, j] = d.latency_us
            topo[i, j] = TOPO_KIND_IDS[d.kind]
            algo[i, j] = ALGO_IDS[a]
            scale[i, j] = 1.0 if a2a else s
            s /= d.npus
    tab = {"npus": npus, "bw": bw, "lat": lat, "scale": scale,
           "topo": topo, "algo": algo}
    plan.pack_memo[key] = tab
    return tab


def plan_duration_tables(trace: Trace,
                         calls: Sequence[Any]) -> tuple[_SimPlan, dict[str, np.ndarray]]:
    """The batched analogue of ``plan_durations``'s inputs: the (cached)
    plan plus one dict of packed numpy tables covering the whole population
    — ``(P, C, D)`` per-class dim tables and ``(P,)`` per-call scalars
    (roofline coefficients, chunks, mode, transfer-lane parameters).  The
    tables are everything ``batch_op_durations`` needs, and they form a
    flat pytree a jit-compiled consumer can take as one argument."""
    plan = _sim_plan(trace)
    tables = dict(_class_static(plan))
    per = [_pack_class_tables(plan, c.cfg, c.par, c.pools) for c in calls]
    P = len(calls)
    C = len(plan.coll_shapes)
    # pad the dim axis to a stable width: the padded-D value is a static
    # shape for the jit-compiled consumer, and letting it flap between
    # batches (4 vs 5 when a residual virtual dim appears) forces a
    # recompile per flap — 6 covers every carve of a <=5-dim network
    D = max(max((t["npus"].shape[1] for t in per), default=1), 6)
    for name, fill, dtype in (("npus", 1.0, np.float64),
                              ("bw", 1.0, np.float64),
                              ("lat", 0.0, np.float64),
                              ("scale", 1.0, np.float64),
                              ("topo", 0, np.int32),
                              ("algo", 0, np.int32)):
        out = np.full((P, C, D), fill, dtype=dtype)
        for k, t in enumerate(per):
            a = t[name]
            out[k, :, :a.shape[1]] = a
        tables[name] = out
    # per-call scalars, computed with the exact scalar-path expressions
    tables["peak"] = np.array([c.cfg.device.peak_tflops * 1e12
                               for c in calls], dtype=np.float64)
    tables["membw"] = np.array([c.cfg.device.mem_bw_gbps * 1e9
                                for c in calls], dtype=np.float64)
    tables["chunks"] = np.array([float(c.cfg.chunks) for c in calls],
                                dtype=np.float64)
    tables["blue"] = np.array([c.cfg.multidim_coll == "blueconnect"
                               for c in calls], dtype=bool)
    tables["xfer_bw"] = np.array(
        [c.cfg.xfer_bw if c.cfg.xfer_bw is not None
         else (c.cfg.network.dims[-1].bw if c.cfg.network.dims else 1.0)
         for c in calls], dtype=np.float64)
    tables["xfer_lat"] = np.array([c.cfg.xfer_latency_us for c in calls],
                                  dtype=np.float64)
    return plan, tables


def batch_op_durations(plan: _SimPlan, tables: dict[str, Any], *, xp=np,
                       op_major: bool = False):
    """Duration of every op for every population member: ``(P, n_ops)``
    (or ``(n_ops, P)`` with ``op_major=True``).

    The whole-population duration pass over ``plan_duration_tables`` output:
    the roofline prices all compute ops x calls in one broadcast, the
    vectorized collective evaluator prices all duration classes x calls in
    one shot (transfer classes switch to the xfer lane model), and the
    results route to op uids through one permutation gather (see
    ``src_of_op`` in ``_class_static`` — XLA CPU scatters are far slower
    than a contiguous-row gather, and op-major rows come out contiguous for
    the scheduling sweep).  With ``xp=np`` each row is bit-identical to the
    scalar ``plan_durations`` row for that call; with ``xp=jnp`` the same
    code traces under jit so the fused backend prices durations on-device,
    feeding the scheduling sweep without a host round-trip."""
    P = int(tables["peak"].shape[0])
    parts = [xp.zeros((1, P), dtype=xp.float64)]
    if len(plan.comp_uids):
        t_c = xp.asarray(plan.comp_flops)[:, None] / tables["peak"][None, :]
        t_m = xp.asarray(plan.comp_bytes)[:, None] / tables["membw"][None, :]
        parts.append(xp.maximum(t_c, t_m) * 1e6)           # (n_comp, P)
    if plan.coll_shapes:
        kind = xp.asarray(tables["kind_id"])[None, :]
        size = xp.asarray(tables["size"])[None, :]
        coll_t = multidim_collective_time_vec(
            kind, size, tables["npus"], tables["bw"], tables["lat"],
            tables["topo"], tables["algo"], tables["chunks"][:, None],
            tables["blue"][:, None], scale=tables["scale"], xp=xp)
        xfer_t = tables["xfer_lat"][:, None] \
            + (size / tables["xfer_bw"][:, None]) * 1e-3
        class_t = xp.where(xp.asarray(tables["is_xfer"])[None, :],
                           xfer_t, coll_t)                 # (P, C)
        if xp is not np:
            # force the (P, C) class table to materialize before the per-op
            # gather: XLA otherwise fuses the whole collective formula into
            # the gather and re-evaluates it per (op, member) — turning a
            # C x P pricing pass into an n_coll x P one (~150x here)
            from jax import lax
            class_t = lax.optimization_barrier(class_t)
        parts.append(class_t.T[xp.asarray(plan.coll_class)]
                     * xp.asarray(plan.coll_repeat)[:, None])  # (n_coll, P)
    if plan.delay_ops:
        parts.append(xp.broadcast_to(
            xp.asarray(tables["delay_us"])[:, None],
            (len(plan.delay_ops), P)))                     # (n_delay, P)
    src = xp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    if xp is not np:
        # same fusion hazard as class_t above, and the barrier also pins a
        # default layout so the host copy of the result is a plain memcpy
        from jax import lax
        src = lax.optimization_barrier(src)
    dur_t = src[xp.asarray(tables["src_of_op"])]           # (n_ops, P)
    return dur_t if op_major else dur_t.T


def plan_durations_batch(trace: Trace,
                         calls: Sequence[Any]) -> tuple[_SimPlan, np.ndarray]:
    """Batched ``plan_durations``: the plan plus a ``(P, n_ops)`` float64
    duration matrix, row ``k`` bit-identical to
    ``plan_durations(trace, calls[k].cfg, calls[k].par, calls[k].pools)``."""
    plan, tables = plan_duration_tables(trace, calls)
    return plan, batch_op_durations(plan, tables, xp=np)


def build_sim_result(plan: _SimPlan, *, makespan: float,
                     busy: Sequence[float], dur: Sequence[float],
                     finish: dict[int, float],
                     record_per_op: bool = False) -> SimResult:
    """Assemble a ``SimResult`` from a backend's schedule: per-resource busy
    times, the makespan, and (opt-in) op finish times."""
    n_res = len(plan.res_names)
    pool_compute = {plan.res_pool[r]: busy[r]
                    for r in range(n_res) if plan.res_names[r] == "compute"}
    comm_busy: dict[str, float] = {}
    for r in range(n_res):
        name = plan.res_names[r]
        if name == "compute" or name.startswith("_delay"):
            continue  # delay timers are releases, not communication
        key = name if plan.res_pool[r] == 0 else f"{name}@p{plan.res_pool[r]}"
        comm_busy[key] = comm_busy.get(key, 0.0) + busy[r]
    if record_per_op:
        per_op = dict(enumerate(dur.tolist() if isinstance(dur, np.ndarray)
                                else dur))
    else:
        per_op = {}
    return SimResult(
        makespan_us=makespan,
        compute_busy_us=pool_compute.get(0, 0.0),
        comm_busy_us=comm_busy,
        # time covered by no compute stream; pools chain/overlap, so the
        # aggregate compute across pools is the honest subtrahend (for a
        # single pool this is exactly the old makespan - compute_busy)
        exposed_comm_us=max(0.0, makespan - sum(pool_compute.values())),
        per_op_us=per_op,
        pool_compute_us=pool_compute,
        op_finish_us=finish,
    )


def simulate(trace: Trace, cfg: SystemConfig, par: Parallelism, *,
             pools: dict[int, Parallelism | tuple[Parallelism, Network]] | None = None,
             record_per_op: bool = False,
             record_finish: bool = False,
             backend: "str | Any | None" = None,
             verify: bool = False,
             analyze: bool = False) -> SimResult:
    """Schedule ``trace`` on the device + network of ``cfg``.

    A thin delegate onto the selected simulation backend
    (``repro.core.backends``); the default ``"reference"`` backend is the
    original discrete-event heapq loop, bit-identical to the pre-backend
    in-module implementation — no caller breaks.

    ``pools`` maps pool id -> that partition's Parallelism for multi-pool
    traces (see ``pool_group_dims`` for the accepted value shapes).
    ``record_per_op`` opts into materializing ``SimResult.per_op_us`` (plus
    ``op_finish_us``); ``record_finish`` materializes only
    ``SimResult.op_finish_us`` — the cheaper flag streaming scenarios use
    per design point to read wave TTFT/TPOT without allocating the per-op
    duration dict.  Both are off on the batched DSE hot path.

    ``verify=True`` statically checks the trace's scheduling plan first
    (dependency-DAG acyclicity, dangling dep/resource references, pool
    feasibility against ``cfg``/``pools``, repeat/delay sanity) and raises
    ``repro.core.analysis.PlanVerificationError`` with a structured report
    instead of letting a defective trace deadlock the event loop mid-run.
    ``analyze=True`` additionally attaches critical-path bottleneck
    attribution (compute vs collective vs gate time on the longest
    dependency chain) as ``SimResult.analysis``."""
    from repro.core.backends import get_backend

    if verify:
        from repro.core.analysis import verify_trace
        verify_trace(trace, cfg, par, pools).raise_if_issues()
    res = get_backend(backend).simulate(trace, cfg, par, pools=pools,
                                        record_per_op=record_per_op,
                                        record_finish=record_finish)
    if analyze:
        from repro.core.analysis import critical_path
        plan, dur = plan_durations(trace, cfg, par, pools)
        res.analysis = critical_path(plan, dur).summary(
            makespan_us=res.makespan_us)
    return res
