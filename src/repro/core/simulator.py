"""Simulation front door + the design-point-independent scheduling plan.

Resources: one compute stream (roofline device model) + one communication
engine per parallelism group (tp/dp/ep/pp), each mapped onto the network
dims it spans.  Ready ops queue on their resource; the queue discipline is
the paper's Collective 'Scheduling Policy' knob (LIFO favours the freshest
— critical-path — collectives, FIFO drains in issue order).  Compute/comm
overlap falls out of the scheduler, so exposed communication is measured,
not assumed.

HOW a trace is scheduled is a swappable backend (``repro.core.backends``):
``simulate()`` below is a thin delegate onto the selected ``SimBackend``
(default: the reference discrete-event heapq loop, bit-identical to the
original in-module implementation).  This module keeps what every backend
shares — the ``SystemConfig``/``SimResult`` value objects, the per-trace
``_SimPlan`` (dependency counts, children lists, per-op resource ids,
compute-op shape arrays, built once per ``Trace`` and reused across every
design point that shares it), and the per-design-point duration pass
(numpy-vectorized roofline for compute ops, the memoized collective cost
model for comm ops with each group's sub-network resolved once per call
rather than once per op).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from repro.core.collectives import multidim_collective_time_us
from repro.core.compute import Device
from repro.core.topology import Network, TopoDim, carve_dims
from repro.core.workload import Op, Parallelism, Trace


SCHED_POLICIES = ("fifo", "lifo")


@dataclass(frozen=True)
class SystemConfig:
    """The Collective + Network + Compute stacks of one design point."""
    network: Network
    device: Device
    coll_algo: tuple[str, ...]          # per network dim
    chunks: int = 1
    sched_policy: str = "fifo"          # lifo | fifo
    multidim_coll: str = "baseline"     # baseline | blueconnect
    # cross-partition transfer engine (multi-pool scenarios: KV-cache
    # handoff between disaggregated pools).  None rides the outermost —
    # scale-out — network dim's link speed.
    xfer_bw: float | None = None        # GB/s per transfer lane
    xfer_latency_us: float = 5.0

    def __post_init__(self) -> None:
        # a typo'd policy used to silently schedule as FIFO (the duration
        # pass only checked == "lifo"); fail at construction instead
        if self.sched_policy not in SCHED_POLICIES:
            raise ValueError(f"unknown sched_policy "
                             f"{self.sched_policy!r}; "
                             f"known: {SCHED_POLICIES}")


def group_dims(net: Network, par: Parallelism) -> dict[str, list[tuple[int, TopoDim]]]:
    """Map parallelism groups onto network dimensions, innermost first:
    TP gets the inner (fastest) dims, then EP(=TP group), SP, DP, PP.

    Each carved dim is returned with the physical dim index it came from
    (``carve_dims`` contract), so DP/PP collectives riding outer dims are
    priced with the collective algorithms the agent configured for THOSE
    dims — not the inner dims' algorithms.  When a group covers part of a
    dim, a virtual TopoDim with the residual group size (same kind/bw)
    approximates the sub-ring/sub-switch.  A group factor sharing no
    divisor with any dim (non-power-of-two pools from disaggregated/
    partitioned scenarios) becomes a virtual dim at the outermost —
    slowest — tier so its collectives are never free."""
    sizes = {"tp": par.tp, "sp": par.sp, "dp": par.dp, "pp": par.pp}
    cap = [d.npus for d in net.dims]  # consumed across groups, in order
    out: dict[str, list[tuple[int, TopoDim]]] = {
        grp: carve_dims(net.dims, cap, sizes[grp])
        for grp in ("tp", "sp", "dp", "pp")
    }
    out["ep"] = out["tp"]  # expert-parallel collectives ride the TP group
    return out


@dataclass
class SimResult:
    makespan_us: float
    compute_busy_us: float              # pool-0 compute stream (back-compat)
    comm_busy_us: dict[str, float]
    exposed_comm_us: float
    per_op_us: dict[int, float] = field(default_factory=dict)
    pool_compute_us: dict[int, float] = field(default_factory=dict)
    # op completion times (same opt-in as per_op_us): the request-stream
    # scenario reads per-wave first-token / last-token finish times off this
    op_finish_us: dict[int, float] = field(default_factory=dict)

    @property
    def latency_ms(self) -> float:
        return self.makespan_us / 1e3


def _group_net(cfg: SystemConfig,
               carved: list[tuple[int, TopoDim]]) -> tuple[Network, tuple[str, ...]] | None:
    """Resolve one parallelism group's sub-network + per-dim algorithms.

    ``carved`` pairs each dim with its source physical dim index, so the
    group's collectives use ``cfg.coll_algo[src_idx]`` — the algorithm the
    agent chose for that physical dim — instead of slicing from position 0
    (which handed DP/PP groups the inner dims' algorithms).  Residual
    virtual dims carry the outermost dim's index and therefore inherit its
    algorithm; indices beyond the configured tuple clamp to its last entry.
    """
    if not carved:
        return None
    n_alg = len(cfg.coll_algo)
    algos = tuple(cfg.coll_algo[min(i, n_alg - 1)] if n_alg else "ring"
                  for i, _ in carved)
    return Network(tuple(d for _, d in carved)), algos


@dataclass
class _SimPlan:
    """Design-point-independent scheduling structure of one trace.

    Ops carry dense uids (0..n-1 in issue order), so dependency bookkeeping
    lives in flat lists instead of dicts.  Resources are small integer ids;
    id 0 is always pool 0's compute stream.  Every pool gets its own compute
    stream and comm engines; cross-partition ``xfer`` collectives share one
    transfer resource; ``delay`` ops (arrival releases in request-stream
    traces) each get a private timer resource so they never serialize.

    Comm ops are condensed into duration CLASSES — the distinct
    ``(pool, group, coll, size)`` shapes (layers repeat shapes, so a trace
    with thousands of collectives typically has a few dozen classes): the
    per-design-point duration pass prices each class once and scatters,
    instead of walking every op through a memo dict."""
    n_ops: int
    res_names: list[str]                # per resource id: "compute" | group
    res_pool: list[int]                 # per resource id: owning pool
    res_of: list[int]                   # per op: resource id
    ndeps0: list[int]
    children: list[list[int]]
    roots: list[int]
    comp_uids: np.ndarray
    comp_flops: np.ndarray
    comp_bytes: np.ndarray
    coll_shapes: list[tuple[int, str, str, float]]  # per class: (pool, group, coll, size)
    coll_uids: np.ndarray               # comm-op uids
    coll_class: np.ndarray              # per comm op: coll_shapes index
    coll_repeat: np.ndarray             # per comm op: back-to-back repeats
    delay_ops: list[tuple[int, float]]  # (uid, delay_us)
    pools: tuple[int, ...]


def _sim_plan(trace: Trace) -> _SimPlan:
    plan = getattr(trace, "_sim_plan", None)
    if plan is not None:
        return plan
    n = len(trace.ops)
    if any(op.uid != i for i, op in enumerate(trace.ops)):
        raise ValueError("simulate() requires dense op uids (0..n-1 in list "
                         "order) — build traces with TraceBuilder")
    res_names = ["compute"]
    res_pool = [0]
    res_index: dict[tuple[int, str], int] = {(0, "compute"): 0}
    res_of = [0] * n
    ndeps0 = [0] * n
    children: list[list[int]] = [[] for _ in range(n)]
    roots: list[int] = []
    comp_idx: list[int] = []
    comp_flops: list[float] = []
    comp_bytes: list[float] = []
    class_index: dict[tuple[int, str, str, float], int] = {}
    coll_shapes: list[tuple[int, str, str, float]] = []
    coll_uids: list[int] = []
    coll_class: list[int] = []
    coll_repeat: list[int] = []
    delay_ops: list[tuple[int, float]] = []
    pools: set[int] = {0}

    def resource(pool: int, name: str) -> int:
        rid = res_index.get((pool, name))
        if rid is None:
            rid = len(res_names)
            res_index[(pool, name)] = rid
            res_names.append(name)
            res_pool.append(pool)
        return rid

    for op in trace.ops:
        pools.add(op.pool)
        if op.kind == "comp":
            res_of[op.uid] = resource(op.pool, "compute")
            comp_idx.append(op.uid)
            # the roofline is linear in (flops, bytes), so an op repeated
            # back-to-back k times is exactly one op scaled by k
            comp_flops.append(op.flops * op.repeat)
            comp_bytes.append(op.bytes * op.repeat)
        elif op.kind == "delay":
            # a pure time offset (request release): private resource so
            # concurrent delays never queue on each other
            res_of[op.uid] = resource(op.pool, f"_delay{op.uid}")
            delay_ops.append((op.uid, op.delay_us))
        else:
            # the transfer engine bridges partitions: one shared resource
            pool = 0 if op.group == "xfer" else op.pool
            res_of[op.uid] = resource(pool, op.group)
            key = (op.pool, op.group, op.coll, op.size_bytes)
            cls = class_index.get(key)
            if cls is None:
                cls = class_index[key] = len(coll_shapes)
                coll_shapes.append(key)
            coll_uids.append(op.uid)
            coll_class.append(cls)
            coll_repeat.append(op.repeat)
        ndeps0[op.uid] = len(op.deps)
        if not op.deps:
            roots.append(op.uid)
        for d in op.deps:
            children[d].append(op.uid)
    plan = _SimPlan(n_ops=n, res_names=res_names, res_pool=res_pool,
                    res_of=res_of, ndeps0=ndeps0, children=children,
                    roots=roots,
                    comp_uids=np.array(comp_idx, dtype=np.intp),
                    comp_flops=np.array(comp_flops, dtype=np.float64),
                    comp_bytes=np.array(comp_bytes, dtype=np.float64),
                    coll_shapes=coll_shapes,
                    coll_uids=np.array(coll_uids, dtype=np.intp),
                    coll_class=np.array(coll_class, dtype=np.intp),
                    coll_repeat=np.array(coll_repeat, dtype=np.float64),
                    delay_ops=delay_ops,
                    pools=tuple(sorted(pools)))
    trace._sim_plan = plan  # traces are cached + immutable; piggyback the plan
    return plan


def _xfer_time_us(cfg: SystemConfig, size_bytes: float) -> float:
    """Cross-partition transfer: latency + bytes over the transfer lane
    (callers pre-divide the payload by the number of parallel lanes)."""
    bw = cfg.xfer_bw if cfg.xfer_bw is not None else cfg.network.dims[-1].bw
    return cfg.xfer_latency_us + (size_bytes / bw) * 1e-3


def _op_durations(plan: _SimPlan, cfg: SystemConfig,
                  gdims_by_pool: dict[int, dict[str, list[tuple[int, TopoDim]]]]) -> np.ndarray:
    """Duration of every op: vectorized roofline for the compute ops, the
    memoized collective model priced once per duration CLASS and scattered
    to the comm ops (a repeat of k back-to-back identical collectives pays
    k full latency+bandwidth terms)."""
    arr = np.zeros(plan.n_ops, dtype=np.float64)
    if len(plan.comp_uids):
        arr[plan.comp_uids] = cfg.device.op_times_us(plan.comp_flops,
                                                     plan.comp_bytes)
    if plan.coll_shapes:
        group_nets = {(pool, g): _group_net(cfg, carved)
                      for pool, gdims in gdims_by_pool.items()
                      for g, carved in gdims.items()}
        chunks, mode = cfg.chunks, cfg.multidim_coll
        class_t = np.empty(len(plan.coll_shapes), dtype=np.float64)
        for cls, (pool, group, coll, size) in enumerate(plan.coll_shapes):
            if group == "xfer":
                t = _xfer_time_us(cfg, size)
            else:
                resolved = group_nets.get((pool, group))
                if resolved is None:
                    t = 0.0
                else:
                    sub, algos = resolved
                    t = multidim_collective_time_us(coll, size, sub, algos,
                                                    chunks=chunks, mode=mode)
            class_t[cls] = t
        arr[plan.coll_uids] = class_t[plan.coll_class] * plan.coll_repeat
    for uid, delay_us in plan.delay_ops:
        arr[uid] = delay_us
    return arr


def pool_group_dims(plan: _SimPlan, cfg: SystemConfig, par: Parallelism,
                    pools: dict[int, Any] | None) -> dict[int, dict[str, list[tuple[int, TopoDim]]]]:
    """Resolve every pool's parallelism-group -> carved-dims mapping.

    ``pools`` maps pool id -> that partition's Parallelism (default: every
    pool is parallelized by ``par`` on ``cfg.network``).  A ``(Parallelism,
    Network)`` value prices the pool's collectives on the sub-fabric its NPU
    slice actually spans instead of the whole cluster; a ``(Parallelism,
    Network, dim_map)`` value (``topology.sub_network_indexed``)
    additionally maps each sub-fabric dim back to its source physical dim so
    ``cfg.coll_algo`` is resolved against the dims the pool's traffic
    actually rides."""
    if pools is None:
        pools = {p: par for p in plan.pools}
    gdims_by_pool = {}
    for p in plan.pools:
        entry = pools.get(p, par)
        dim_map: tuple[int, ...] | None = None
        if isinstance(entry, tuple):
            if len(entry) == 3:
                par_p, net_p, dim_map = entry
            else:
                par_p, net_p = entry
        else:
            par_p, net_p = entry, cfg.network
        gd = group_dims(net_p, par_p)
        if dim_map:
            # carve indices are relative to the pool's sub-fabric; translate
            # them to the parent fabric's physical dims for algo resolution
            last = len(dim_map) - 1
            gd = {g: [(dim_map[min(i, last)], d) for i, d in v]
                  for g, v in gd.items()}
        gdims_by_pool[p] = gd
    return gdims_by_pool


def plan_durations(trace: Trace, cfg: SystemConfig, par: Parallelism,
                   pools: dict[int, Any] | None = None) -> tuple[_SimPlan, np.ndarray]:
    """The shared per-design-point half of every backend: the (cached)
    scheduling plan plus this config's per-op durations (float64)."""
    plan = _sim_plan(trace)
    return plan, _op_durations(plan, cfg, pool_group_dims(plan, cfg, par,
                                                          pools))


def build_sim_result(plan: _SimPlan, *, makespan: float,
                     busy: Sequence[float], dur: Sequence[float],
                     finish: dict[int, float],
                     record_per_op: bool = False) -> SimResult:
    """Assemble a ``SimResult`` from a backend's schedule: per-resource busy
    times, the makespan, and (opt-in) op finish times."""
    n_res = len(plan.res_names)
    pool_compute = {plan.res_pool[r]: busy[r]
                    for r in range(n_res) if plan.res_names[r] == "compute"}
    comm_busy: dict[str, float] = {}
    for r in range(n_res):
        name = plan.res_names[r]
        if name == "compute" or name.startswith("_delay"):
            continue  # delay timers are releases, not communication
        key = name if plan.res_pool[r] == 0 else f"{name}@p{plan.res_pool[r]}"
        comm_busy[key] = comm_busy.get(key, 0.0) + busy[r]
    if record_per_op:
        per_op = dict(enumerate(dur.tolist() if isinstance(dur, np.ndarray)
                                else dur))
    else:
        per_op = {}
    return SimResult(
        makespan_us=makespan,
        compute_busy_us=pool_compute.get(0, 0.0),
        comm_busy_us=comm_busy,
        # time covered by no compute stream; pools chain/overlap, so the
        # aggregate compute across pools is the honest subtrahend (for a
        # single pool this is exactly the old makespan - compute_busy)
        exposed_comm_us=max(0.0, makespan - sum(pool_compute.values())),
        per_op_us=per_op,
        pool_compute_us=pool_compute,
        op_finish_us=finish,
    )


def simulate(trace: Trace, cfg: SystemConfig, par: Parallelism, *,
             pools: dict[int, Parallelism | tuple[Parallelism, Network]] | None = None,
             record_per_op: bool = False,
             record_finish: bool = False,
             backend: "str | Any | None" = None) -> SimResult:
    """Schedule ``trace`` on the device + network of ``cfg``.

    A thin delegate onto the selected simulation backend
    (``repro.core.backends``); the default ``"reference"`` backend is the
    original discrete-event heapq loop, bit-identical to the pre-backend
    in-module implementation — no caller breaks.

    ``pools`` maps pool id -> that partition's Parallelism for multi-pool
    traces (see ``pool_group_dims`` for the accepted value shapes).
    ``record_per_op`` opts into materializing ``SimResult.per_op_us`` (plus
    ``op_finish_us``); ``record_finish`` materializes only
    ``SimResult.op_finish_us`` — the cheaper flag streaming scenarios use
    per design point to read wave TTFT/TPOT without allocating the per-op
    duration dict.  Both are off on the batched DSE hot path."""
    from repro.core.backends import get_backend

    return get_backend(backend).simulate(trace, cfg, par, pools=pools,
                                         record_per_op=record_per_op,
                                         record_finish=record_finish)
