"""Discrete-event simulator for distributed ML execution (ASTRA-sim-lite).

Resources: one compute stream (roofline device model) + one communication
engine per parallelism group (tp/dp/ep/pp), each mapped onto the network
dims it spans.  Ready ops queue on their resource; the queue discipline is
the paper's Collective 'Scheduling Policy' knob (LIFO favours the freshest
— critical-path — collectives, FIFO drains in issue order).  Compute/comm
overlap falls out of the event loop, so exposed communication is measured,
not assumed.

Batched-DSE fast path: the trace-dependent scheduling structure (dependency
counts, children lists, per-op resource ids, compute-op shape arrays) is
built once per ``Trace`` and reused across every design point that shares
it, the compute-op roofline pass is vectorized with numpy, and collective
durations come from the memoized cost model with the per-group sub-network
resolved once per call rather than once per op.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from repro.core.collectives import multidim_collective_time_us
from repro.core.compute import Device
from repro.core.topology import Network, TopoDim
from repro.core.workload import Op, Parallelism, Trace


@dataclass(frozen=True)
class SystemConfig:
    """The Collective + Network + Compute stacks of one design point."""
    network: Network
    device: Device
    coll_algo: tuple[str, ...]          # per network dim
    chunks: int = 1
    sched_policy: str = "fifo"          # lifo | fifo
    multidim_coll: str = "baseline"     # baseline | blueconnect


def group_dims(net: Network, par: Parallelism) -> dict[str, list[TopoDim]]:
    """Map parallelism groups onto network dimensions, innermost first:
    TP gets the inner (fastest) dims, then EP(=TP group), SP, DP, PP.

    When a group covers part of a dim, a virtual TopoDim with the residual
    group size (same kind/bw) approximates the sub-ring/sub-switch."""
    sizes = {"tp": par.tp, "sp": par.sp, "dp": par.dp, "pp": par.pp}
    out: dict[str, list[TopoDim]] = {g: [] for g in ("tp", "sp", "dp", "pp")}
    dim_iter = list(net.dims)
    cap = [d.npus for d in dim_iter]
    for grp in ("tp", "sp", "dp", "pp"):
        need = sizes[grp]
        for i, d in enumerate(dim_iter):
            if need <= 1:
                break
            if cap[i] <= 1:
                continue
            take = math.gcd(need, cap[i])
            if take <= 1:
                continue
            out[grp].append(TopoDim(d.kind, take, d.bw, d.latency_us))
            cap[i] //= take
            need //= take
    out["ep"] = out["tp"]  # expert-parallel collectives ride the TP group
    return out


@dataclass
class SimResult:
    makespan_us: float
    compute_busy_us: float
    comm_busy_us: dict[str, float]
    exposed_comm_us: float
    per_op_us: dict[int, float] = field(default_factory=dict)

    @property
    def latency_ms(self) -> float:
        return self.makespan_us / 1e3


def _group_net(cfg: SystemConfig, dims: list[TopoDim]) -> tuple[Network, tuple[str, ...]] | None:
    """Resolve one parallelism group's sub-network + per-dim algorithms."""
    if not dims:
        return None
    algos = list(cfg.coll_algo[: len(dims)])
    if len(algos) < len(dims):
        algos += [algos[-1] if algos else "ring"] * (len(dims) - len(algos))
    return Network(tuple(dims)), tuple(algos)


@dataclass
class _SimPlan:
    """Design-point-independent scheduling structure of one trace.

    Ops carry dense uids (0..n-1 in issue order), so dependency bookkeeping
    lives in flat lists instead of dicts.  Resources are small integer ids;
    id 0 is always the compute stream."""
    n_ops: int
    res_names: list[str]                # per resource id: "compute" | group
    res_of: list[int]                   # per op: resource id
    ndeps0: list[int]
    children: list[list[int]]
    roots: list[int]
    comp_uids: np.ndarray
    comp_flops: np.ndarray
    comp_bytes: np.ndarray
    coll_ops: list[tuple[int, str, float, str]]   # (uid, coll, size, group)


def _sim_plan(trace: Trace) -> _SimPlan:
    plan = getattr(trace, "_sim_plan", None)
    if plan is not None:
        return plan
    n = len(trace.ops)
    if any(op.uid != i for i, op in enumerate(trace.ops)):
        raise ValueError("simulate() requires dense op uids (0..n-1 in list "
                         "order) — build traces with TraceBuilder")
    res_names = ["compute"]
    res_index: dict[str, int] = {"compute": 0}
    res_of = [0] * n
    ndeps0 = [0] * n
    children: list[list[int]] = [[] for _ in range(n)]
    roots: list[int] = []
    comp_idx: list[int] = []
    comp_flops: list[float] = []
    comp_bytes: list[float] = []
    coll_ops: list[tuple[int, str, float, str]] = []
    for op in trace.ops:
        if op.kind == "comp":
            res_of[op.uid] = 0
            comp_idx.append(op.uid)
            comp_flops.append(op.flops)
            comp_bytes.append(op.bytes)
        else:
            name = f"net:{op.group}"
            rid = res_index.get(name)
            if rid is None:
                rid = len(res_names)
                res_index[name] = rid
                res_names.append(op.group)
            res_of[op.uid] = rid
            coll_ops.append((op.uid, op.coll, op.size_bytes, op.group))
        ndeps0[op.uid] = len(op.deps)
        if not op.deps:
            roots.append(op.uid)
        for d in op.deps:
            children[d].append(op.uid)
    plan = _SimPlan(n_ops=n, res_names=res_names, res_of=res_of,
                    ndeps0=ndeps0, children=children, roots=roots,
                    comp_uids=np.array(comp_idx, dtype=np.intp),
                    comp_flops=np.array(comp_flops, dtype=np.float64),
                    comp_bytes=np.array(comp_bytes, dtype=np.float64),
                    coll_ops=coll_ops)
    trace._sim_plan = plan  # traces are cached + immutable; piggyback the plan
    return plan


def _op_durations(plan: _SimPlan, cfg: SystemConfig,
                  gdims: dict[str, list[TopoDim]]) -> list[float]:
    """Duration of every op: vectorized roofline for the compute ops, the
    memoized collective model for the comm ops."""
    arr = np.zeros(plan.n_ops, dtype=np.float64)
    if len(plan.comp_uids):
        arr[plan.comp_uids] = cfg.device.op_times_us(plan.comp_flops,
                                                     plan.comp_bytes)
    dur = arr.tolist()
    group_nets = {g: _group_net(cfg, dims) for g, dims in gdims.items()}
    chunks, mode = cfg.chunks, cfg.multidim_coll
    local: dict[tuple[str, str, float], float] = {}  # layers repeat shapes
    for uid, coll, size, group in plan.coll_ops:
        key = (group, coll, size)
        t = local.get(key)
        if t is None:
            resolved = group_nets.get(group)
            if resolved is None:
                t = 0.0
            else:
                sub, algos = resolved
                t = multidim_collective_time_us(coll, size, sub, algos,
                                                chunks=chunks, mode=mode)
            local[key] = t
        dur[uid] = t
    return dur


def simulate(trace: Trace, cfg: SystemConfig, par: Parallelism) -> SimResult:
    plan = _sim_plan(trace)
    gdims = group_dims(cfg.network, par)
    dur = _op_durations(plan, cfg, gdims)

    n_res = len(plan.res_names)
    ndeps = list(plan.ndeps0)
    children = plan.children
    res_of = plan.res_of
    queues: list[list[tuple[int, int]]] = [[] for _ in range(n_res)]
    free_at = [0.0] * n_res
    busy = [0.0] * n_res
    sign = -1 if cfg.sched_policy == "lifo" else 1
    seq = 0  # enqueue order tiebreaker
    hpush, hpop = heapq.heappush, heapq.heappop

    events: list[tuple[float, int, int]] = []  # (time, eseq, uid)
    eseq = 0
    n_finished = 0

    for uid in plan.roots:
        seq += 1
        hpush(queues[res_of[uid]], (sign * seq, uid))
    for r in range(n_res):
        if queues[r]:
            _, uid = hpop(queues[r])
            d = dur[uid]
            free_at[r] = d
            busy[r] += d
            eseq += 1
            hpush(events, (d, eseq, uid))

    makespan = 0.0
    while events:
        now, _, uid = hpop(events)
        n_finished += 1
        if now > makespan:
            makespan = now
        # only the freed resource and resources receiving new work can start
        # an op here: any other free resource with queued work would already
        # have been started when it last freed (the loop's invariant)
        cand = [res_of[uid]]
        for ch in children[uid]:
            ndeps[ch] -= 1
            if ndeps[ch] == 0:
                seq += 1
                r = res_of[ch]
                hpush(queues[r], (sign * seq, ch))
                if r not in cand:
                    cand.append(r)
        for r in cand:
            if free_at[r] <= now and queues[r]:
                _, nxt = hpop(queues[r])
                d = dur[nxt]
                free_at[r] = now + d
                busy[r] += d
                eseq += 1
                hpush(events, (now + d, eseq, nxt))

    if n_finished != plan.n_ops:
        raise RuntimeError(f"deadlock: {n_finished}/{plan.n_ops} ops finished")

    compute_busy = busy[0]
    comm_busy = {plan.res_names[r]: busy[r] for r in range(1, n_res)}
    return SimResult(
        makespan_us=makespan,
        compute_busy_us=compute_busy,
        comm_busy_us=comm_busy,
        exposed_comm_us=max(0.0, makespan - compute_busy),
        per_op_us=dict(enumerate(dur)),
    )
