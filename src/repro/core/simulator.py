"""Discrete-event simulator for distributed ML execution (ASTRA-sim-lite).

Resources: one compute stream (roofline device model) + one communication
engine per parallelism group (tp/dp/ep/pp), each mapped onto the network
dims it spans.  Ready ops queue on their resource; the queue discipline is
the paper's Collective 'Scheduling Policy' knob (LIFO favours the freshest
— critical-path — collectives, FIFO drains in issue order).  Compute/comm
overlap falls out of the event loop, so exposed communication is measured,
not assumed.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.core.collectives import multidim_collective_time_us
from repro.core.compute import Device
from repro.core.topology import Network, TopoDim
from repro.core.workload import Op, Parallelism, Trace


@dataclass(frozen=True)
class SystemConfig:
    """The Collective + Network + Compute stacks of one design point."""
    network: Network
    device: Device
    coll_algo: tuple[str, ...]          # per network dim
    chunks: int = 1
    sched_policy: str = "fifo"          # lifo | fifo
    multidim_coll: str = "baseline"     # baseline | blueconnect


def group_dims(net: Network, par: Parallelism) -> dict[str, list[TopoDim]]:
    """Map parallelism groups onto network dimensions, innermost first:
    TP gets the inner (fastest) dims, then EP(=TP group), SP, DP, PP.

    When a group covers part of a dim, a virtual TopoDim with the residual
    group size (same kind/bw) approximates the sub-ring/sub-switch."""
    sizes = {"tp": par.tp, "sp": par.sp, "dp": par.dp, "pp": par.pp}
    out: dict[str, list[TopoDim]] = {g: [] for g in ("tp", "sp", "dp", "pp")}
    dim_iter = list(net.dims)
    cap = [d.npus for d in dim_iter]
    for grp in ("tp", "sp", "dp", "pp"):
        need = sizes[grp]
        for i, d in enumerate(dim_iter):
            if need <= 1:
                break
            if cap[i] <= 1:
                continue
            take = math.gcd(need, cap[i])
            if take <= 1:
                continue
            out[grp].append(TopoDim(d.kind, take, d.bw, d.latency_us))
            cap[i] //= take
            need //= take
    out["ep"] = out["tp"]  # expert-parallel collectives ride the TP group
    return out


@dataclass
class SimResult:
    makespan_us: float
    compute_busy_us: float
    comm_busy_us: dict[str, float]
    exposed_comm_us: float
    per_op_us: dict[int, float] = field(default_factory=dict)

    @property
    def latency_ms(self) -> float:
        return self.makespan_us / 1e3


def _coll_time(op: Op, cfg: SystemConfig, dims: list[TopoDim]) -> float:
    if not dims:
        return 0.0
    sub = Network(tuple(dims))
    algos = list(cfg.coll_algo[: len(dims)])
    if len(algos) < len(dims):
        algos += [algos[-1] if algos else "ring"] * (len(dims) - len(algos))
    return multidim_collective_time_us(op.coll, op.size_bytes, sub, algos,
                                       chunks=cfg.chunks, mode=cfg.multidim_coll)


def simulate(trace: Trace, cfg: SystemConfig, par: Parallelism) -> SimResult:
    gdims = group_dims(cfg.network, par)
    durations: dict[int, float] = {}
    for op in trace.ops:
        if op.kind == "comp":
            durations[op.uid] = cfg.device.op_time_us(op.flops, op.bytes)
        else:
            durations[op.uid] = _coll_time(op, cfg, gdims.get(op.group, []))

    n_deps = {op.uid: len(op.deps) for op in trace.ops}
    children: dict[int, list[int]] = {op.uid: [] for op in trace.ops}
    for op in trace.ops:
        for d in op.deps:
            children[d].append(op.uid)

    res_of = {op.uid: ("compute" if op.kind == "comp" else f"net:{op.group}")
              for op in trace.ops}
    queues: dict[str, list] = {}
    busy: dict[str, float] = {}
    free_at: dict[str, float] = {}
    seq = 0  # enqueue order tiebreaker

    def push(res: str, uid: int, now: float):
        nonlocal seq
        seq += 1
        order = -seq if cfg.sched_policy == "lifo" else seq
        heapq.heappush(queues.setdefault(res, []), (order, uid, now))

    events: list[tuple[float, int, str, int]] = []  # (time, tag, res, uid)
    now = 0.0
    for op in trace.ops:
        if n_deps[op.uid] == 0:
            push(res_of[op.uid], op.uid, 0.0)

    finished: dict[int, float] = {}
    eseq = 0

    def try_start(res: str, now: float):
        nonlocal eseq
        if free_at.get(res, 0.0) > now or not queues.get(res):
            return
        _, uid, _ = heapq.heappop(queues[res])
        dur = durations[uid]
        free_at[res] = now + dur
        busy[res] = busy.get(res, 0.0) + dur
        eseq += 1
        heapq.heappush(events, (now + dur, eseq, res, uid))

    for res in set(res_of.values()):
        try_start(res, 0.0)

    makespan = 0.0
    while events:
        now, _, res, uid = heapq.heappop(events)
        finished[uid] = now
        makespan = max(makespan, now)
        for ch in children[uid]:
            n_deps[ch] -= 1
            if n_deps[ch] == 0:
                push(res_of[ch], ch, now)
        # resources whose queue may now be serviceable
        for r in set(list(queues.keys()) + [res]):
            if free_at.get(r, 0.0) <= now:
                try_start(r, now)

    if len(finished) != len(trace.ops):
        raise RuntimeError(f"deadlock: {len(finished)}/{len(trace.ops)} ops finished")

    compute_busy = busy.get("compute", 0.0)
    comm_busy = {r.split(":", 1)[1]: v for r, v in busy.items() if r.startswith("net:")}
    return SimResult(
        makespan_us=makespan,
        compute_busy_us=compute_busy,
        comm_busy_us=comm_busy,
        exposed_comm_us=max(0.0, makespan - compute_busy),
        per_op_us=durations,
    )
