"""System-preset registry: named target systems for the whole DSE stack.

A ``SystemPreset`` bundles what used to be hand-wired per benchmark script:
the cluster size, the compute device (paper Table 3), and the Table-3
baseline stack defaults used to pin non-searched stacks in single-stack
ablations.  ``StudySpec.system`` resolves here, as do the benchmark
helpers — adding a new target system is one ``register_system`` call, not a
new copy of the assembly code.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping

from repro.core.compute import (SYSTEM_1_DEVICE, SYSTEM_2_DEVICE,
                                SYSTEM_3_DEVICE, Device)
from repro.core.psa import ParameterSet, paper_psa


@dataclass(frozen=True)
class SystemPreset:
    """One named target system: cluster size + device + baseline stacks.

    ``base_defaults`` are the Table-3 baseline values for the collective and
    network stacks; ``workload_defaults`` the baseline parallelization —
    together they pin every non-searched parameter when a study restricts
    the searched stacks (``ParameterSet.restrict``)."""
    name: str
    n_npus: int
    device: Device
    base_defaults: Mapping[str, Any] = field(default_factory=dict)
    workload_defaults: Mapping[str, Any] = field(default_factory=dict)
    doc: str = ""

    def stack_defaults(self) -> dict[str, Any]:
        return {**self.base_defaults, **self.workload_defaults}


SYSTEM_REGISTRY: dict[str, SystemPreset] = {}


def register_system(preset: SystemPreset, *, replace: bool = False) -> SystemPreset:
    if not replace and preset.name in SYSTEM_REGISTRY:
        raise ValueError(f"system {preset.name!r} already registered")
    SYSTEM_REGISTRY[preset.name] = preset
    return preset


def get_system(system: "str | SystemPreset") -> SystemPreset:
    if isinstance(system, SystemPreset):
        return system
    try:
        return SYSTEM_REGISTRY[system]
    except KeyError:
        raise ValueError(f"unknown system {system!r}; "
                         f"known: {sorted(SYSTEM_REGISTRY)}") from None


def list_systems() -> dict[str, SystemPreset]:
    return dict(SYSTEM_REGISTRY)


# Paper Table 3: the three evaluation systems, with their Table-3 baseline
# stacks (previously duplicated across benchmarks/common.py call sites).
WORKLOAD_DEFAULTS = dict(dp=64, pp=1, sp=4, weight_sharded=1)

register_system(SystemPreset(
    "system1", 512, SYSTEM_1_DEVICE,
    base_defaults=dict(
        sched_policy="fifo", coll_algo=("ring", "ring", "ring", "rhd"),
        chunks=2, multidim_coll="baseline",
        topology=("ring", "ring", "ring", "switch"),
        npus_per_dim=(4, 4, 4, 8), bw_per_dim=(200, 200, 200, 50)),
    workload_defaults=WORKLOAD_DEFAULTS,
    doc="512-NPU TPU-v5p-class pod (paper Table 3, System 1)"))

register_system(SystemPreset(
    "system2", 1024, SYSTEM_2_DEVICE,
    base_defaults=dict(
        sched_policy="fifo", coll_algo=("ring", "direct", "ring", "rhd"),
        chunks=2, multidim_coll="baseline",
        topology=("ring", "fc", "ring", "switch"),
        npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100)),
    workload_defaults=WORKLOAD_DEFAULTS,
    doc="1024-NPU wafer-scale-class system (paper Table 3, System 2)"))

register_system(SystemPreset(
    "system3", 2048, SYSTEM_3_DEVICE,
    base_defaults=dict(
        sched_policy="fifo", coll_algo=("direct", "rhd", "ring", "ring"),
        chunks=2, multidim_coll="baseline",
        topology=("fc", "switch", "ring", "ring"),
        npus_per_dim=(8, 16, 4, 4), bw_per_dim=(450, 100, 50, 50)),
    workload_defaults=WORKLOAD_DEFAULTS,
    doc="2048-NPU H100-class cluster (paper Table 3, System 3)"))


# -- assembly helpers (the former benchmarks/common.py make_env/make_pset) --

def system_pset(system: "str | SystemPreset", *,
                stacks: "set[str] | None" = None,
                max_pp: int = 4) -> ParameterSet:
    """The paper PsA sized for a system, optionally restricted to a stack
    subset with every pinned parameter defaulted from the preset."""
    preset = get_system(system)
    ps = paper_psa(preset.n_npus, max_pp=max_pp)
    if stacks is not None:
        ps = ps.restrict(stacks, preset.stack_defaults())
    return ps


def system_env(arch, system: "str | SystemPreset", *, batch: int = 1024,
               seq: int | None = None, objective="perf_per_bw",
               mode: str = "train", scenario=None,
               eval_store: dict | None = None, decode_tokens: int = 64,
               capacity_gb: float = 24.0, backend: str = "reference"):
    """A ``CosmicEnv`` over a registered system.  ``arch`` is an ``ARCHS``
    key or an ``ArchSpec``; ``seq`` defaults to the arch's max_seq;
    ``backend`` selects the simulation backend (``repro.core.backends``)."""
    from repro.configs import ARCHS
    from repro.core.env import CosmicEnv

    preset = get_system(system)
    spec = ARCHS[arch] if isinstance(arch, str) else arch
    return CosmicEnv(spec=spec, n_npus=preset.n_npus, device=preset.device,
                     scenario=scenario, batch=batch,
                     seq=seq or spec.max_seq, mode=mode,
                     decode_tokens=decode_tokens, objective=objective,
                     eval_store=eval_store, capacity_gb=capacity_gb,
                     backend=backend)
