"""Per-NPU memory-footprint model — the validity gate of Section 5.4
("any parallelization strategy resulting in a memory footprint exceeding
24 GB per NPU is considered invalid and discarded")."""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchSpec
from repro.core.cache import switchable_lru_cache
from repro.core.workload import Parallelism

BYTES_PARAM = 2            # bf16 weights
BYTES_OPT = 12             # fp32 master + Adam m/v
BYTES_ACT = 2


@dataclass(frozen=True)
class Footprint:
    params_gb: float
    optimizer_gb: float
    activations_gb: float
    kv_cache_gb: float

    @property
    def total_gb(self) -> float:
        return self.params_gb + self.optimizer_gb + self.activations_gb + self.kv_cache_gb


def footprint(spec: ArchSpec, par: Parallelism, *, batch: int, seq: int,
              mode: str = "train", act_factor: float = 4.0,
              remat: bool = True, microbatches: int = 8) -> Footprint:
    """Memoized on its (hashable) value-object arguments — the DSE loop
    re-gates the same (spec, parallelization) points constantly."""
    return _footprint_cached(spec, par, batch, seq, mode, act_factor,
                             remat, microbatches)


def _footprint_impl(spec: ArchSpec, par: Parallelism, batch: int, seq: int,
                    mode: str, act_factor: float, remat: bool,
                    microbatches: int) -> Footprint:
    p_total = spec.param_count()
    tp = par.tp
    shard = tp * par.pp * (par.dp if par.weight_sharded else 1)
    params = p_total * BYTES_PARAM / shard
    optimizer = p_total * BYTES_OPT / (tp * par.pp * par.dp) if mode == "train" else 0.0
    if not par.weight_sharded and mode == "train":
        optimizer = p_total * BYTES_OPT / (tp * par.pp)

    b = batch / par.dp / (microbatches if mode == "train" else 1)
    s = seq / par.sp
    layers_per_stage = max(1, spec.n_layers // par.pp)
    per_layer = b * s * spec.d_model * BYTES_ACT
    if mode == "train":
        # remat keeps ~the residual stream per layer; otherwise act_factor
        # intermediate tensors per layer survive to the backward pass
        acts = per_layer * layers_per_stage * (1.5 if remat else act_factor)
    else:
        acts = per_layer * 2

    kv = 0.0
    if mode != "train":
        kv = kv_cache_bytes(spec, batch=b, seq=seq, tp=tp)

    return Footprint(params / 1e9, optimizer / 1e9, acts / 1e9, kv / 1e9)


def kv_cache_bytes(spec: ArchSpec, *, batch: float, seq: int,
                   tp: int = 1) -> float:
    """K+V cache bytes for ``batch`` requests at ``seq`` tokens, per TP
    shard — the single source of truth for both the footprint gate and the
    disaggregated-serving KV transfer size."""
    hd = spec.resolved_head_dim
    n_attn = sum(1 for ld in spec.layer_defs() if ld.mixer.startswith("attn"))
    return n_attn * batch * seq * spec.n_kv_heads * hd * 2 * BYTES_ACT / tp


_footprint_cached = switchable_lru_cache(maxsize=16384)(_footprint_impl)


def fits(spec: ArchSpec, par: Parallelism, *, batch: int, seq: int,
         capacity_gb: float = 24.0, mode: str = "train") -> bool:
    return footprint(spec, par, batch=batch, seq=seq, mode=mode).total_gb <= capacity_gb
