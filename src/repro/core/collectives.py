"""Collective communication cost models: {Ring, Direct, RHD, DBT} x
{ring, switch, fc} x {reduce-scatter, all-gather, all-reduce, all-to-all},
with chunked pipelining and BlueConnect multi-dimensional decomposition.

alpha-beta form: T = steps * alpha + wire_bytes / effective_bw, where
effective_bw folds in (i) how many of the NPU's links the algorithm can
drive concurrently on the given topology and (ii) congestion when the
algorithm's traffic pattern doesn't match the physical links (e.g. Direct
on a ring incurs multi-hop forwarding).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.core.cache import switchable_lru_cache
from repro.core.topology import Network, TopoDim

ALGOS = ("ring", "direct", "rhd", "dbt")
COLL_KINDS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all")


def _steps(algo: str, kind: str, n: int) -> float:
    """Latency term: serialized communication rounds."""
    if n <= 1:
        return 0.0
    lg = math.ceil(math.log2(n))
    if algo == "ring":
        per_pass = n - 1
    elif algo == "direct":
        per_pass = 1.0
    else:  # rhd, dbt
        per_pass = lg
    if kind == "all_reduce":
        return 2.0 * per_pass   # reduce-scatter pass + all-gather pass
    if kind == "all_to_all":
        return 1.0 if algo == "direct" else per_pass
    return float(per_pass)      # AG / RS: one pass


def _wire_bytes(kind: str, n: int, size: float) -> float:
    """Bytes each NPU must move through its injection port (bandwidth-optimal
    lower bound): AR = 2M(n-1)/n, AG/RS/A2A = M(n-1)/n."""
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    return (2.0 if kind == "all_reduce" else 1.0) * size * frac


def _parallel_links(algo: str, topo_kind: str, n: int) -> float:
    """How many links per NPU the algorithm drives concurrently."""
    if topo_kind == "ring":
        # ring topology: 2 neighbour links; ring algo streams through 1 tx
        # (bidirectional rings can split ~2x, halved by turnaround overheads)
        return {"ring": 1.0, "direct": 1.0, "rhd": 1.0, "dbt": 2.0}[algo]
    if topo_kind == "switch":
        return 1.0  # NIC-bound through the switch for every algorithm
    # fully connected: direct/A2A-style patterns drive all n-1 links
    return {"ring": 1.0, "direct": float(n - 1), "rhd": 1.0, "dbt": 2.0}[algo]


def _congestion(algo: str, topo_kind: str, n: int) -> float:
    """Multiplier >= 1 when traffic must be forwarded over links it doesn't
    own (pattern/topology mismatch)."""
    if n <= 2:
        return 1.0
    if topo_kind == "ring":
        if algo == "direct":
            return n / 4.0            # mean hop distance on a bidirectional ring
        if algo == "rhd":
            # exchange at distance 2^i: sum of hops / passes
            return max(1.0, (n / 2.0) / math.ceil(math.log2(n)))
        if algo == "dbt":
            return max(1.0, n / (2.0 * math.ceil(math.log2(n))))
    if topo_kind == "switch":
        return 1.0                    # non-blocking
    return 1.0                        # fc: every pair has a wire


def collective_time_us(kind: str, size_bytes: float, dim: TopoDim, algo: str,
                       chunks: int = 1) -> float:
    """Time for one collective of `size_bytes` within one network dim.

    Chunking trades bandwidth efficiency for latency/pipelinability: the
    latency term pays per chunk; the bandwidth term is unchanged (chunks are
    serialized within a single dim — the pipelining win shows up across dims
    in `multidim_collective_time_us`)."""
    n = dim.npus
    if n <= 1 or size_bytes <= 0:
        return 0.0
    steps = _steps(algo, kind, n) * max(chunks, 1)
    wire = _wire_bytes(kind, n, size_bytes)
    eff_bw = dim.bw * _parallel_links(algo, dim.kind, n) / _congestion(algo, dim.kind, n)
    return steps * dim.latency_us + (wire / eff_bw) * 1e-3  # bytes/(GB/s) -> us
    # (1 byte / 1 GB/s = 1e-9 s = 1e-3 us)


def multidim_collective_time_us(kind: str, size_bytes: float, net: Network,
                                algos: Sequence[str], chunks: int = 1,
                                mode: str = "baseline",
                                dims: Sequence[int] | None = None) -> float:
    """A collective spanning several mesh dimensions.

    Memoized on ``(kind, size, net, algos, chunks, mode, dims)`` — traces
    repeat the same per-layer collective shapes, and searches revisit design
    points, so the hit rate on the DSE hot path is very high.  ``Network``
    and ``TopoDim`` are frozen dataclasses, making the whole key hashable;
    a hit is bit-identical to the uncached computation.

    baseline:    hierarchical reduce-scatter up the dims then all-gather back
                 down (sizes shrink by the group size at each hop); chunks
                 pipeline across the per-dim phases.
    blueconnect: decompose the collective into per-dim schedules running
                 concurrently on disjoint chunks (Cho et al., MLSys'19) —
                 total time approaches the slowest dim instead of the sum.
    """
    return _multidim_collective_time_cached(
        kind, float(size_bytes), net, tuple(algos), chunks, mode,
        None if dims is None else tuple(dims))


def _multidim_collective_time_impl(kind: str, size_bytes: float, net: Network,
                                   algos: Sequence[str], chunks: int,
                                   mode: str,
                                   dims: Sequence[int] | None) -> float:
    idx = list(range(len(net.dims))) if dims is None else list(dims)
    idx = [i for i in idx if net.dims[i].npus > 1]
    if not idx or size_bytes <= 0:
        return 0.0
    if len(idx) == 1:
        return collective_time_us(kind, size_bytes, net.dims[idx[0]], algos[idx[0]], chunks)

    if kind == "all_to_all":
        # dimension-ordered routing: each dim moves the full payload once
        phases = [collective_time_us(kind, size_bytes, net.dims[i], algos[i], chunks)
                  for i in idx]
    else:
        # RS up / AG down with shrinking payloads
        phases = []
        scale = 1.0
        for i in idx:
            d = net.dims[i]
            if kind == "all_reduce":
                phases.append(
                    collective_time_us("reduce_scatter", size_bytes * scale, d, algos[i], chunks)
                    + collective_time_us("all_gather", size_bytes * scale, d, algos[i], chunks))
            else:
                phases.append(collective_time_us(kind, size_bytes * scale, d, algos[i], chunks))
            scale /= d.npus

    c = max(chunks, 1)
    if mode == "blueconnect":
        # concurrent per-dim schedules on disjoint chunk shards
        return max(phases) + (sum(phases) - max(phases)) / c
    # hierarchical with chunk pipelining between consecutive phases
    return sum(p / c for p in phases) + (c - 1) / c * max(phases)


_multidim_collective_time_cached = \
    switchable_lru_cache(maxsize=131072)(_multidim_collective_time_impl)
