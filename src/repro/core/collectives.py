"""Collective communication cost models: {Ring, Direct, RHD, DBT} x
{ring, switch, fc} x {reduce-scatter, all-gather, all-reduce, all-to-all},
with chunked pipelining and BlueConnect multi-dimensional decomposition.

alpha-beta form: T = steps * alpha + wire_bytes / effective_bw, where
effective_bw folds in (i) how many of the NPU's links the algorithm can
drive concurrently on the given topology and (ii) congestion when the
algorithm's traffic pattern doesn't match the physical links (e.g. Direct
on a ring incurs multi-hop forwarding).

Two evaluation paths share one set of coefficient tables:

  * the SCALAR path (``collective_time_us`` / ``multidim_collective_time_us``)
    — the memoized per-design-point oracle the reference backend prices
    with, bit-identical to the original branchy implementation;
  * the VECTORIZED path (``collective_time_vec`` /
    ``multidim_collective_time_vec``) — the same model over arrays of
    integer ids (kind/algo/topo_kind) and float dims, evaluating whole
    populations x duration-classes in one shot.  ``xp`` selects the array
    module (numpy, or ``jax.numpy`` so the fused backend can price inside
    jit).  With a host-exact ``scale`` table the numpy path reproduces the
    scalar path bit for bit; without one it matches to the last couple of
    ulps (cumprod vs sequential division).
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from repro.core.cache import switchable_lru_cache
from repro.core.topology import TOPO_KINDS, Network, TopoDim

ALGOS = ("ring", "direct", "rhd", "dbt")
COLL_KINDS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all")

# -- integer ids: the gather keys of the vectorized evaluator ---------------
ALGO_IDS = {a: i for i, a in enumerate(ALGOS)}
COLL_KIND_IDS = {k: i for i, k in enumerate(COLL_KINDS)}
TOPO_KIND_IDS = {t: i for i, t in enumerate(TOPO_KINDS)}  # ring/switch/fc

_AR = COLL_KIND_IDS["all_reduce"]
_A2A = COLL_KIND_IDS["all_to_all"]
_RING_A, _DIRECT_A, _RHD_A, _DBT_A = (ALGO_IDS[a] for a in ALGOS)
_RING_T, _SWITCH_T, _FC_T = (TOPO_KIND_IDS[t] for t in TOPO_KINDS)

# -- coefficient tables ------------------------------------------------------
# Plain-float tuples feed the scalar path (no numpy scalars on the memoized
# hot path); the numpy arrays the vectorized evaluator gathers from are
# built FROM them so the two paths cannot diverge.
# per-NPU concurrently-driven links, [topo_kind_id][algo_id];
# -1 marks the n-dependent entry (Direct on fully-connected drives n-1)
_LINKS = (
    # ring   direct  rhd   dbt
    (1.0,    1.0,    1.0,  2.0),   # ring topology
    (1.0,    1.0,    1.0,  1.0),   # switch (NIC-bound for every algorithm)
    (1.0,   -1.0,    1.0,  2.0),   # fully connected
)
# serialized-rounds multiplier per collective kind (all-reduce pays a
# reduce-scatter pass plus an all-gather pass); the per-pass round count is
# the algo selector: ring -> n-1, direct -> 1, rhd/dbt -> ceil(log2 n)
_KIND_STEP_MULT = (2.0, 1.0, 1.0, 1.0)
# injection-port bytes multiplier per kind: AR = 2M(n-1)/n, rest = M(n-1)/n
_KIND_WIRE_MULT = (2.0, 1.0, 1.0, 1.0)

_LINKS_TABLE = np.array(_LINKS)
_KIND_STEP_MULT_ARR = np.array(_KIND_STEP_MULT)
_KIND_WIRE_MULT_ARR = np.array(_KIND_WIRE_MULT)


def _ceil_log2(n: int) -> int:
    """ceil(log2(n)) for n >= 1, exactly (bit tricks, no libm)."""
    return max(n - 1, 0).bit_length() if n > 1 else 0


def _steps(algo: str, kind: str, n: int) -> float:
    """Latency term: serialized communication rounds."""
    if n <= 1:
        return 0.0
    lg = math.ceil(math.log2(n))
    if algo == "ring":
        per_pass = n - 1
    elif algo == "direct":
        per_pass = 1.0
    else:  # rhd, dbt
        per_pass = lg
    if kind == "all_reduce":
        return 2.0 * per_pass   # reduce-scatter pass + all-gather pass
    if kind == "all_to_all":
        return 1.0 if algo == "direct" else per_pass
    return float(per_pass)      # AG / RS: one pass


def _wire_bytes(kind: str, n: int, size: float) -> float:
    """Bytes each NPU must move through its injection port (bandwidth-optimal
    lower bound): AR = 2M(n-1)/n, AG/RS/A2A = M(n-1)/n."""
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    return _KIND_WIRE_MULT[COLL_KIND_IDS[kind]] * size * frac


def _parallel_links(algo: str, topo_kind: str, n: int) -> float:
    """How many links per NPU the algorithm drives concurrently (the
    ``_LINKS_TABLE`` coefficient; -1 marks the n-dependent fc/direct entry)."""
    v = _LINKS_TABLE[TOPO_KIND_IDS[topo_kind], ALGO_IDS[algo]]
    return float(n - 1) if v < 0 else float(v)


def _congestion(algo: str, topo_kind: str, n: int) -> float:
    """Multiplier >= 1 when traffic must be forwarded over links it doesn't
    own (pattern/topology mismatch)."""
    if n <= 2:
        return 1.0
    if topo_kind == "ring":
        if algo == "direct":
            return n / 4.0            # mean hop distance on a bidirectional ring
        if algo == "rhd":
            # exchange at distance 2^i: sum of hops / passes
            return max(1.0, (n / 2.0) / math.ceil(math.log2(n)))
        if algo == "dbt":
            return max(1.0, n / (2.0 * math.ceil(math.log2(n))))
    if topo_kind == "switch":
        return 1.0                    # non-blocking
    return 1.0                        # fc: every pair has a wire


def collective_time_us(kind: str, size_bytes: float, dim: TopoDim, algo: str,
                       chunks: int = 1) -> float:
    """Time for one collective of `size_bytes` within one network dim.

    Chunking trades bandwidth efficiency for latency/pipelinability: the
    latency term pays per chunk; the bandwidth term is unchanged (chunks are
    serialized within a single dim — the pipelining win shows up across dims
    in `multidim_collective_time_us`)."""
    n = dim.npus
    if n <= 1 or size_bytes <= 0:
        return 0.0
    steps = _steps(algo, kind, n) * max(chunks, 1)
    wire = _wire_bytes(kind, n, size_bytes)
    eff_bw = dim.bw * _parallel_links(algo, dim.kind, n) / _congestion(algo, dim.kind, n)
    return steps * dim.latency_us + (wire / eff_bw) * 1e-3  # bytes/(GB/s) -> us
    # (1 byte / 1 GB/s = 1e-9 s = 1e-3 us)


def multidim_collective_time_us(kind: str, size_bytes: float, net: Network,
                                algos: Sequence[str], chunks: int = 1,
                                mode: str = "baseline",
                                dims: Sequence[int] | None = None) -> float:
    """A collective spanning several mesh dimensions.

    Memoized on ``(kind, size, net, algos, chunks, mode, dims)`` — traces
    repeat the same per-layer collective shapes, and searches revisit design
    points, so the hit rate on the DSE hot path is very high.  ``Network``
    and ``TopoDim`` are frozen dataclasses, making the whole key hashable;
    a hit is bit-identical to the uncached computation.

    baseline:    hierarchical reduce-scatter up the dims then all-gather back
                 down (sizes shrink by the group size at each hop); chunks
                 pipeline across the per-dim phases.
    blueconnect: decompose the collective into per-dim schedules running
                 concurrently on disjoint chunks (Cho et al., MLSys'19) —
                 total time approaches the slowest dim instead of the sum.
    """
    return _multidim_collective_time_cached(
        kind, float(size_bytes), net, tuple(algos), chunks, mode,
        None if dims is None else tuple(dims))


def _multidim_collective_time_impl(kind: str, size_bytes: float, net: Network,
                                   algos: Sequence[str], chunks: int,
                                   mode: str,
                                   dims: Sequence[int] | None) -> float:
    idx = list(range(len(net.dims))) if dims is None else list(dims)
    idx = [i for i in idx if net.dims[i].npus > 1]
    if not idx or size_bytes <= 0:
        return 0.0
    if len(idx) == 1:
        return collective_time_us(kind, size_bytes, net.dims[idx[0]], algos[idx[0]], chunks)

    if kind == "all_to_all":
        # dimension-ordered routing: each dim moves the full payload once
        phases = [collective_time_us(kind, size_bytes, net.dims[i], algos[i], chunks)
                  for i in idx]
    else:
        # RS up / AG down with shrinking payloads
        phases = []
        scale = 1.0
        for i in idx:
            d = net.dims[i]
            if kind == "all_reduce":
                phases.append(
                    collective_time_us("reduce_scatter", size_bytes * scale, d, algos[i], chunks)
                    + collective_time_us("all_gather", size_bytes * scale, d, algos[i], chunks))
            else:
                phases.append(collective_time_us(kind, size_bytes * scale, d, algos[i], chunks))
            scale /= d.npus

    c = max(chunks, 1)
    if mode == "blueconnect":
        # concurrent per-dim schedules on disjoint chunk shards
        return max(phases) + (sum(phases) - max(phases)) / c
    # hierarchical with chunk pipelining between consecutive phases
    return sum(p / c for p in phases) + (c - 1) / c * max(phases)


_multidim_collective_time_cached = \
    switchable_lru_cache(maxsize=131072)(_multidim_collective_time_impl)


# ---------------------------------------------------------------------------
# Vectorized evaluator: the same model over arrays of integer ids
# ---------------------------------------------------------------------------

def _vec_ceil_log2(n, xp):
    """ceil(log2(n)) for float arrays of integers, exactly: the exponent of
    frexp(n - 1) is bit_length(n - 1), with no libm rounding to worry about.
    Returns 1 where n <= 2 (callers only consume lg through congestion /
    rhd-dbt step counts, which are guarded there)."""
    _, e = xp.frexp(xp.maximum(n - 1.0, 1.0))
    return xp.maximum(e.astype(xp.float64), 1.0)


def collective_time_vec(kind_id, size_bytes, npus, bw, latency_us, topo_id,
                        algo_id, chunks, *, xp=np):
    """Elementwise ``collective_time_us`` over arrays.

    All arguments broadcast together; ids are integer arrays indexing the
    coefficient tables (``COLL_KIND_IDS`` / ``ALGO_IDS`` / ``TOPO_KIND_IDS``),
    the rest are float64 arrays.  Entries with ``npus <= 1`` or
    ``size_bytes <= 0`` evaluate to 0, so padded dim slots are free."""
    n = xp.asarray(npus, dtype=xp.float64)
    size = xp.asarray(size_bytes, dtype=xp.float64)
    c = xp.maximum(xp.asarray(chunks, dtype=xp.float64), 1.0)
    lat = xp.asarray(latency_us, dtype=xp.float64)
    kind_id = xp.asarray(kind_id)
    algo_id = xp.asarray(algo_id)
    topo_id = xp.asarray(topo_id)

    lg = _vec_ceil_log2(n, xp)
    # latency term: per-pass rounds selected by algo, doubled for all-reduce
    per_pass = xp.where(algo_id == _RING_A, n - 1.0,
                        xp.where(algo_id == _DIRECT_A, 1.0, lg))
    steps = per_pass * xp.asarray(_KIND_STEP_MULT)[kind_id] * c
    # bandwidth term: injection-port bytes over effective bandwidth
    frac = (n - 1.0) / n
    wire = xp.asarray(_KIND_WIRE_MULT)[kind_id] * size * frac
    links = xp.asarray(_LINKS_TABLE)[topo_id, algo_id]
    links = xp.where(links < 0, n - 1.0, links)
    on_ring = topo_id == _RING_T
    cong = xp.ones_like(n)
    cong = xp.where(on_ring & (algo_id == _DIRECT_A), n / 4.0, cong)
    cong = xp.where(on_ring & (algo_id == _RHD_A),
                    xp.maximum(1.0, (n / 2.0) / lg), cong)
    cong = xp.where(on_ring & (algo_id == _DBT_A),
                    xp.maximum(1.0, n / (2.0 * lg)), cong)
    cong = xp.where(n <= 2.0, 1.0, cong)
    eff_bw = bw * links / cong
    t = steps * lat + (wire / eff_bw) * 1e-3
    return xp.where((n > 1.0) & (size > 0.0), t, 0.0)


def multidim_collective_time_vec(kind_id, size_bytes, npus, bw, latency_us,
                                 topo_id, algo_id, chunks, blueconnect, *,
                                 scale=None, xp=np):
    """Vectorized ``multidim_collective_time_us`` over padded dim tables.

    The trailing axis is the (padded) dim axis: ``npus``/``bw``/
    ``latency_us``/``topo_id``/``algo_id`` are ``(..., D)``; ``kind_id``,
    ``size_bytes``, ``chunks`` and the boolean ``blueconnect`` (mode) are
    ``(...)`` and broadcast.  Pad unused slots with ``npus = 1`` (carved
    dims always have >= 2 NPUs, so real and padded slots can't collide).

    ``scale`` optionally provides the hierarchical payload-shrinking table
    ``(..., D)`` host-exactly (sequential division, as the scalar path
    computes it) — the packed-table fast path passes it; when ``None`` it is
    derived here via cumprod (equal to the last ulp).  All-to-all rows must
    pass scale 1 (dimension-ordered routing moves the full payload per dim);
    the internal derivation handles that, host-built tables must too.

    Reductions over the dim axis are unrolled so the accumulation order
    matches the scalar path's active-dims-in-order ``sum()``/``max()`` —
    with a host-exact ``scale`` the numpy evaluation is bit-identical to
    the (uncached) scalar model."""
    n = xp.asarray(npus, dtype=xp.float64)
    size = xp.asarray(size_bytes, dtype=xp.float64)[..., None]
    kind = xp.asarray(kind_id)[..., None]
    c = xp.maximum(xp.asarray(chunks, dtype=xp.float64), 1.0)
    if scale is None:
        inv = 1.0 / n
        shifted = xp.cumprod(inv[..., :-1], axis=-1)
        scale = xp.concatenate(
            [xp.ones_like(inv[..., :1]), shifted], axis=-1)
        scale = xp.where(kind == _A2A, 1.0, scale)
    else:
        scale = xp.asarray(scale, dtype=xp.float64)
    phases = collective_time_vec(kind, size * scale, n, bw, latency_us,
                                 topo_id, algo_id, c[..., None], xp=xp)
    ndim = phases.shape[-1]
    # unrolled reductions: padded slots contribute exact 0.0 terms
    sum_p = phases[..., 0]
    max_p = phases[..., 0]
    base_sum = phases[..., 0] / c
    for d in range(1, ndim):
        p = phases[..., d]
        sum_p = sum_p + p
        max_p = xp.maximum(max_p, p)
        base_sum = base_sum + p / c
    active = xp.sum(n > 1.0, axis=-1)
    blue = max_p + (sum_p - max_p) / c
    base = base_sum + (c - 1.0) / c * max_p
    multi = xp.where(xp.asarray(blueconnect, dtype=bool), blue, base)
    # 0 or 1 active dims: no cross-dim pipelining — the bare phase (or 0)
    return xp.where(active <= 1, sum_p, multi)
