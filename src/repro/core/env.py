"""CosmicEnv: the ArchGym-style environment wrapping the simulator.

An agent submits a PsA configuration; the environment materializes the
(workload, collective, network, compute) stacks and hands the resolved
``EnvContext`` to its ``Scenario``, which runs the WTG + simulator and
returns the reward.  Fixed parameters (single-stack baselines) are handled
upstream by ``ParameterSet.restrict`` — the env is stack-agnostic.

Batched evaluation: ``step_batch`` evaluates a population of configurations
at once, deduplicating repeated design points through a per-env evaluation
memo (evaluation is a pure function of the config) and optionally fanning
the distinct points out to a ``concurrent.futures`` process pool.  Results
are identical to serial ``step`` calls in the same order.  With a
vectorized simulation backend (``backend="jax"``), the surviving unique
points are instead described as declarative ``SimJob``s and swept through
the backend's population-batched ``simulate_batch``, grouped by shared
trace.

Cross-search sharing: pass the same ``eval_store`` dict to several envs
over the same (spec, scenario, system) and they share one evaluation memo —
benchmark sweeps running four agents over one space stop re-evaluating
identical design points per agent.  Hit/miss counters live on each env.
"""
from __future__ import annotations

import itertools
import multiprocessing
import os
import sys
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Sequence

from repro.configs.base import ArchSpec
from repro.core.backends import BACKEND_REGISTRY, get_backend, run_sim_jobs
from repro.core.cache import cache_epoch, caches_enabled
from repro.core.compute import Device
from repro.core.rewards import Evaluation, Objective, get_objective
from repro.core.scenario import EnvContext, Scenario, TrainScenario
from repro.core.simulator import SystemConfig
from repro.core.topology import Network, build_network


@dataclass
class StepRecord:
    step: int
    config: dict[str, Any]
    reward: float
    latency_ms: float
    valid: bool


def _config_key(config: dict[str, Any]) -> tuple:
    """Canonical hashable key for one design point."""
    return tuple(sorted((k, v) for k, v in config.items()))


# -- process-pool plumbing ---------------------------------------------------
# Workers hold a history-free copy of the env (installed once per worker via
# the pool initializer) and evaluate configs against it; only (config ->
# Evaluation) crosses the process boundary.
_WORKER_ENV: "CosmicEnv | None" = None


def _pool_init(env: "CosmicEnv") -> None:
    global _WORKER_ENV
    _WORKER_ENV = env


_WORKER_SEEN_EPOCH: int | None = None


def _pool_eval(config: dict[str, Any], caches_on: bool,
               epoch: int) -> Evaluation:
    assert _WORKER_ENV is not None, "pool worker not initialized"
    # the parent's runtime cache toggle and clear_all_caches() epoch don't
    # reach long-lived workers (fork freezes state at pool creation, spawn
    # re-imports the defaults), so every task carries both
    global _WORKER_SEEN_EPOCH
    from repro.core import cache as _cache
    if _WORKER_SEEN_EPOCH is not None and _WORKER_SEEN_EPOCH != epoch:
        _cache.clear_all_caches()
    _WORKER_SEEN_EPOCH = epoch
    if _cache.caches_enabled() != caches_on:
        _cache.set_caches_enabled(caches_on)
    return _WORKER_ENV.evaluate_config(config)


@dataclass
class CosmicEnv:
    spec: ArchSpec
    n_npus: int
    device: Device
    # the workload shape under design.  Either pass a Scenario, or use the
    # legacy (batch, seq, mode, decode_tokens) fields and get a TrainScenario
    # built for you — PR-1 call sites keep working unchanged.
    scenario: Scenario | None = None
    batch: int | None = None
    seq: int | None = None
    mode: str | None = "train"
    decode_tokens: int | None = 64
    # an Objective-registry name or an Objective instance; resolved to an
    # Objective at construction (self.objective is always an Objective after
    # __post_init__)
    objective: "str | Objective" = "perf_per_bw"
    capacity_gb: float = 24.0
    fixed_network: Network | None = None   # for workload/collective-only DSE
    # simulation-backend registry name (``repro.core.backends``): how every
    # design point's traces are scheduled.  Vectorized backends ("jax")
    # additionally reroute ``step_batch`` through the population-batched
    # ``simulate_batch`` path.  Kept a string so envs pickle to pool workers.
    backend: str = "reference"
    # optional cross-search shared memo (see module docstring)
    eval_store: dict[tuple, Evaluation] | None = None
    store_hits: int = 0
    store_misses: int = 0
    # optional observer of fresh evaluations: called (config, Evaluation)
    # once per memo miss (the persistent cross-campaign eval store hooks in
    # here).  Not forwarded to pool workers — the parent records results as
    # they come back.
    eval_record: Any = None
    history: list[StepRecord] = field(default_factory=list)
    _eval_cache: dict[tuple, Evaluation] = field(default_factory=dict, repr=False)
    _sig_cache: tuple | None = field(default=None, repr=False)
    _memo_epoch: int = field(default=-1, repr=False)
    _executor: ProcessPoolExecutor | None = field(default=None, repr=False)
    _executor_workers: int = field(default=0, repr=False)
    _in_context: bool = field(default=False, repr=False)  # inside `with env:`

    def __post_init__(self) -> None:
        # fail at construction on a bad objective, not deep in a search:
        # resolve the name through the Objective registry; streaming-required
        # objectives (e.g. "goodput") additionally need a scenario that
        # resolves per-request metrics itself
        self.objective = get_objective(self.objective)
        if self.objective.streaming and self.scenario is not None \
                and not getattr(self.scenario, "supports_stream_objectives",
                                False):
            raise ValueError(
                f"objective {self.objective.name!r} needs a streaming "
                f"scenario (per-request metrics); "
                f"{type(self.scenario).__name__} only supports scalar "
                f"(one-latency) objectives")
        if self.backend not in BACKEND_REGISTRY:
            raise ValueError(f"unknown simulation backend {self.backend!r}; "
                             f"known: {sorted(BACKEND_REGISTRY)}")
        if self.scenario is None:
            if self.objective.streaming:
                raise ValueError(f"objective {self.objective.name!r} needs a "
                                 f"streaming scenario, not the legacy "
                                 f"batch/seq TrainScenario path")
            if self.batch is None or self.seq is None:
                raise TypeError("CosmicEnv needs either a scenario or "
                                "legacy batch/seq fields")
            self.scenario = TrainScenario(self.batch, self.seq, self.mode,
                                          self.decode_tokens)
        else:
            # the scenario owns the workload shape — drop legacy fields so
            # nothing reads stale workload metadata off the env
            self.batch = self.seq = self.mode = self.decode_tokens = None

    def _network(self, config: dict[str, Any]) -> Network:
        if self.fixed_network is not None and "topology" not in config:
            return self.fixed_network
        return build_network(config["topology"], config["npus_per_dim"],
                             config["bw_per_dim"])

    def context(self, config: dict[str, Any]) -> EnvContext:
        """Resolve one design point's network/system stacks for the scenario."""
        net = self._network(config)
        sys_cfg = SystemConfig(
            network=net, device=self.device,
            coll_algo=tuple(config["coll_algo"]),
            chunks=int(config["chunks"]),
            sched_policy=config["sched_policy"],
            multidim_coll=config["multidim_coll"],
        )
        return EnvContext(spec=self.spec, n_npus=self.n_npus,
                          device=self.device, objective=self.objective,
                          capacity_gb=self.capacity_gb, config=config,
                          network=net, sys_cfg=sys_cfg, backend=self.backend)

    def evaluate_config(self, config: dict[str, Any]) -> Evaluation:
        """Pure evaluation of one design point (no history, no memo)."""
        return self.scenario.evaluate(self.context(config))

    def clear_memo(self) -> None:
        self._eval_cache.clear()
        if self.eval_store is not None:
            # evict only this env's signature from the shared store —
            # other envs' entries are theirs to manage
            sig = self._store_sig()
            for k in [k for k in self.eval_store if k[0] == sig]:
                del self.eval_store[k]

    # -- memoization -------------------------------------------------------
    # Private memo keys are the bare config; the shared store prefixes the
    # env signature so envs over different (spec, scenario, system) can
    # safely share one dict.
    def _store_sig(self) -> tuple:
        if self._sig_cache is None:  # all inputs are frozen value objects
            # hash the full spec/device (not just names): same-named but
            # differing objects must not share store entries.  The backend
            # is part of the signature — a vectorized backend's results may
            # differ (within tolerance) from the reference oracle's, so
            # they must not cross-hit through a shared store.
            self._sig_cache = (self.spec, self.n_npus, self.device,
                               self.objective, self.capacity_gb,
                               self.scenario, self.fixed_network,
                               self.backend)
        return self._sig_cache

    def _point_key(self, config: dict[str, Any]) -> tuple:
        canon = getattr(self.scenario, "canonical", None)
        if canon is not None:
            config = canon(config)
        key = _config_key(config)
        return (self._store_sig(), key) if self.eval_store is not None else key

    def _memo(self) -> dict[tuple, Evaluation]:
        """The evaluation memo, honoring cache.clear_all_caches() epochs."""
        if self.eval_store is not None:
            return self.eval_store  # lifetime is the caller's to manage
        if self._memo_epoch != cache_epoch():
            self._eval_cache.clear()
            self._memo_epoch = cache_epoch()
        return self._eval_cache

    def store_records(self) -> list[tuple[dict[str, Any], float]]:
        """(config, reward) pairs this env has memoized — from its slice of
        a shared ``eval_store`` (only this env's signature) or its private
        memo.  The surrogate layer's dataset builders consume this shape
        (``repro.core.surrogate.build_dataset``)."""
        memo = self._memo()
        if self.eval_store is not None:
            sig = self._store_sig()
            return [(dict(k[1]), ev.reward)
                    for k, ev in memo.items() if k[0] == sig]
        return [(dict(k), ev.reward) for k, ev in memo.items()]

    def _evaluate_memo(self, config: dict[str, Any]) -> Evaluation:
        if not caches_enabled():
            return self.evaluate_config(config)
        memo = self._memo()
        key = self._point_key(config)
        ev = memo.get(key)
        if ev is None:
            self.store_misses += self.eval_store is not None
            ev = self.evaluate_config(config)
            memo[key] = ev
            if self.eval_record is not None:
                self.eval_record(config, ev)
        else:
            self.store_hits += self.eval_store is not None
        return ev

    def step(self, config: dict[str, Any]) -> Evaluation:
        ev = self._evaluate_memo(config)
        self.history.append(StepRecord(len(self.history), config, ev.reward,
                                       ev.latency_ms, ev.valid))
        return ev

    def step_batch(self, configs: Sequence[dict[str, Any]],
                   workers: int = 0) -> list[Evaluation]:
        """Evaluate a population of design points.

        Distinct uncached points are computed once each — serially, or on a
        process pool when ``workers > 1`` — then results are recorded in
        input order, so history and returned evaluations match what serial
        ``step`` calls would have produced.
        """
        memo_on = caches_enabled()
        if memo_on:
            # evaluate each distinct uncached point once
            memo = self._memo()
            shared = self.eval_store is not None
            keys = [self._point_key(c) for c in configs]
            todo: dict[tuple, dict[str, Any]] = {}
            for key, cfg in zip(keys, configs):
                if key not in memo:
                    todo.setdefault(key, cfg)
            if shared:
                # per-occurrence accounting matching serial step() calls:
                # the first sighting of a new key is the miss, duplicates
                # (within the batch or not) are hits
                counted_new: set = set()
                for key in keys:
                    if key not in todo or key in counted_new:
                        self.store_hits += 1
                    else:
                        self.store_misses += 1
                        counted_new.add(key)
            if todo:
                evs = self._eval_many(list(todo.values()), workers)
                memo.update(zip(todo.keys(), evs))
                if self.eval_record is not None:
                    for cfg, ev in zip(todo.values(), evs):
                        self.eval_record(cfg, ev)
            out = [memo[key] for key in keys]
        else:
            # caches off = the honest uncached baseline: every occurrence
            # is evaluated, including within-batch duplicates
            out = self._eval_many(list(configs), workers)
        for cfg, ev in zip(configs, out):
            self.history.append(StepRecord(len(self.history), cfg, ev.reward,
                                           ev.latency_ms, ev.valid))
        return out

    def _eval_many(self, cfgs: list[dict[str, Any]],
                   workers: int) -> list[Evaluation]:
        backend = get_backend(self.backend)
        if backend.vectorized and len(cfgs) > 1 \
                and hasattr(self.scenario, "sim_job"):
            # population-vectorized path: describe every point's simulator
            # calls declaratively, then sweep the calls sharing a trace —
            # and therefore a scheduling plan — in one simulate_batch each.
            # Takes precedence over the process pool: fanning single-point
            # evaluations out to workers would forfeit the shared-plan
            # sweep (and pay a per-worker jit compile).
            jobs = [self.scenario.sim_job(self.context(c)) for c in cfgs]
            return run_sim_jobs(jobs, backend)
        if workers > 1 and len(cfgs) > 1:
            pool = self._get_executor(workers)
            chunk = max(1, len(cfgs) // (self._executor_workers * 2))
            flags = itertools.repeat(caches_enabled())
            epochs = itertools.repeat(cache_epoch())
            return list(pool.map(_pool_eval, cfgs, flags, epochs,
                                 chunksize=chunk))
        return [self.evaluate_config(c) for c in cfgs]

    # -- pool lifecycle ---------------------------------------------------
    def pool_is_caller_managed(self) -> bool:
        """True when the caller controls pool lifetime — the env is inside a
        ``with`` block, or a pool already exists from earlier use.  Search
        drivers use this to decide whether to reap the pool they caused."""
        return self._executor is not None or self._in_context

    def _get_executor(self, workers: int) -> ProcessPoolExecutor:
        workers = min(workers, os.cpu_count() or 1)
        if self._executor is not None and self._executor_workers != workers:
            self.close()
        if self._executor is None:
            bare = replace(self, history=[], _eval_cache={}, _executor=None,
                           _executor_workers=0, eval_store=None,
                           store_hits=0, store_misses=0, eval_record=None)
            # fork gives near-free workers, but inherits other threads' locks
            # mid-held — unsafe once a threaded runtime (jax) is loaded, so
            # fall back to spawn there (slower startup, re-imports per worker)
            method = "spawn" if ("jax" in sys.modules
                                 or "fork" not in multiprocessing.get_all_start_methods()) \
                else "fork"
            self._executor = ProcessPoolExecutor(
                max_workers=workers, initializer=_pool_init, initargs=(bare,),
                mp_context=multiprocessing.get_context(method))
            self._executor_workers = workers
        return self._executor

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown()
            self._executor = None
            self._executor_workers = 0

    def __enter__(self) -> "CosmicEnv":
        self._in_context = True
        return self

    def __exit__(self, *exc) -> None:
        self._in_context = False
        self.close()

    def best(self) -> StepRecord | None:
        valid = [r for r in self.history if r.valid]
        return max(valid, key=lambda r: r.reward) if valid else None
