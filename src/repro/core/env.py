"""CosmicEnv: the ArchGym-style environment wrapping the simulator.

An agent submits a PsA configuration; the environment materializes the
(workload, collective, network, compute) stacks, runs the WTG + simulator,
and returns the reward.  Fixed parameters (single-stack baselines) are
handled upstream by ``ParameterSet.restrict`` — the env is stack-agnostic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

from repro.configs.base import ArchSpec
from repro.core.compute import Device
from repro.core.rewards import Evaluation, evaluate
from repro.core.simulator import SystemConfig
from repro.core.topology import Network, build_network
from repro.core.workload import Parallelism


@dataclass
class StepRecord:
    step: int
    config: dict[str, Any]
    reward: float
    latency_ms: float
    valid: bool


@dataclass
class CosmicEnv:
    spec: ArchSpec
    n_npus: int
    device: Device
    batch: int
    seq: int
    mode: str = "train"
    objective: str = "perf_per_bw"
    capacity_gb: float = 24.0
    fixed_network: Network | None = None   # for workload/collective-only DSE
    history: list[StepRecord] = field(default_factory=list)

    def _network(self, config: dict[str, Any]) -> Network:
        if self.fixed_network is not None and "topology" not in config:
            return self.fixed_network
        return build_network(config["topology"], config["npus_per_dim"],
                             config["bw_per_dim"])

    def step(self, config: dict[str, Any]) -> Evaluation:
        par = Parallelism(self.n_npus, config["dp"], config["sp"], config["pp"],
                          bool(config["weight_sharded"]))
        net = self._network(config)
        sys_cfg = SystemConfig(
            network=net, device=self.device,
            coll_algo=tuple(config["coll_algo"]),
            chunks=int(config["chunks"]),
            sched_policy=config["sched_policy"],
            multidim_coll=config["multidim_coll"],
        )
        ev = evaluate(self.spec, par, sys_cfg, batch=self.batch, seq=self.seq,
                      mode=self.mode, objective=self.objective,
                      capacity_gb=self.capacity_gb)
        self.history.append(StepRecord(len(self.history), config, ev.reward,
                                       ev.latency_ms, ev.valid))
        return ev

    def best(self) -> StepRecord | None:
        valid = [r for r in self.history if r.valid]
        return max(valid, key=lambda r: r.reward) if valid else None
