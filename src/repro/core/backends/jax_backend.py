"""JaxBackend: a jit+vmap-compiled levelized sweep over the dependency DAG,
optionally fused with the batched duration pass into one compiled call.

The reference event loop is inherently sequential per design point.  This
backend lowers the shared ``_SimPlan`` into a fixed-structure longest-path
sweep that XLA compiles once per trace shape and ``vmap`` evaluates for a
whole agent population in a single call.

The lowering: under an issue-order schedule, each resource runs its ops in
uid order (a topological order by the ``TraceBuilder``/
``compose_request_waves`` contract), so ``free[resource]`` at op *i* is
exactly the finish time of the previous op on *i*'s resource.  That turns
the whole schedule into a max-plus longest-path recurrence over the DAG
augmented with per-resource chain edges::

    finish[i] = dur[i] + max(finish[j] for j in deps[i] + {prev_on_res[i]})

The augmented-parent table is static per trace (built once, piggybacked on
the plan).  The per-design-point durations are the ONLY population-varying
input, and they come in two flavours:

  * FUSED (default): ``simulator.plan_duration_tables`` packs the whole
    population's collective dim tables + roofline coefficients host-side
    (memoized per design-point key), and one jit-compiled function per plan
    prices every duration class x population member with the vectorized
    collective evaluator (``collectives.multidim_collective_time_vec``) and
    feeds the durations straight into the scheduling sweep — no host
    round-trip between pricing and scheduling.
  * UNFUSED (``JaxBackend(fused=False)``, registered as ``jax-unfused``):
    the scalar per-call duration pass (vectorized roofline + memoized
    scalar collective model via ``simulator.plan_durations``) feeding the
    compiled sweep — the pre-fusion behaviour, kept as the measurable
    baseline for the duration-pass-vs-sweep time split.

``last_timings`` records the split after every ``simulate_batch``:
``durations_s`` (host-side duration pass: the scalar loop when unfused, the
memoized table packing when fused) and ``sweep_s`` (the compiled evaluation
— pricing + sweep together when fused).

Fidelity: each resource serializes its ops in issue order instead of the
reference loop's arrival-order (FIFO) / freshest-first (LIFO) queue
discipline, so makespans can deviate where a resource's queue reorders —
parity tests pin the tolerance (exact on every trace family shipped:
per-resource ready order follows issue order there).  Use the reference
backend when bit-exact schedules matter; use this one to sweep large
populations over large traces.
"""
from __future__ import annotations

import time
from collections.abc import Mapping
from typing import Any, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.simulator import (SimResult, SystemConfig, _SimPlan,
                                  batch_op_durations, build_sim_result,
                                  plan_duration_tables, plan_durations)
from repro.core.workload import Parallelism, Trace


@jax.jit
def _sweep_population(dur_t: jnp.ndarray,
                      parents_pad: jnp.ndarray) -> jnp.ndarray:
    """Finish time of every op for every population member.

    ``dur_t`` is (n_ops, P) — population on the trailing axis so the
    vmapped carry writes whole contiguous rows; ``parents_pad`` (n_ops, D)
    holds each op's augmented parents (deps + same-resource predecessor)
    padded with ``n_ops``, a dummy slot pinned to finish 0.  Returns
    (n_ops + 1, P) finish times (the dummy row last)."""
    n_ops = dur_t.shape[0]

    def one(d: jnp.ndarray) -> jnp.ndarray:
        def body(i, finish):
            fin = finish[parents_pad[i]].max() + d[i]
            return finish.at[i].set(fin)

        # modest unroll amortizes the while-loop dispatch overhead that
        # dominates this intrinsically sequential recurrence (~12% on the
        # 26k-op request-stream trace; measured 4/8/16/32, 16 is best)
        return lax.fori_loop(0, n_ops, body, jnp.zeros(n_ops + 1, d.dtype),
                             unroll=16)

    return jax.vmap(one, in_axes=1, out_axes=1)(dur_t)


def _plan_parents(trace: Trace, plan: _SimPlan) -> np.ndarray:
    """The plan's augmented-parent table, built once and piggybacked on the
    plan (plans are piggybacked on cached immutable traces)."""
    cached = getattr(plan, "_jax_parents", None)
    if cached is not None:
        return cached
    n = plan.n_ops
    last_on_res: dict[int, int] = {}
    rows: list[list[int]] = []
    for op in trace.ops:
        if any(d >= op.uid for d in op.deps):
            # the sweep reads parents' finish times in uid order; a forward
            # dep would silently read 0 where the reference loop deadlocks
            raise ValueError(f"op {op.uid} depends on a later op — the jax "
                             f"backend needs topologically-ordered uids "
                             f"(TraceBuilder/compose_request_waves traces)")
        r = plan.res_of[op.uid]
        row = list(op.deps)
        prev = last_on_res.get(r)
        if prev is not None:
            row.append(prev)
        last_on_res[r] = op.uid
        rows.append(row)
    width = max((len(row) for row in rows), default=0)
    parents = np.full((n, max(width, 1)), n, dtype=np.int32)
    for i, row in enumerate(rows):
        parents[i, :len(row)] = row
    plan._jax_parents = parents
    return parents


def _x64():
    """Double-precision tracing scoped to this backend's sweeps (the global
    default stays untouched for the pallas/kernel code paths)."""
    return jax.experimental.enable_x64()


def _fused_eval(plan: _SimPlan):
    """The per-plan fused kernel: population duration tables in, per-op
    durations AND finish times out, one jit-compiled call.

    Compiled per plan (the plan's scatter index arrays are closure
    constants, so the function identity must be plan-specific) and cached
    on it; XLA re-specializes per (population size, padded dim count) —
    both stable across the generations of a search."""
    fn = plan.pack_memo.get("_fused")
    if fn is None:
        def fused(tables, parents):
            # op-major durations feed the sweep with contiguous per-op rows
            # (the loop body reads one row per step) and ship to host
            # without a transpose — busy accounting scatters op-major too
            dur_t = batch_op_durations(plan, tables, xp=jnp, op_major=True)
            return dur_t, _sweep_population(dur_t, parents)
        fn = plan.pack_memo["_fused"] = jax.jit(fused)
    return fn


class FinishTimes(Mapping):
    """``SimResult.op_finish_us`` backed by the sweep's finish row — dict
    semantics (uid -> finish time) without materializing tens of thousands
    of boxed floats per design point; scenarios only read the wave-mark
    uids off it."""

    __slots__ = ("_row",)

    def __init__(self, row: np.ndarray) -> None:
        self._row = row

    def __getitem__(self, uid: int) -> float:
        # dict semantics, not array semantics: unknown uids must raise
        # KeyError (so `in`/`.get()` work) and never wrap negatively
        if not 0 <= uid < len(self._row):
            raise KeyError(uid)
        return float(self._row[uid])

    def __len__(self) -> int:
        return len(self._row)

    def __iter__(self):
        return iter(range(len(self._row)))


class JaxBackend:
    """Population-vectorized scheduling on the XLA-compiled levelized sweep.

    ``fused=True`` (the default, registered as ``jax``) prices durations
    inside the same compiled call as the sweep; ``fused=False`` (registered
    as ``jax-unfused``) keeps the scalar per-call duration pass feeding the
    sweep — the measurable pre-fusion baseline."""

    vectorized = True

    def __init__(self, fused: bool = True) -> None:
        self.fused = fused
        self.name = "jax" if fused else "jax-unfused"
        # duration-pass vs compiled-evaluation wall-time split of the most
        # recent simulate_batch (see module docstring)
        self.last_timings: dict[str, float] = {}

    def simulate(self, trace: Trace, cfg: SystemConfig, par: Parallelism, *,
                 pools: dict[int, Any] | None = None,
                 record_per_op: bool = False,
                 record_finish: bool = False) -> SimResult:
        from repro.core.backends.base import SimCall

        return self.simulate_batch(
            trace, [SimCall(trace, cfg, par, pools=pools,
                            record_per_op=record_per_op,
                            record_finish=record_finish)])[0]

    def simulate_batch(self, trace: Trace,
                       calls: Sequence[Any]) -> list[SimResult]:
        if not calls:
            return []
        t0 = time.perf_counter()
        if self.fused:
            plan, tables = plan_duration_tables(trace, calls)
            parents = plan.pack_memo.get("_parents_dev")
            t1 = time.perf_counter()
            with _x64():
                if parents is None:
                    # keep the static parent table resident on device — it
                    # is the same every batch and re-uploading it costs
                    # more than the entire class-table pack
                    parents = jnp.asarray(_plan_parents(trace, plan))
                    plan.pack_memo["_parents_dev"] = parents
                dur_d, finish_d = _fused_eval(plan)(tables, parents)
                dur = np.asarray(dur_d).T    # (P, n_ops) view, op-major data
                finish = np.asarray(finish_d)[:plan.n_ops].T
        else:
            plans_durs = [plan_durations(trace, c.cfg, c.par, c.pools)
                          for c in calls]
            plan = plans_durs[0][0]
            parents = _plan_parents(trace, plan)
            dur = np.asarray([d for _, d in plans_durs], dtype=np.float64)
            t1 = time.perf_counter()
            with _x64():
                finish = np.asarray(_sweep_population(
                    jnp.asarray(dur.T), jnp.asarray(parents)))[:plan.n_ops].T
        t2 = time.perf_counter()
        self.last_timings = {"durations_s": t1 - t0, "sweep_s": t2 - t1}
        makespan = finish.max(axis=1) if plan.n_ops else np.zeros(len(calls))
        res_of = np.asarray(plan.res_of, dtype=np.intp)
        n_res = len(plan.res_names)
        # whole-population busy accounting in one 2D scatter over
        # (population, resource).  Either broadcast orientation accumulates
        # each (member, resource) cell in increasing-uid order — the same
        # order as the per-call np.bincount it replaces — so every row is
        # bit-identical; iterate the orientation matching the duration
        # matrix's memory layout (op-major from the fused kernel)
        busy2d = np.zeros((len(calls), n_res), dtype=np.float64)
        if self.fused:
            np.add.at(busy2d.T,
                      (res_of[:, None],
                       np.arange(len(calls))[None, :]), dur.T)
        else:
            np.add.at(busy2d,
                      (np.arange(len(calls))[:, None], res_of[None, :]), dur)
        out: list[SimResult] = []
        for k, call in enumerate(calls):
            fin: Mapping = {}
            if call.record_per_op or call.record_finish:
                fin = FinishTimes(finish[k])
            out.append(build_sim_result(
                plan, makespan=float(makespan[k]), busy=busy2d[k].tolist(),
                dur=dur[k], finish=fin,
                record_per_op=call.record_per_op))
        return out
