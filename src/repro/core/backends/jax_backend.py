"""JaxBackend: a jit+vmap-compiled levelized sweep over the dependency DAG.

The reference event loop is inherently sequential per design point.  This
backend lowers the shared ``_SimPlan`` into a fixed-structure longest-path
sweep that XLA compiles once per trace shape and ``vmap`` evaluates for a
whole agent population in a single call.

The lowering: under an issue-order schedule, each resource runs its ops in
uid order (a topological order by the ``TraceBuilder``/
``compose_request_waves`` contract), so ``free[resource]`` at op *i* is
exactly the finish time of the previous op on *i*'s resource.  That turns
the whole schedule into a max-plus longest-path recurrence over the DAG
augmented with per-resource chain edges::

    finish[i] = dur[i] + max(finish[j] for j in deps[i] + {prev_on_res[i]})

The augmented-parent table is static per trace (built once, piggybacked on
the plan); the per-design-point durations (vectorized roofline + memoized
collective model, shared with the reference backend via
``simulator.plan_durations``) are the ONLY population-varying input, so the
compiled sweep is reused across every design point of the search.

Fidelity: each resource serializes its ops in issue order instead of the
reference loop's arrival-order (FIFO) / freshest-first (LIFO) queue
discipline, so makespans can deviate where a resource's queue reorders —
parity tests pin the tolerance (exact on every trace family shipped:
per-resource ready order follows issue order there).  Use the reference
backend when bit-exact schedules matter; use this one to sweep large
populations over large traces.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Any, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.simulator import (SimResult, SystemConfig, _SimPlan,
                                  build_sim_result, plan_durations)
from repro.core.workload import Parallelism, Trace


@jax.jit
def _sweep_population(dur_t: jnp.ndarray,
                      parents_pad: jnp.ndarray) -> jnp.ndarray:
    """Finish time of every op for every population member.

    ``dur_t`` is (n_ops, P) — population on the trailing axis so the
    vmapped carry writes whole contiguous rows; ``parents_pad`` (n_ops, D)
    holds each op's augmented parents (deps + same-resource predecessor)
    padded with ``n_ops``, a dummy slot pinned to finish 0.  Returns
    (n_ops + 1, P) finish times (the dummy row last)."""
    n_ops = dur_t.shape[0]

    def one(d: jnp.ndarray) -> jnp.ndarray:
        def body(i, finish):
            fin = finish[parents_pad[i]].max() + d[i]
            return finish.at[i].set(fin)

        return lax.fori_loop(0, n_ops, body, jnp.zeros(n_ops + 1, d.dtype))

    return jax.vmap(one, in_axes=1, out_axes=1)(dur_t)


def _plan_parents(trace: Trace, plan: _SimPlan) -> np.ndarray:
    """The plan's augmented-parent table, built once and piggybacked on the
    plan (plans are piggybacked on cached immutable traces)."""
    cached = getattr(plan, "_jax_parents", None)
    if cached is not None:
        return cached
    n = plan.n_ops
    last_on_res: dict[int, int] = {}
    rows: list[list[int]] = []
    for op in trace.ops:
        if any(d >= op.uid for d in op.deps):
            # the sweep reads parents' finish times in uid order; a forward
            # dep would silently read 0 where the reference loop deadlocks
            raise ValueError(f"op {op.uid} depends on a later op — the jax "
                             f"backend needs topologically-ordered uids "
                             f"(TraceBuilder/compose_request_waves traces)")
        r = plan.res_of[op.uid]
        row = list(op.deps)
        prev = last_on_res.get(r)
        if prev is not None:
            row.append(prev)
        last_on_res[r] = op.uid
        rows.append(row)
    width = max((len(row) for row in rows), default=0)
    parents = np.full((n, max(width, 1)), n, dtype=np.int32)
    for i, row in enumerate(rows):
        parents[i, :len(row)] = row
    plan._jax_parents = parents
    return parents


def _x64():
    """Double-precision tracing scoped to this backend's sweeps (the global
    default stays untouched for the pallas/kernel code paths)."""
    return jax.experimental.enable_x64()


class FinishTimes(Mapping):
    """``SimResult.op_finish_us`` backed by the sweep's finish row — dict
    semantics (uid -> finish time) without materializing tens of thousands
    of boxed floats per design point; scenarios only read the wave-mark
    uids off it."""

    __slots__ = ("_row",)

    def __init__(self, row: np.ndarray) -> None:
        self._row = row

    def __getitem__(self, uid: int) -> float:
        # dict semantics, not array semantics: unknown uids must raise
        # KeyError (so `in`/`.get()` work) and never wrap negatively
        if not 0 <= uid < len(self._row):
            raise KeyError(uid)
        return float(self._row[uid])

    def __len__(self) -> int:
        return len(self._row)

    def __iter__(self):
        return iter(range(len(self._row)))


class JaxBackend:
    """Population-vectorized scheduling on the XLA-compiled levelized sweep."""

    name = "jax"
    vectorized = True

    def simulate(self, trace: Trace, cfg: SystemConfig, par: Parallelism, *,
                 pools: dict[int, Any] | None = None,
                 record_per_op: bool = False,
                 record_finish: bool = False) -> SimResult:
        from repro.core.backends.base import SimCall

        return self.simulate_batch(
            trace, [SimCall(trace, cfg, par, pools=pools,
                            record_per_op=record_per_op,
                            record_finish=record_finish)])[0]

    def simulate_batch(self, trace: Trace,
                       calls: Sequence[Any]) -> list[SimResult]:
        if not calls:
            return []
        plans_durs = [plan_durations(trace, c.cfg, c.par, c.pools)
                      for c in calls]
        plan = plans_durs[0][0]
        parents = _plan_parents(trace, plan)
        dur = np.asarray([d for _, d in plans_durs], dtype=np.float64)
        with _x64():
            finish = np.asarray(_sweep_population(
                jnp.asarray(dur.T), jnp.asarray(parents)))[:plan.n_ops].T
        makespan = finish.max(axis=1) if plan.n_ops else np.zeros(len(calls))
        res_of = np.asarray(plan.res_of, dtype=np.intp)
        n_res = len(plan.res_names)
        out: list[SimResult] = []
        for k, call in enumerate(calls):
            busy = np.bincount(res_of, weights=dur[k], minlength=n_res)
            fin: Mapping = {}
            if call.record_per_op or call.record_finish:
                fin = FinishTimes(finish[k])
            out.append(build_sim_result(
                plan, makespan=float(makespan[k]), busy=busy.tolist(),
                dur=dur[k], finish=fin,
                record_per_op=call.record_per_op))
        return out
