"""Pluggable simulation backends (see ``backends.base`` for the API).

Built-ins:

  ``reference``  the discrete-event heapq loop — the semantics oracle,
                 bit-identical to the pre-backend ``simulate()``.
  ``jax``        jit+vmap-compiled levelized DAG sweep FUSED with the
                 batched duration pass — an entire population's collective
                 pricing, roofline and schedule evaluate in one compiled
                 call (requires the ``jax`` optional extra).
  ``jax-unfused`` the same compiled sweep fed by the scalar per-call
                 duration pass — the pre-fusion baseline, kept so the
                 duration-pass-vs-sweep split stays measurable.
"""
from __future__ import annotations

from repro.core.backends.base import (BACKEND_REGISTRY, SimBackend, SimCall,
                                      SimJob, backend_available, get_backend,
                                      list_backends, register_backend,
                                      run_sim_job, run_sim_jobs)


def _reference_factory() -> SimBackend:
    from repro.core.backends.reference import ReferenceBackend

    return ReferenceBackend()


def _jax_factory(fused: bool = True):
    def factory() -> SimBackend:
        try:
            from repro.core.backends.jax_backend import JaxBackend
        except ImportError as e:
            raise ImportError(
                "the 'jax' simulation backend needs jax installed — "
                "pip install 'cosmic-repro[jax]'") from e
        return JaxBackend(fused=fused)
    return factory


register_backend("reference", _reference_factory,
                 doc="discrete-event heapq loop (bit-exact oracle, default)")
register_backend("jax", _jax_factory(fused=True),
                 doc="fused jit+vmap evaluation — vectorized collective + "
                     "roofline pricing and the levelized DAG sweep in one "
                     "compiled call (needs the jax extra)")
register_backend("jax-unfused", _jax_factory(fused=False),
                 doc="jit+vmap levelized DAG sweep fed by the scalar "
                     "per-call duration pass (pre-fusion baseline)")

__all__ = [
    "BACKEND_REGISTRY", "SimBackend", "SimCall", "SimJob",
    "backend_available", "get_backend", "list_backends", "register_backend",
    "run_sim_job", "run_sim_jobs",
]
