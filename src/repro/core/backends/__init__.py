"""Pluggable simulation backends (see ``backends.base`` for the API).

Built-ins:

  ``reference``  the discrete-event heapq loop — the semantics oracle,
                 bit-identical to the pre-backend ``simulate()``.
  ``jax``        jit+vmap-compiled levelized DAG sweep — evaluates a whole
                 agent population against one shared scheduling plan per
                 call (requires the ``jax`` optional extra).
"""
from __future__ import annotations

from repro.core.backends.base import (BACKEND_REGISTRY, SimBackend, SimCall,
                                      SimJob, backend_available, get_backend,
                                      list_backends, register_backend,
                                      run_sim_job, run_sim_jobs)


def _reference_factory() -> SimBackend:
    from repro.core.backends.reference import ReferenceBackend

    return ReferenceBackend()


def _jax_factory() -> SimBackend:
    try:
        from repro.core.backends.jax_backend import JaxBackend
    except ImportError as e:
        raise ImportError(
            "the 'jax' simulation backend needs jax installed — "
            "pip install 'cosmic-repro[jax]'") from e
    return JaxBackend()


register_backend("reference", _reference_factory,
                 doc="discrete-event heapq loop (bit-exact oracle, default)")
register_backend("jax", _jax_factory,
                 doc="jit+vmap levelized DAG sweep — population-vectorized "
                     "simulate_batch (needs the jax extra)")

__all__ = [
    "BACKEND_REGISTRY", "SimBackend", "SimCall", "SimJob",
    "backend_available", "get_backend", "list_backends", "register_backend",
    "run_sim_job", "run_sim_jobs",
]
