"""The simulation-backend API: how a trace gets evaluated is a first-class,
swappable axis of the engine.

A ``SimBackend`` turns (trace, system config, parallelization) into a
``SimResult``.  Two entry points:

  * ``simulate(trace, cfg, par, pools=..., ...)`` — one design point, the
    drop-in contract of the original ``core.simulator.simulate`` (which is
    now a thin delegate onto the selected backend);
  * ``simulate_batch(trace, calls)`` — a whole agent population evaluated
    against ONE shared scheduling plan (``core.simulator._sim_plan``), the
    seam vectorized backends exploit: the trace-dependent structure is
    resolved once and only the per-design-point durations vary.

Backends register in ``BACKEND_REGISTRY`` by name (factories, so optional
heavy deps — jax — import only when the backend is actually requested);
``get_backend`` resolves names to process-wide singletons.  ``repro.dse
list-backends`` enumerates the registry.

Scenarios talk to backends through ``SimJob``: a declarative bundle of
``SimCall``s plus a ``finalize`` closure turning the results into one
``Evaluation``.  ``run_sim_job`` executes one job; ``run_sim_jobs``
executes a population of jobs, grouping calls that share a trace so a
vectorized backend sweeps each shared plan in a single ``simulate_batch``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

from repro.core.simulator import SimResult, SystemConfig
from repro.core.workload import Parallelism, Trace


@dataclass(frozen=True)
class SimCall:
    """One simulator invocation a scenario wants executed: the positional
    ``simulate()`` arguments plus the opt-in recording flags."""
    trace: Trace
    cfg: SystemConfig
    par: Parallelism
    pools: dict[int, Any] | None = None
    record_per_op: bool = False
    record_finish: bool = False


@dataclass(frozen=True)
class SimJob:
    """Everything one design point needs simulated, plus how to turn the
    results into an ``Evaluation``.  ``finalize`` receives the ``SimResult``s
    in ``calls`` order.  Scenarios return a ``SimJob`` (or a terminal
    ``Evaluation`` for gated-invalid points) from ``sim_job(ctx)``; the
    generic drivers below execute it on any backend."""
    calls: tuple[SimCall, ...]
    finalize: Callable[[list[SimResult]], Any]


@runtime_checkable
class SimBackend(Protocol):
    """Structural protocol for simulation backends.

    ``vectorized`` declares that ``simulate_batch`` genuinely evaluates the
    population in one sweep (rather than looping ``simulate``) — the env's
    batched evaluation path only reroutes through ``run_sim_jobs`` for
    vectorized backends, keeping the reference path bit-identical to serial
    evaluation."""

    name: str
    vectorized: bool

    def simulate(self, trace: Trace, cfg: SystemConfig, par: Parallelism, *,
                 pools: dict[int, Any] | None = None,
                 record_per_op: bool = False,
                 record_finish: bool = False) -> SimResult: ...

    def simulate_batch(self, trace: Trace,
                       calls: Sequence[SimCall]) -> list[SimResult]: ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# name -> (factory, one-line doc).  Factories defer heavy imports (jax) to
# first use; ``get_backend`` memoizes the constructed singleton.
BACKEND_REGISTRY: dict[str, tuple[Callable[[], SimBackend], str]] = {}
_instances: dict[str, SimBackend] = {}


def register_backend(name: str, factory: Callable[[], SimBackend], *,
                     doc: str = "", replace: bool = False) -> None:
    if not replace and name in BACKEND_REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    BACKEND_REGISTRY[name] = (factory, doc)
    _instances.pop(name, None)


def get_backend(backend: "str | SimBackend | None") -> SimBackend:
    """Resolve a backend name to its process-wide instance (or pass an
    instance through).  ``None`` resolves to the reference backend."""
    if backend is None:
        backend = "reference"
    if not isinstance(backend, str):
        return backend
    inst = _instances.get(backend)
    if inst is None:
        try:
            factory, _ = BACKEND_REGISTRY[backend]
        except KeyError:
            raise ValueError(f"unknown simulation backend {backend!r}; "
                             f"known: {sorted(BACKEND_REGISTRY)}") from None
        inst = _instances[backend] = factory()
    return inst


def list_backends() -> dict[str, str]:
    """name -> one-line description (no instantiation: an unavailable
    optional backend still lists, and fails with a clear error on use)."""
    return {name: doc for name, (_, doc) in BACKEND_REGISTRY.items()}


def backend_available(name: str) -> bool:
    """True when the backend's dependencies import (instantiates it)."""
    try:
        get_backend(name)
        return True
    except (ImportError, ValueError):
        return False


# ---------------------------------------------------------------------------
# Job drivers
# ---------------------------------------------------------------------------

def run_sim_job(job: Any, backend: "str | SimBackend | None" = None, *,
                verify: bool = False) -> Any:
    """Execute one scenario job on a backend.  A non-``SimJob`` input (a
    terminal ``Evaluation`` from a gated-invalid design point) passes
    through untouched.

    ``verify=True`` statically checks each call's scheduling plan first
    (``repro.core.analysis.verify_trace`` — acyclicity, dangling dep /
    resource references, pool feasibility) and raises
    ``PlanVerificationError`` instead of handing a defective plan to the
    event loop; the verdict is memoized per trace, so the steady-state
    cost is a dict lookup."""
    if not isinstance(job, SimJob):
        return job
    if verify:
        from repro.core.analysis import verify_trace  # lazy: avoids a cycle
        for c in job.calls:
            verify_trace(c.trace, c.cfg, c.par, c.pools).raise_if_issues()
    be = get_backend(backend)
    results = [be.simulate(c.trace, c.cfg, c.par, pools=c.pools,
                           record_per_op=c.record_per_op,
                           record_finish=c.record_finish)
               for c in job.calls]
    return job.finalize(results)


def run_sim_jobs(jobs: Sequence[Any],
                 backend: "str | SimBackend | None" = None) -> list[Any]:
    """Execute a population of scenario jobs, batching calls that share a
    trace into one ``simulate_batch`` per shared scheduling plan.

    Calls are grouped by trace identity (traces are interned by the WTG
    cache, so design points differing only in non-trace-shaping knobs share
    the object — and its piggybacked ``_SimPlan``).  Results are finalized
    in input order; non-``SimJob`` entries pass through untouched."""
    be = get_backend(backend)
    # (job index, call index) slots to fill, grouped by trace identity
    groups: dict[int, tuple[Trace, list[tuple[int, int]]]] = {}
    slots: list[list[SimResult | None]] = []
    for ji, job in enumerate(jobs):
        if not isinstance(job, SimJob):
            slots.append([])
            continue
        slots.append([None] * len(job.calls))
        for ci, call in enumerate(job.calls):
            key = id(call.trace)
            entry = groups.get(key)
            if entry is None or entry[0] is not call.trace:
                groups[key] = entry = (call.trace, [])
            entry[1].append((ji, ci))
    for trace, members in groups.values():
        calls = [jobs[ji].calls[ci] for ji, ci in members]
        results = be.simulate_batch(trace, calls)
        for (ji, ci), res in zip(members, results):
            slots[ji][ci] = res
    out = []
    for ji, job in enumerate(jobs):
        if not isinstance(job, SimJob):
            out.append(job)
            continue
        out.append(job.finalize(list(slots[ji])))
    return out
