"""ReferenceBackend: the original discrete-event heapq scheduler.

This IS the pre-backend ``core.simulator.simulate`` event loop, moved here
verbatim — bit-identical results are pinned by golden tests.  Ready ops
queue on their resource; the queue discipline is the paper's Collective
'Scheduling Policy' knob (LIFO favours the freshest — critical-path —
collectives, FIFO drains in issue order).  Compute/comm overlap falls out
of the event loop, so exposed communication is measured, not assumed.

``simulate_batch`` is the honest loop over ``simulate`` — the reference
backend is the semantics oracle, not the fast path; vectorized backends
(``jax_backend``) exploit the shared-plan seam instead.
"""
from __future__ import annotations

import heapq
from typing import Any, Sequence

from repro.core.simulator import (SimResult, SystemConfig, build_sim_result,
                                  plan_durations)
from repro.core.workload import Parallelism, Trace


class ReferenceBackend:
    """The heapq discrete-event loop (the engine's original scheduler)."""

    name = "reference"
    vectorized = False

    def simulate(self, trace: Trace, cfg: SystemConfig, par: Parallelism, *,
                 pools: dict[int, Any] | None = None,
                 record_per_op: bool = False,
                 record_finish: bool = False) -> SimResult:
        plan, dur_arr = plan_durations(trace, cfg, par, pools)
        dur = dur_arr.tolist()  # python floats: fastest for the event loop

        n_res = len(plan.res_names)
        ndeps = list(plan.ndeps0)
        children = plan.children
        res_of = plan.res_of
        queues: list[list[tuple[int, int]]] = [[] for _ in range(n_res)]
        free_at = [0.0] * n_res
        busy = [0.0] * n_res
        sign = -1 if cfg.sched_policy == "lifo" else 1
        seq = 0  # enqueue order tiebreaker
        hpush, hpop = heapq.heappush, heapq.heappop

        events: list[tuple[float, int, int]] = []  # (time, eseq, uid)
        eseq = 0
        n_finished = 0
        finish: dict[int, float] = {}
        track_finish = record_per_op or record_finish

        for uid in plan.roots:
            seq += 1
            hpush(queues[res_of[uid]], (sign * seq, uid))
        for r in range(n_res):
            if queues[r]:
                _, uid = hpop(queues[r])
                d = dur[uid]
                free_at[r] = d
                busy[r] += d
                eseq += 1
                hpush(events, (d, eseq, uid))

        makespan = 0.0
        while events:
            now, _, uid = hpop(events)
            n_finished += 1
            if track_finish:
                finish[uid] = now
            if now > makespan:
                makespan = now
            # only the freed resource and resources receiving new work can
            # start an op here: any other free resource with queued work
            # would already have been started when it last freed (the
            # loop's invariant)
            cand = [res_of[uid]]
            for ch in children[uid]:
                ndeps[ch] -= 1
                if ndeps[ch] == 0:
                    seq += 1
                    r = res_of[ch]
                    hpush(queues[r], (sign * seq, ch))
                    if r not in cand:
                        cand.append(r)
            for r in cand:
                if free_at[r] <= now and queues[r]:
                    _, nxt = hpop(queues[r])
                    d = dur[nxt]
                    free_at[r] = now + d
                    busy[r] += d
                    eseq += 1
                    hpush(events, (now + d, eseq, nxt))

        if n_finished != plan.n_ops:
            raise RuntimeError(
                f"deadlock: {n_finished}/{plan.n_ops} ops finished")

        return build_sim_result(plan, makespan=makespan, busy=busy, dur=dur,
                                finish=finish, record_per_op=record_per_op)

    def simulate_batch(self, trace: Trace,
                       calls: Sequence[Any]) -> list[SimResult]:
        return [self.simulate(trace, c.cfg, c.par, pools=c.pools,
                              record_per_op=c.record_per_op,
                              record_finish=c.record_finish)
                for c in calls]
