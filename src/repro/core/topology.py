"""Multi-dimensional network fabrics from {Ring, Switch, FullyConnected}
building blocks (paper Fig. 3), with link counts and a LIBRA-style dollar
cost model for the Perf-per-Network-Cost reward.

Heterogeneous sub-partitions: a ``Cluster`` carves one physical fabric into
disjoint ``Partition``s (an NPU range + the sub-network it spans + its own
compute device), the substrate for multi-tenant DSE where each tenant owns a
slice of a possibly heterogeneous machine."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from repro.core.cache import switchable_lru_cache

if TYPE_CHECKING:  # no runtime dep: compute.py never imports topology
    from repro.core.compute import Device

TOPO_KINDS = ("ring", "switch", "fc")


@dataclass(frozen=True)
class TopoDim:
    kind: str            # ring | switch | fc
    npus: int            # NPUs along this dimension
    bw: float            # GB/s per link (paper's 'Bandwidth per Dim')
    latency_us: float = 0.5  # per-hop link latency

    def __post_init__(self):
        if self.kind not in TOPO_KINDS:
            raise ValueError(f"unknown topology kind {self.kind}")
        if self.npus < 2:
            raise ValueError("a network dimension needs >= 2 NPUs")

    # -- structural properties -------------------------------------------
    def links(self) -> int:
        """Physical links along this dim (per group of `npus`)."""
        n = self.npus
        if self.kind == "ring":
            return n                      # unidirectional ring of n links
        if self.kind == "switch":
            return n                      # n NPU<->switch links
        return n * (n - 1) // 2           # fully connected

    def links_per_npu(self) -> int:
        if self.kind == "ring":
            return 2                      # tx+rx neighbours (bidirectional)
        if self.kind == "switch":
            return 1
        return self.npus - 1

    def bisection_bw(self) -> float:
        n = self.npus
        if self.kind == "ring":
            return 2 * self.bw
        if self.kind == "switch":
            return (n // 2) * self.bw
        return (n // 2) * (n - n // 2) * self.bw / 1.0


@dataclass(frozen=True)
class Network:
    dims: tuple[TopoDim, ...]

    @property
    def n_npus(self) -> int:
        n = 1
        for d in self.dims:
            n *= d.npus
        return n

    def describe(self) -> str:
        return " x ".join(f"{d.kind}({d.npus})@{d.bw}GB/s" for d in self.dims)

    # -- LIBRA-style dollar cost ------------------------------------------
    # $/ (GB/s) per link, by technology tier: dim 0 is the cheapest
    # (on-board electrical), outer dims get progressively more expensive
    # (optical / switched fabrics).  Switch ports add a fixed premium.
    _LINK_COST_PER_GBPS = (1.0, 2.0, 6.0, 12.0)
    _SWITCH_PREMIUM = 1.5  # switched dims pay for the switch silicon

    def dollar_cost(self) -> float:
        total = 0.0
        n = self.n_npus
        for i, d in enumerate(self.dims):
            tier = self._LINK_COST_PER_GBPS[min(i, len(self._LINK_COST_PER_GBPS) - 1)]
            n_groups = n // d.npus      # how many parallel copies of this dim
            cost = d.links() * d.bw * tier
            if d.kind == "switch":
                cost *= self._SWITCH_PREMIUM
            total += cost * n_groups
        return total

    def bw_per_npu(self) -> float:
        """Sum of per-dim bandwidth allocated to each NPU (the paper's
        'BW per NPU' regularizer denominator)."""
        return sum(d.bw for d in self.dims)


@switchable_lru_cache(maxsize=8192)
def _build_network_cached(topology: tuple, npus_per_dim: tuple,
                          bw_per_dim: tuple, latency_us: tuple) -> Network:
    dims = tuple(
        TopoDim(t, int(n), float(b), float(l))
        for t, n, b, l in zip(topology, npus_per_dim, bw_per_dim, latency_us)
    )
    return Network(dims)


def build_network(topology: Sequence[str], npus_per_dim: Sequence[int],
                  bw_per_dim: Sequence[float],
                  latency_us: Sequence[float] | float = 0.5) -> Network:
    if isinstance(latency_us, (int, float)):
        latency_us = (float(latency_us),) * len(topology)
    # memoized: a search population re-resolves the same handful of fabric
    # configs every generation (Network is frozen, so sharing is safe)
    return _build_network_cached(tuple(topology), tuple(npus_per_dim),
                                 tuple(bw_per_dim), tuple(latency_us))


def carve_dims(dims: Sequence[TopoDim], caps: list[int],
               need: int) -> list[tuple[int, TopoDim]]:
    """THE carving rule: gcd-take ``need`` NPUs from ``dims`` innermost
    first, consuming the (mutated) per-dim capacities ``caps``; a residual
    factor no dim covers becomes a virtual dim at the outermost — slowest —
    tier's speed so its traffic is never free.  Each carved dim is returned
    as ``(source_dim_index, TopoDim)`` so callers can resolve per-physical-
    dim configuration (e.g. the Collective stack's per-dim algorithm knob)
    against the dim the traffic actually rides; residual virtual dims carry
    the outermost dim's index.  Shared by ``sub_network`` (partition
    fabrics) and ``simulator.group_dims`` (parallelism-group mapping) so
    the two can't diverge."""
    out: list[tuple[int, TopoDim]] = []
    for i, d in enumerate(dims):
        if need <= 1:
            break
        if caps[i] <= 1:
            continue
        take = math.gcd(need, caps[i])
        if take <= 1:
            continue
        out.append((i, TopoDim(d.kind, take, d.bw, d.latency_us)))
        caps[i] //= take
        need //= take
    if need > 1 and dims:
        last = dims[-1]
        out.append((len(dims) - 1, TopoDim(last.kind, need, last.bw,
                                           last.latency_us)))
    return out


def sub_network(net: Network, n: int) -> Network:
    """The sub-fabric a contiguous group of ``n`` NPUs spans (see
    ``carve_dims``), so a partition's collectives see the link tiers its
    NPUs would actually occupy."""
    return sub_network_indexed(net, n)[0]


def sub_network_indexed(net: Network, n: int) -> tuple[Network, tuple[int, ...]]:
    """``sub_network`` plus each sub-dim's source physical dim index, so
    multi-pool simulations can resolve per-physical-dim configuration (the
    Collective stack's per-dim algorithms) against the parent fabric's dims
    instead of the sub-fabric's positions."""
    carved = carve_dims(net.dims, [d.npus for d in net.dims], n)
    return (Network(tuple(d for _, d in carved)),
            tuple(i for i, _ in carved))


@dataclass(frozen=True)
class Partition:
    """A disjoint slice of a cluster: NPUs [offset, offset+n_npus), the
    sub-network they span, and the compute device installed there (per-
    partition devices are what makes a cluster heterogeneous)."""
    name: str
    offset: int
    n_npus: int
    network: Network
    device: "Device"

    def npu_range(self) -> tuple[int, int]:
        return (self.offset, self.offset + self.n_npus)

    def describe(self) -> str:
        lo, hi = self.npu_range()
        return f"{self.name}: npus[{lo}:{hi}) {self.device.name} {self.network.describe()}"


@dataclass(frozen=True)
class Cluster:
    """Disjoint partitions of one physical fabric (multi-tenant substrate)."""
    partitions: tuple[Partition, ...]
    total_npus: int

    def describe(self) -> str:
        return " | ".join(p.describe() for p in self.partitions)


def partition_cluster(net: Network, sizes: Sequence[int],
                      devices: Sequence["Device"],
                      names: Sequence[str] | None = None) -> Cluster:
    """Carve ``net`` into disjoint partitions of ``sizes[i]`` NPUs with
    ``devices[i]`` installed.  Raises if the sizes oversubscribe the fabric —
    callers that search partition sizes gate that to reward 0 instead."""
    if len(sizes) != len(devices):
        raise ValueError(f"{len(sizes)} partition sizes but "
                         f"{len(devices)} devices")
    if sum(sizes) > net.n_npus:
        raise ValueError(f"partitions {list(sizes)} oversubscribe "
                         f"{net.n_npus}-NPU cluster")
    parts = []
    off = 0
    for i, (n, dev) in enumerate(zip(sizes, devices)):
        name = names[i] if names else f"part{i}"
        parts.append(Partition(name, off, n, sub_network(net, n), dev))
        off += n
    return Cluster(tuple(parts), net.n_npus)


# -- the paper's Table 3 systems -------------------------------------------

def system_1() -> Network:
    """512 TPUv5p-like: [RI, RI, RI, SW], 4x4x4x8, [200,200,200,50]."""
    return build_network(("ring", "ring", "ring", "switch"), (4, 4, 4, 8),
                         (200, 200, 200, 50))


def system_2() -> Network:
    """1,024 NPUs 4D (Themis-like): [RI, FC, RI, SW], 4x8x4x8."""
    return build_network(("ring", "fc", "ring", "switch"), (4, 8, 4, 8),
                         (375, 175, 150, 100))


def system_3() -> Network:
    """2,048 H100-like: [FC, SW, RI, RI], 8x16x4x4."""
    return build_network(("fc", "switch", "ring", "ring"), (8, 16, 4, 4),
                         (900, 100, 50, 12.5))


def tpu_v5e_pod() -> Network:
    """Our dry-run target: 16x16 pod, 2D torus-ish ICI at ~50 GB/s/link."""
    return build_network(("ring", "ring"), (16, 16), (50, 50), latency_us=0.3)
