"""Design-space-exploration driver: agent x environment loop with
convergence bookkeeping (reward-vs-step curves, steps-to-peak — the data
behind the paper's Fig. 9/10).

The loop is batch-driven: each round asks the agent for a population of
``batch_size`` proposals, pushes them through ``CosmicEnv.step_batch``
(memoized, optionally on a process pool), and feeds every reward back at
once.  ``batch_size=1`` reproduces the sequential propose/step/observe loop
exactly — same RNG stream, same rewards, same convergence bookkeeping.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.agents import make_agent
from repro.core.env import CosmicEnv
from repro.core.psa import ParameterSet
from repro.core.space import DesignSpace


@dataclass
class SearchResult:
    agent: str
    steps: int
    best_reward: float
    best_config: dict[str, Any] | None
    best_latency_ms: float
    steps_to_peak: int
    reward_curve: list[float]
    invalid_rate: float
    wall_s: float
    batch_size: int = 1
    points_per_s: float = 0.0
    # corpus records handed to the agent before step 0 (surrogate warm
    # start from a persistent eval store); 0 for agents without one
    warm_start_points: int = 0

    def summary(self) -> dict[str, Any]:
        return {
            "agent": self.agent, "steps": self.steps,
            "best_reward": self.best_reward,
            "best_latency_ms": self.best_latency_ms,
            "steps_to_peak": self.steps_to_peak,
            "invalid_rate": round(self.invalid_rate, 4),
            "wall_s": round(self.wall_s, 2),
            "batch_size": self.batch_size,
            "points_per_s": round(self.points_per_s, 1),
        }


def run_search(pset: ParameterSet, env: CosmicEnv, agent_kind: str = "ga",
               steps: int = 500, seed: int = 0, batch_size: int = 1,
               workers: int = 0, warm_start: Any = None,
               **agent_hyper) -> SearchResult:
    """Explore ``steps`` design points.

    batch_size: population evaluated per agent round (1 = sequential).
    workers:    >1 fans distinct points of each batch out to a process pool.
    warm_start: optional (config, reward) records from prior campaigns
                (e.g. a persistent eval store); handed to the agent's
                ``warm_start()`` before step 0 when it has one — a
                surrogate agent starts with a trained predictor instead of
                burning its budget on warmup coverage.  Agents without a
                ``warm_start`` method ignore the records.
    """
    space = DesignSpace(pset)
    agent = make_agent(agent_kind, space, seed=seed, **agent_hyper)
    warm_n = 0
    if warm_start and hasattr(agent, "warm_start"):
        warm_n = agent.warm_start(warm_start)
    t0 = time.time()
    curve: list[float] = []
    best, best_step, best_lat = -np.inf, 0, float("inf")
    best_cfg = None
    n_invalid = 0
    i = 0
    # reap a pool this search causes to exist, but leave one the caller set
    # up (context-managed env) alone so it can amortize across searches
    caller_owns_pool = env.pool_is_caller_managed()
    try:
        while i < steps:
            n = min(max(batch_size, 1), steps - i)
            cfgs = agent.propose_batch(n)
            evs = env.step_batch(cfgs, workers=workers)
            agent.observe_batch(cfgs, [ev.reward for ev in evs])
            for cfg, ev in zip(cfgs, evs):
                n_invalid += not ev.valid
                if ev.reward > best:
                    best, best_step, best_cfg, best_lat = ev.reward, i, cfg, ev.latency_ms
                curve.append(best)
                i += 1
    finally:
        if workers > 1 and not caller_owns_pool:
            env.close()  # don't leak pool workers past the search
    wall = time.time() - t0
    return SearchResult(
        agent=agent_kind, steps=steps, best_reward=float(best),
        best_config=best_cfg, best_latency_ms=float(best_lat),
        steps_to_peak=best_step, reward_curve=curve,
        invalid_rate=n_invalid / max(steps, 1), wall_s=wall,
        batch_size=max(batch_size, 1),
        points_per_s=steps / max(wall, 1e-9),
        warm_start_points=warm_n,
    )
