"""Design-space-exploration driver: agent x environment loop with
convergence bookkeeping (reward-vs-step curves, steps-to-peak — the data
behind the paper's Fig. 9/10)."""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.agents import make_agent
from repro.core.env import CosmicEnv
from repro.core.psa import ParameterSet
from repro.core.space import DesignSpace


@dataclass
class SearchResult:
    agent: str
    steps: int
    best_reward: float
    best_config: dict[str, Any] | None
    best_latency_ms: float
    steps_to_peak: int
    reward_curve: list[float]
    invalid_rate: float
    wall_s: float

    def summary(self) -> dict[str, Any]:
        return {
            "agent": self.agent, "steps": self.steps,
            "best_reward": self.best_reward,
            "best_latency_ms": self.best_latency_ms,
            "steps_to_peak": self.steps_to_peak,
            "invalid_rate": round(self.invalid_rate, 4),
            "wall_s": round(self.wall_s, 2),
        }


def run_search(pset: ParameterSet, env: CosmicEnv, agent_kind: str = "ga",
               steps: int = 500, seed: int = 0, **agent_hyper) -> SearchResult:
    space = DesignSpace(pset)
    agent = make_agent(agent_kind, space, seed=seed, **agent_hyper)
    t0 = time.time()
    curve: list[float] = []
    best, best_step, best_lat = -np.inf, 0, float("inf")
    best_cfg = None
    n_invalid = 0
    for i in range(steps):
        cfg = agent.propose()
        ev = env.step(cfg)
        agent.observe(cfg, ev.reward)
        n_invalid += not ev.valid
        if ev.reward > best:
            best, best_step, best_cfg, best_lat = ev.reward, i, cfg, ev.latency_ms
        curve.append(best)
    return SearchResult(
        agent=agent_kind, steps=steps, best_reward=float(best),
        best_config=best_cfg, best_latency_ms=float(best_lat),
        steps_to_peak=best_step, reward_curve=curve,
        invalid_rate=n_invalid / max(steps, 1), wall_s=time.time() - t0,
    )
