"""repro.core.fleet — serving at fleet scale.

Generalizes ``RequestStreamScenario``'s single engine to N model replicas
over a (possibly heterogeneous) ``topology.Cluster``:

  * **Arrival-trace generators** — seeded, deterministic request streams:
    homogeneous Poisson, ``diurnal`` (sinusoidal non-homogeneous Poisson —
    the day/night cycle of millions-of-users traffic), ``bursty``
    (Markov-modulated Poisson: calm <-> burst phases), and ``replayed``
    (cycled inter-arrival gaps from a production trace).
  * **Router policies** — deterministic pre-simulation request->replica
    assignment: ``round-robin``, ``least-outstanding`` (greedy virtual-queue
    argmin under an analytic service-time estimate), and ``prefix-hash``
    (session-affinity hashing; with ``n_sessions > 0`` a replica-local
    prefix-cache hit shrinks the request's effective prompt).
  * **Autoscaler** — target-utilization up/down with cooldown over fixed
    decision epochs; replicas scaled down stop accruing provisioned cost.
  * **``FleetScenario``** — each replica's routed sub-stream evaluates
    through the shared ``RequestStreamScenario.stream_call`` engine core as
    one ``SimCall`` on the replica's cluster partition, so the whole fleet
    is a single ``SimJob`` and vectorized backends sweep replicas like
    population members.  Fleet metrics concatenate per-replica per-request
    arrays; the ``goodput_per_dollar`` objective divides by the dollars of
    capacity *actually provisioned* (``StreamMetrics.provisioned_cost``).

A 1-replica fleet with a static router/autoscaler and preemption off
reduces bit-identically to ``RequestStreamScenario`` — the subsystem
provably contains the single-engine model (see ``tests/test_fleet.py``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, replace
from typing import Any, ClassVar, Mapping

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.backends import SimJob, run_sim_job
from repro.core.cache import switchable_lru_cache
from repro.core.compute import DEVICES, Device
from repro.core.psa import Constraint, Parameter
from repro.core.rewards import Evaluation, stream_metrics, stream_reward
from repro.core.scenario import (EnvContext, RequestStreamScenario, _invalid,
                                 _arrivals_cached, _request_shapes_cached,
                                 _request_tiers_cached,
                                 dataclass_scenario_builder, register_scenario)
from repro.core.simulator import SimResult
from repro.core.topology import Cluster, partition_cluster

# ---------------------------------------------------------------------------
# Arrival-trace generators
# ---------------------------------------------------------------------------

ARRIVAL_KINDS = ("poisson", "diurnal", "bursty", "replayed")


def _diurnal_times_impl(n: int, base_rps: float, peak_rps: float,
                        period_s: float, seed: int) -> tuple[float, ...]:
    """Non-homogeneous Poisson arrivals under a sinusoidal rate that starts
    at the trough: ``rate(t) = base + (peak-base) * (1 - cos(2*pi*t/T))/2``.
    Each gap is drawn exponential at the instantaneous rate (the rate moves
    slowly against the gaps, so the realized mean tracks ``(base+peak)/2``
    over whole periods)."""
    rng = np.random.default_rng([seed, 0xD1])
    period_ms = max(period_s, 1e-9) * 1e3
    t, out = 0.0, []
    for _ in range(n):
        r = base_rps + (peak_rps - base_rps) * 0.5 \
            * (1.0 - math.cos(2.0 * math.pi * t / period_ms))
        t += rng.exponential(1000.0 / max(r, 1e-9))
        out.append(t)
    return tuple(out)


def _bursty_times_impl(n: int, rate_rps: float, burst_factor: float,
                       burst_s: float, seed: int) -> tuple[float, ...]:
    """Markov-modulated Poisson arrivals: calm phases at ``rate_rps``,
    burst phases at ``rate_rps * burst_factor``; mean dwell ``burst_s`` in
    a burst and ``3 * burst_s`` calm (so ~25% of wall time is burst)."""
    rng = np.random.default_rng([seed, 0xB5])
    t, burst, out = 0.0, False, []
    for _ in range(n):
        r = rate_rps * (burst_factor if burst else 1.0)
        g = rng.exponential(1000.0 / max(r, 1e-9))
        t += g
        out.append(t)
        dwell_ms = (burst_s if burst else 3.0 * burst_s) * 1e3
        if rng.random() < 1.0 - math.exp(-g / max(dwell_ms, 1e-9)):
            burst = not burst
    return tuple(out)


_diurnal_times = switchable_lru_cache(maxsize=64)(_diurnal_times_impl)
_bursty_times = switchable_lru_cache(maxsize=64)(_bursty_times_impl)


def arrival_times_ms(kind: str, n: int, *, rate_rps: float = 8.0,
                     peak_rps: float = 0.0, period_s: float = 60.0,
                     burst_factor: float = 4.0, burst_s: float = 2.0,
                     gaps_ms: tuple = (), seed: int = 0) -> tuple[float, ...]:
    """Deterministic seeded arrival times for one of ``ARRIVAL_KINDS``.
    ``poisson`` and ``replayed`` delegate to the engine's generator (same
    draws as ``RequestStreamScenario`` — the fleet reduction depends on
    this); ``diurnal`` defaults its peak to ``2 * rate_rps`` when
    ``peak_rps`` is unset."""
    if n < 1:
        raise ValueError(f"need n >= 1 arrivals, got {n}")
    if kind == "poisson":
        return _arrivals_cached((), n, rate_rps, seed)
    if kind == "replayed":
        if not gaps_ms:
            raise ValueError("replayed arrivals need arrival_gaps_ms")
        return _arrivals_cached(tuple(gaps_ms), n, rate_rps, seed)
    if kind == "diurnal":
        peak = peak_rps if peak_rps > 0.0 else 2.0 * rate_rps
        return _diurnal_times(n, rate_rps, peak, period_s, seed)
    if kind == "bursty":
        return _bursty_times(n, rate_rps, burst_factor, burst_s, seed)
    raise ValueError(f"unknown arrival kind {kind!r}; "
                     f"known: {list(ARRIVAL_KINDS)}")


def _session_groups_impl(n: int, n_sessions: int,
                         seed: int) -> tuple[int, ...]:
    if n_sessions <= 0:
        return tuple(range(n))    # every request its own session: no reuse
    rng = np.random.default_rng([seed, 0x5E])
    return tuple(int(v) for v in rng.integers(0, n_sessions, size=n))


_session_groups = switchable_lru_cache(maxsize=64)(_session_groups_impl)


# ---------------------------------------------------------------------------
# Router + autoscaler (deterministic pre-simulation policies)
# ---------------------------------------------------------------------------

ROUTER_POLICIES = ("round-robin", "least-outstanding", "prefix-hash")


def svc_est_ms(spec: ArchSpec, device: Device, n_npus: int, mfu: float,
               prompt: int, decode: int) -> float:
    """Analytic per-request service-time estimate (ms): 2*P flops per token
    over the replica's aggregate compute at ``mfu`` utilization — the
    router/autoscaler hint, NOT the simulated time."""
    flops = 2.0 * spec.param_count() * (prompt + decode)
    return flops / max(mfu * device.peak_tflops * 1e12 * n_npus, 1e-9) * 1e3


def autoscale_active(arrivals_ms: tuple, *, epoch_ms: float,
                     min_replicas: int, max_replicas: int,
                     target_util: float, cooldown_epochs: int,
                     replica_rps: float) -> tuple[int, ...]:
    """Per-epoch active replica counts from a reactive target-utilization
    policy: each epoch's capacity is decided BEFORE its arrivals land (from
    the previous epochs' observed rate), scale-up jumps straight to the
    demanded count, scale-down sheds one replica per cooldown window.
    ``target_util <= 0`` disables autoscaling (static full fleet)."""
    n_epochs = int(arrivals_ms[-1] // epoch_ms) + 1 if arrivals_ms else 1
    if target_util <= 0.0:
        return (max_replicas,) * n_epochs
    counts = np.bincount(
        np.minimum(np.asarray(arrivals_ms) // epoch_ms,
                   n_epochs - 1).astype(int), minlength=n_epochs)
    active, cool, out = min_replicas, 0, []
    for c in counts:
        out.append(active)
        rate = float(c) / (epoch_ms / 1e3)
        desired = math.ceil(rate / max(target_util * replica_rps, 1e-9))
        desired = min(max_replicas, max(min_replicas, desired))
        cool -= 1
        if cool <= 0 and desired != active:
            active = desired if desired > active else active - 1
            cool = cooldown_epochs
    return tuple(out)


def route_requests(policy: str, arrivals_ms: tuple, active_per_req: list,
                   svc_ms: list, groups: tuple,
                   max_replicas: int) -> tuple[int, ...]:
    """Deterministic request -> replica assignment among the replicas active
    at each request's arrival epoch (replicas ``0..active-1``)."""
    assign: list[int] = []
    if policy == "round-robin":
        for k, a in enumerate(active_per_req):
            assign.append(k % a)
    elif policy == "least-outstanding":
        busy = [0.0] * max_replicas
        for i, (t, a) in enumerate(zip(arrivals_ms, active_per_req)):
            r = min(range(a), key=lambda j: (busy[j], j))
            assign.append(r)
            busy[r] = max(busy[r], t) + svc_ms[i]
    elif policy == "prefix-hash":
        for g, a in zip(groups, active_per_req):
            # Knuth multiplicative hash keeps low session ids well spread
            assign.append((g * 2654435761) % (1 << 32) % a)
    else:
        raise ValueError(f"unknown router policy {policy!r}; "
                         f"known: {list(ROUTER_POLICIES)}")
    return tuple(assign)


# ---------------------------------------------------------------------------
# FleetScenario
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetScenario:
    """A fleet of ``replicas`` serving engines over disjoint partitions of
    one cluster, fed by a routed arrival trace and scaled by a
    target-utilization autoscaler.

    Each replica is a full ``RequestStreamScenario`` engine (disaggregated
    prefill/decode pools, admission waves, the opt-in continuous-batching
    knobs) on ``n_npus / replicas`` NPUs of the cluster fabric — carved via
    ``partition_cluster``, so a replica's collectives are priced on its own
    sub-network and ``replica_devices`` can install heterogeneous compute.
    The searchable scenario stack adds ``router``, ``autoscale_target``
    (0 = static full fleet) and ``autoscale_cooldown_s`` on top of the
    engine knobs; ``objective="goodput_per_dollar"`` divides fleet SLO
    goodput by the dollars of capacity actually provisioned (autoscaled
    replica-seconds), making goodput-per-dollar searchable end to end."""
    supports_stream_objectives: ClassVar[bool] = True

    # -- request stream shape (engine fields, shared by every replica) -----
    n_requests: int = 256
    seq: int = 2048
    decode_tokens: int = 64
    seed: int = 0
    prompt_len_range: tuple = ()
    decode_len_range: tuple = ()
    prompt_lens: tuple = ()
    decode_lens: tuple = ()
    max_batch: int = 32
    ttft_slo_ms: float = 4000.0
    tpot_slo_ms: float = 200.0
    priority_frac: float = 0.0
    priorities: tuple = ()
    # -- arrival trace -----------------------------------------------------
    arrival: str = "poisson"         # poisson | diurnal | bursty | replayed
    rate_rps: float = 8.0            # base rate (diurnal trough)
    peak_rps: float = 0.0            # diurnal peak (0 -> 2 * rate_rps)
    period_s: float = 60.0           # diurnal period
    burst_factor: float = 4.0        # bursty rate multiplier
    burst_s: float = 2.0             # bursty mean burst dwell
    arrival_gaps_ms: tuple = ()      # replayed inter-arrival gaps
    # -- fleet shape -------------------------------------------------------
    replicas: int = 2                # cluster is carved into this many
    min_replicas: int = 1            # autoscaler floor
    replica_devices: tuple = ()      # per-replica DEVICES names ("" = env)
    epoch_s: float = 10.0            # autoscaler decision epoch
    mfu_hint: float = 0.35           # analytic capacity estimate for hints
    n_sessions: int = 0              # >0 enables the prefix-cache model
    prefix_hit_frac: float = 0.75    # prompt fraction skipped on a hit
    # -- searchable knobs (scenario stack) ---------------------------------
    routers: tuple = ROUTER_POLICIES
    autoscale_targets: tuple = (0.0, 0.55, 0.75, 0.9)
    autoscale_cooldowns_s: tuple = (10.0, 30.0)
    # -- engine knobs forwarded to every replica ---------------------------
    batch_windows_ms: tuple = (0.0, 50.0, 200.0, 500.0, 1000.0)
    max_inflights: tuple = (1, 2, 4, 8)
    prefill_fracs: tuple = (0.25, 0.5, 0.625, 0.75, 0.875)
    decode_batches: tuple = (4, 8, 16, 32)
    admissions: tuple = ()
    prefill_chunk_choices: tuple = ()
    preempt_choices: tuple = ()
    kv_headrooms: tuple = ()
    name: str = "fleet"

    # -- engine assembly ---------------------------------------------------
    def _engine_template(self) -> RequestStreamScenario:
        """An engine with this fleet's knob choice tuples (for PsA params —
        request shapes are irrelevant there)."""
        return RequestStreamScenario(
            batch_windows_ms=self.batch_windows_ms,
            max_inflights=self.max_inflights,
            prefill_fracs=self.prefill_fracs,
            decode_batches=self.decode_batches,
            admissions=self.admissions,
            prefill_chunk_choices=self.prefill_chunk_choices,
            preempt_choices=self.preempt_choices,
            kv_headrooms=self.kv_headrooms)

    def _engine(self, n: int, times: tuple, prompts: tuple, decodes: tuple,
                tiers: tuple) -> RequestStreamScenario:
        """One replica's engine: the routed sub-stream replayed as explicit
        arrival times / per-request lengths / priority tiers."""
        return replace(self._engine_template(), n_requests=n,
                       seq=self.seq, decode_tokens=self.decode_tokens,
                       seed=self.seed, max_batch=self.max_batch,
                       ttft_slo_ms=self.ttft_slo_ms,
                       tpot_slo_ms=self.tpot_slo_ms,
                       arrival_times_ms=times, prompt_lens=prompts,
                       decode_lens=decodes, priorities=tiers)

    # -- deterministic pre-simulation inputs -------------------------------
    def arrivals_ms(self) -> tuple[float, ...]:
        return arrival_times_ms(
            self.arrival, self.n_requests, rate_rps=self.rate_rps,
            peak_rps=self.peak_rps, period_s=self.period_s,
            burst_factor=self.burst_factor, burst_s=self.burst_s,
            gaps_ms=self.arrival_gaps_ms, seed=self.seed)

    def request_shapes(self) -> tuple[tuple[int, int], ...]:
        return _request_shapes_cached(
            self.n_requests, self.seq, self.decode_tokens, self.prompt_lens,
            self.decode_lens, self.prompt_len_range, self.decode_len_range,
            self.seed)

    def request_tiers(self) -> tuple[int, ...]:
        return _request_tiers_cached(self.n_requests, self.priorities,
                                     self.priority_frac, self.seed)

    def session_groups(self) -> tuple[int, ...]:
        return _session_groups(self.n_requests, self.n_sessions, self.seed)

    # -- PsA ---------------------------------------------------------------
    def psa_params(self) -> list[Parameter]:
        params = self._engine_template().psa_params()
        params.extend([
            Parameter("router", "scenario", self.routers,
                      doc="request -> replica routing policy"),
            Parameter("autoscale_target", "scenario", self.autoscale_targets,
                      doc="target utilization (0 = static full fleet)"),
            Parameter("autoscale_cooldown_s", "scenario",
                      self.autoscale_cooldowns_s,
                      doc="min seconds between autoscaler decisions"),
        ])
        return params

    def psa_constraints(self, n_npus: int) -> list[Constraint]:
        # every replica runs the parallelism on its own carve-out, so the
        # searchable (dp, sp, pp) must fit ONE replica, not the cluster —
        # without this the agents mostly sample dead full-cluster layouts
        per = max(n_npus // max(self.replicas, 1), 1)
        return [Constraint("product_le", ("dp", "sp", "pp"), per,
                           name=f"parallelism fits one replica ({per} NPUs)")]

    def canonical(self, config: Mapping[str, Any]) -> Mapping[str, Any]:
        """Memo-key canonicalization: with autoscaling off the cooldown is
        dead, and with one replica the router is dead — don't re-evaluate
        their aliases."""
        cfg = dict(config)
        changed = False
        if float(cfg.get("autoscale_target", 0.0)) <= 0.0 \
                and "autoscale_cooldown_s" in cfg:
            cfg["autoscale_cooldown_s"] = self.autoscale_cooldowns_s[0]
            changed = True
        if self.replicas == 1 and "router" in cfg:
            cfg["router"] = self.routers[0]
            changed = True
        return cfg if changed else config

    def lint_info(self) -> dict[str, Any]:
        """Extra shape facts for ``python -m repro.dse lint``: the fleet
        cost multiplier over a single engine's trace."""
        return {"replicas": self.replicas, "arrival": self.arrival,
                "fleet_requests": self.n_requests}

    # -- the fleet plan (deterministic, pre-simulation) --------------------
    def _cluster(self, ctx: EnvContext) -> Cluster:
        per = ctx.n_npus // self.replicas
        names = [f"replica{r}" for r in range(self.replicas)]
        devices = []
        for r in range(self.replicas):
            nm = self.replica_devices[r] if r < len(self.replica_devices) \
                else ""
            devices.append(DEVICES[nm] if nm else ctx.device)
        return partition_cluster(ctx.network, [per] * self.replicas,
                                 devices, names=names)

    def _plan(self, ctx: EnvContext):
        """(active-per-epoch, per-request assignment, effective prompts,
        epoch_ms) — everything the router/autoscaler decides before any
        simulation runs."""
        arrivals = self.arrivals_ms()
        shapes = self.request_shapes()
        groups = self.session_groups()
        epoch_ms = max(self.epoch_s, 1e-3) * 1e3
        svc = [svc_est_ms(ctx.spec, ctx.device,
                          ctx.n_npus // self.replicas, self.mfu_hint, p, d)
               for p, d in shapes]
        replica_rps = 1000.0 * len(svc) / max(sum(svc), 1e-9)
        target = float(ctx.config["autoscale_target"])
        cooldown = max(1, int(round(
            float(ctx.config["autoscale_cooldown_s"])
            / max(self.epoch_s, 1e-9))))
        active = autoscale_active(
            arrivals, epoch_ms=epoch_ms, min_replicas=self.min_replicas,
            max_replicas=self.replicas, target_util=target,
            cooldown_epochs=cooldown, replica_rps=replica_rps)
        epoch_of = [min(int(t // epoch_ms), len(active) - 1)
                    for t in arrivals]
        active_per_req = [active[e] for e in epoch_of]
        assign = route_requests(str(ctx.config["router"]), arrivals,
                                active_per_req, svc, groups, self.replicas)
        # replica-local prefix-cache: a repeat session on the same replica
        # skips prefix_hit_frac of its prompt (affinity routing earns hits)
        eff_prompt = [p for p, _ in shapes]
        if self.n_sessions > 0:
            seen: list[set] = [set() for _ in range(self.replicas)]
            for i, r in enumerate(assign):
                if groups[i] in seen[r]:
                    eff_prompt[i] = max(1, int(round(
                        eff_prompt[i] * (1.0 - self.prefix_hit_frac))))
                seen[r].add(groups[i])
        return arrivals, shapes, active, assign, eff_prompt, epoch_ms, target

    def traces(self, ctx: EnvContext):
        out = {}
        got = self._replica_calls(ctx)
        if isinstance(got, Evaluation):
            return out
        for r, _, call, _, _, _ in got[0]:
            out[f"replica{r}"] = call.trace
        return out

    def _replica_calls(self, ctx: EnvContext):
        if self.replicas < 1:
            return _invalid(f"need >= 1 replicas, got {self.replicas}")
        if ctx.n_npus % self.replicas:
            return _invalid(f"{ctx.n_npus} NPUs not divisible into "
                            f"{self.replicas} replicas")
        if self.replica_devices and \
                len(self.replica_devices) != self.replicas:
            return _invalid(
                f"replica_devices has {len(self.replica_devices)} entries "
                f"for {self.replicas} replicas")
        cluster = self._cluster(ctx)
        arrivals, shapes, active, assign, eff_prompt, epoch_ms, target = \
            self._plan(ctx)
        tiers = self.request_tiers()
        per_replica: list[list[int]] = [[] for _ in range(self.replicas)]
        for i, r in enumerate(assign):
            per_replica[r].append(i)
        slices = []
        for r, idxs in enumerate(per_replica):
            if not idxs:
                continue
            part = cluster.partitions[r]
            eng = self._engine(
                len(idxs), tuple(arrivals[i] for i in idxs),
                tuple(eff_prompt[i] for i in idxs),
                tuple(shapes[i][1] for i in idxs),
                tuple(tiers[i] for i in idxs))
            rctx = replace(
                ctx, n_npus=part.n_npus, device=part.device,
                network=part.network,
                sys_cfg=replace(ctx.sys_cfg, network=part.network,
                                device=part.device))
            got = eng.stream_call(rctx)
            if isinstance(got, Evaluation):
                return replace(got, detail=dict(
                    got.detail, scenario=self.name, replica=r))
            call, request_times, rdetail, last_arr = got
            slices.append((r, idxs, call, request_times, rdetail, last_arr))
        if not slices:
            return _invalid("no replica received any requests")
        return slices, cluster, active, assign, epoch_ms, target, arrivals

    def sim_job(self, ctx: EnvContext) -> "SimJob | Evaluation":
        got = self._replica_calls(ctx)
        if isinstance(got, Evaluation):
            return got
        slices, cluster, active, assign, epoch_ms, target, arrivals = got
        router = str(ctx.config["router"])
        cooldown_s = float(ctx.config["autoscale_cooldown_s"])

        def fin(results: list[SimResult]) -> Evaluation:
            tt, tp, la = [], [], []
            makespan = {}
            for (r, idxs, _, request_times, _, _), res in zip(slices,
                                                              results):
                a, b, c = request_times(res)
                tt.append(a)
                tp.append(b)
                la.append(c)
                makespan[r] = res.latency_ms
            ttfts = np.concatenate(tt)
            tpots = np.concatenate(tp)
            lats = np.concatenate(la)
            horizon_ms = max(max(makespan.values()), arrivals[-1])
            m = stream_metrics(ttfts, tpots, lats,
                               ttft_slo_ms=self.ttft_slo_ms,
                               tpot_slo_ms=self.tpot_slo_ms,
                               horizon_ms=horizon_ms)
            # provisioned cost: static fleets pay every partition for the
            # whole horizon (1-replica case == net.dollar_cost() exactly);
            # autoscaled fleets pay per-replica provisioned epochs plus the
            # drain tail past each replica's last active epoch
            prov_ms = []
            for r, part in enumerate(cluster.partitions):
                if target <= 0.0:
                    prov_ms.append(horizon_ms)
                    continue
                epochs_on = [e for e, a_ in enumerate(active) if a_ > r]
                on_ms = epoch_ms * len(epochs_on)
                drain = 0.0
                if epochs_on and r in makespan:
                    end = epoch_ms * (epochs_on[-1] + 1)
                    drain = max(0.0, makespan[r] - end)
                prov_ms.append(on_ms + drain)
            cost = sum(
                part.network.dollar_cost() * (pm / max(horizon_ms, 1e-9))
                for part, pm in zip(cluster.partitions, prov_ms))
            m = dataclasses.replace(m, provisioned_cost=cost)
            r_ = stream_reward(ctx.objective, m, ctx.sys_cfg.network)
            n_req = [0] * self.replicas
            for r, idxs, *_ in slices:
                n_req[r] = len(idxs)
            return Evaluation(r_, m.latency_p99_ms, True, {
                "scenario": self.name, "replicas": self.replicas,
                "replica_npus": ctx.n_npus // self.replicas,
                "arrival": self.arrival, "router": router,
                "autoscale_target": target,
                "autoscale_cooldown_s": cooldown_s,
                "active_per_epoch": list(active),
                "replica_requests": n_req,
                "replica_makespan_ms": {str(r): ms
                                        for r, ms in sorted(makespan.items())},
                "provisioned_replica_s": [pm / 1e3 for pm in prov_ms],
                "makespan_ms": max(makespan.values()),
                "cluster": cluster.describe(),
                **m.detail(),
            })

        return SimJob(tuple(call for _, _, call, _, _, _ in slices), fin)

    def evaluate(self, ctx: EnvContext) -> Evaluation:
        return run_sim_job(self.sim_job(ctx), ctx.backend)


register_scenario("fleet", dataclass_scenario_builder(FleetScenario))
