"""Compute-device roofline model (the paper's Compute knob: peak-perf,
local-mem-bw, memory-capacity)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Device:
    name: str
    peak_tflops: float       # TFLOP/s (bf16)
    mem_bw_gbps: float       # GB/s HBM
    mem_capacity_gb: float   # GB

    def op_time_us(self, flops: float, bytes_accessed: float) -> float:
        """max(compute, memory) — the roofline."""
        t_c = flops / (self.peak_tflops * 1e12)
        t_m = bytes_accessed / (self.mem_bw_gbps * 1e9)
        return max(t_c, t_m) * 1e6

    def op_times_us(self, flops: np.ndarray, bytes_accessed: np.ndarray) -> np.ndarray:
        """Vectorized roofline over whole traces; float64 arithmetic matches
        the scalar path bit for bit."""
        t_c = np.asarray(flops, dtype=np.float64) / (self.peak_tflops * 1e12)
        t_m = np.asarray(bytes_accessed, dtype=np.float64) / (self.mem_bw_gbps * 1e9)
        return np.maximum(t_c, t_m) * 1e6

    def ridge_intensity(self) -> float:
        """FLOP/byte at which the device turns compute-bound."""
        return (self.peak_tflops * 1e12) / (self.mem_bw_gbps * 1e9)


# Paper Table 3 compute knobs (perf in TFLOPS, BW in GB/s; 24 GB validity cap
# comes from Section 5.4 and is enforced by the memory model).
SYSTEM_1_DEVICE = Device("system1-tpu-v5p", 459.0, 2765.0, 24.0)
SYSTEM_2_DEVICE = Device("system2-npu", 10.0, 50.0, 24.0)
SYSTEM_3_DEVICE = Device("system3-h100", 900.0, 3000.0, 24.0)

# Our dry-run/roofline target (per task sheet): TPU v5e-like.
TPU_V5E = Device("tpu-v5e", 197.0, 819.0, 16.0)

DEVICES = {d.name: d for d in (SYSTEM_1_DEVICE, SYSTEM_2_DEVICE, SYSTEM_3_DEVICE, TPU_V5E)}
