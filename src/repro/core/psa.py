"""Parameter Set Architecture (PsA) — the paper's central abstraction.

Like an ISA delineates software/hardware, the PsA delineates the interface
between search agents and the system under design: a declarative schema of
searchable parameters, their value ranges, and cross-parameter constraints
(Section 4.2 of the paper).  Domain experts author ``ParameterSet``s; the
Parameter Set Scheduler (``repro.core.space``) turns them into agent action
spaces automatically.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

# 'scenario' parameters are contributed by the active Scenario (e.g. the
# disaggregated-serving prefill/decode split) via ``Scenario.psa_params()``
# and searched alongside the paper's four stacks.
Stack = str  # 'workload' | 'collective' | 'network' | 'compute' | 'scenario'


@dataclass(frozen=True)
class Parameter:
    """One searchable knob.

    ``choices`` is the explicit (ordered) value set; ``ndim > 1`` declares a
    multi-dimensional knob (one independent slot per network dimension, like
    the paper's ``MultiDim {Ring, Direct, RHD, DBT}``).
    """

    name: str
    stack: Stack
    choices: tuple
    ndim: int = 1
    doc: str = ""

    def __post_init__(self):
        if not self.choices:
            raise ValueError(f"parameter {self.name}: empty choice set")
        if self.ndim < 1:
            raise ValueError(f"parameter {self.name}: ndim must be >= 1")

    @property
    def slots(self) -> list[str]:
        if self.ndim == 1:
            return [self.name]
        return [f"{self.name}[{i}]" for i in range(self.ndim)]

    def cardinality(self) -> int:
        return len(self.choices) ** self.ndim


@dataclass(frozen=True)
class Constraint:
    """Declarative cross-parameter constraint.

    kinds:
      product_eq : prod(values of `params`) == target
      product_le : prod(values of `params`) <= target
      sum_le     : sum(values of `params`) <= target   (partition budgets)
      predicate  : fn(config) -> bool  (escape hatch)
    `params` may name scalar parameters or a multidim parameter (expands to
    all of its slots).
    """

    kind: str
    params: tuple[str, ...] = ()
    target: float | int | str = 0
    fn: Callable[[dict], bool] | None = None
    name: str = ""

    def describe(self) -> str:
        if self.name:
            return self.name
        if self.kind == "predicate":
            return "predicate"
        if self.kind == "sum_le":
            return f"sum({', '.join(self.params)}) <= {self.target}"
        op = {"product_eq": "==", "product_le": "<="}[self.kind]
        return f"product({', '.join(self.params)}) {op} {self.target}"


@dataclass
class ParameterSet:
    """A PsA schema instance: parameters + constraints (+ fixed values).

    ``fixed`` pins parameters to constants — this is how the paper's
    single-stack baselines are expressed (e.g. workload-only search fixes
    the collective and network stacks).
    """

    params: list[Parameter]
    constraints: list[Constraint] = field(default_factory=list)
    fixed: dict[str, Any] = field(default_factory=dict)
    name: str = "psa"

    def __post_init__(self):
        names = [p.name for p in self.params]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate parameter names in {self.name}")

    # ------------------------------------------------------------------
    def by_name(self, name: str) -> Parameter:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(name)

    def stacks(self) -> set[str]:
        return {p.stack for p in self.params}

    def restrict(self, stacks: Iterable[str], defaults: dict[str, Any]) -> "ParameterSet":
        """Single-stack ablation: keep `stacks` searchable, pin the rest to
        `defaults` (the paper's workload-only / collective-only / network-only
        baselines)."""
        stacks = set(stacks)
        fixed = dict(self.fixed)
        for p in self.params:
            if p.stack not in stacks and p.name not in fixed:
                if p.name not in defaults:
                    raise KeyError(f"no default for pinned parameter {p.name}")
                fixed[p.name] = defaults[p.name]
        return ParameterSet(self.params, self.constraints, fixed,
                            name=f"{self.name}:{'+'.join(sorted(stacks))}")

    def extend(self, params: Iterable[Parameter],
               constraints: Iterable[Constraint] = (),
               name: str | None = None) -> "ParameterSet":
        """A new ParameterSet with extra parameters/constraints appended —
        how a Scenario contributes its searchable knobs to a base PsA."""
        return ParameterSet(self.params + list(params),
                            self.constraints + list(constraints),
                            dict(self.fixed), name=name or self.name)

    def pin(self, overrides: dict[str, Any]) -> "ParameterSet":
        """A new ParameterSet with ``overrides`` pinned as fixed values —
        how a StudySpec's ``psa_overrides`` narrow a search.  Every key must
        name an existing parameter and every value must lie inside its
        declared choices (per slot for multidim parameters) — a typo'd pin
        must not silently search outside the design space."""
        pinned: dict[str, Any] = {}
        for k, v in overrides.items():
            try:
                p = self.by_name(k)
            except KeyError:
                raise ValueError(
                    f"unknown pinned parameter {k!r}; known: "
                    f"{[q.name for q in self.params]}") from None
            if p.ndim == 1:
                if v not in p.choices:
                    raise ValueError(f"pin {k}={v!r} is outside the "
                                     f"parameter's choices {p.choices}")
                pinned[k] = v
            else:
                vv = tuple(v) if isinstance(v, (list, tuple)) else (v,)
                if len(vv) != p.ndim or any(x not in p.choices for x in vv):
                    raise ValueError(
                        f"pin {k}={v!r} must be {p.ndim} values, each from "
                        f"{p.choices}")
                pinned[k] = vv
        return ParameterSet(self.params, self.constraints,
                            {**self.fixed, **pinned}, name=self.name)

    def cardinality(self) -> float:
        """Raw design-space size (unconstrained product — Table 1's count)."""
        total = 1.0
        for p in self.params:
            if p.name in self.fixed:
                continue
            total *= p.cardinality()
        return total

    def searched_params(self) -> list[Parameter]:
        """The parameters an agent actually searches over: not pinned via
        ``fixed`` and with more than one choice (the lint layer's dead-knob
        pass only flags these — a 1-choice or pinned knob is inert by
        construction, not a defect)."""
        return [p for p in self.params
                if p.name not in self.fixed and p.cardinality() > 1]

    def slot_names(self) -> list[str]:
        out: list[str] = []
        for p in self.params:
            if p.name in self.fixed:
                continue
            out.extend(p.slots)
        return out

    def expand_constraint_params(self, c: Constraint) -> list[str]:
        """Multidim params in a constraint expand to all their slots."""
        out: list[str] = []
        for name in c.params:
            try:
                p = self.by_name(name)
                out.extend(p.slots)
            except KeyError:
                out.append(name)  # already a slot name
        return out


# ---------------------------------------------------------------------------
# The paper's evaluation PsA (Table 4), with TPU-flavoured compute presets.
# ---------------------------------------------------------------------------

def pow2_range(lo: int, hi: int) -> tuple[int, ...]:
    """All powers of two from ``lo`` to ``hi`` inclusive.  Both bounds must
    themselves be powers of two — a non-power-of-two bound used to be
    silently truncated (``pow2_range(1, 1000)`` -> ... 512), which turned a
    typo'd cluster size into a quietly smaller design space."""
    for v, side in ((lo, "lo"), (hi, "hi")):
        if not isinstance(v, int) or v < 1:
            raise ValueError(f"pow2_range {side}={v!r} must be a positive "
                             f"integer power of two")
        if v & (v - 1):
            raise ValueError(
                f"pow2_range {side}={v} is not a power of two "
                f"(nearest are {2 ** (v.bit_length() - 1)} and "
                f"{2 ** v.bit_length()})")
    if lo > hi:
        raise ValueError(f"pow2_range lo={lo} > hi={hi}")
    return tuple(2 ** i for i in range(int(math.log2(lo)), int(math.log2(hi)) + 1))


COLL_ALGOS = ("ring", "direct", "rhd", "dbt")
TOPOLOGIES = ("ring", "switch", "fc")


def paper_psa(n_npus: int = 1024, net_dims: int = 4, *, searchable_npus: bool = False,
              max_pp: int = 4) -> ParameterSet:
    """The PsA of Table 4.  `n_npus` fixes the cluster size (1024 for
    System 2); parallelization degrees and NPUs-per-dim must multiply to it."""
    params = [
        Parameter("dp", "workload", pow2_range(1, n_npus), doc="data parallelism"),
        Parameter("pp", "workload", pow2_range(1, max_pp), doc="pipeline parallelism"),
        Parameter("sp", "workload", pow2_range(1, n_npus), doc="sequence parallelism"),
        Parameter("weight_sharded", "workload", (0, 1), doc="ZeRO weight sharding"),
        Parameter("sched_policy", "collective", ("lifo", "fifo")),
        Parameter("coll_algo", "collective", COLL_ALGOS, ndim=net_dims),
        Parameter("chunks", "collective", (2, 4, 8, 16)),
        Parameter("multidim_coll", "collective", ("baseline", "blueconnect")),
        Parameter("topology", "network", TOPOLOGIES, ndim=net_dims),
        Parameter("npus_per_dim", "network", (4, 8, 16), ndim=net_dims),
        Parameter("bw_per_dim", "network", tuple(range(50, 501, 50)), ndim=net_dims),
    ]
    constraints = [
        Constraint("product_le", ("dp", "sp", "pp"), n_npus,
                   name=f"product(DP,SP,PP) <= {n_npus}"),
        Constraint("product_eq", ("npus_per_dim",), n_npus,
                   name=f"product(NPUs per dim) == {n_npus}"),
    ]
    return ParameterSet(params, constraints, name=f"paper-psa-{n_npus}")


def table1_psa(n_npus: int = 1024, net_dims: int = 4) -> ParameterSet:
    """The motivation-section schema (Table 1): chunks 1..32, BW in
    {100..500}.  Raw cardinality reproduces the paper's 7.69e13."""
    params = [
        Parameter("dp", "workload", pow2_range(1, n_npus)),
        Parameter("pp", "workload", pow2_range(1, n_npus)),
        Parameter("sp", "workload", pow2_range(1, n_npus)),
        Parameter("weight_sharded", "workload", (0, 1)),
        Parameter("sched_policy", "collective", ("lifo", "fifo")),
        Parameter("coll_algo", "collective", COLL_ALGOS, ndim=net_dims),
        Parameter("chunks", "collective", tuple(range(1, 33))),
        Parameter("multidim_coll", "collective", ("baseline", "blueconnect")),
        Parameter("topology", "network", TOPOLOGIES, ndim=net_dims),
        Parameter("npus_per_dim", "network", (4, 8, 16), ndim=net_dims),
        Parameter("bw_per_dim", "network", (100, 200, 300, 400, 500), ndim=net_dims),
    ]
    constraints = [
        Constraint("product_le", ("dp", "sp", "pp"), n_npus),
        Constraint("product_eq", ("npus_per_dim",), n_npus),
    ]
    return ParameterSet(params, constraints, name="table1-psa")
