"""The Study API: one declarative, serializable front door for the DSE stack.

A ``StudySpec`` is a frozen, JSON-round-trippable description of a whole
co-design experiment: (model x system x scenario x searched stacks x
objective x agent grid x seeds x budget).  Everything resolves through
first-class registries — ``configs.ARCHS`` for the model,
``core.systems.SYSTEM_REGISTRY`` for the target system,
``core.scenario.SCENARIO_REGISTRY`` for the workload shape, and
``core.rewards.OBJECTIVES`` for the reward — and is validated at spec
construction, not deep inside a search.

``run_study`` executes the spec's (agent x seed) grid as ONE campaign:

  * one shared ``eval_store`` across every cell — a design point any cell
    already evaluated is free for the rest;
  * one reusable process pool (``workers > 1``) held open across cells;
  * per-cell ``SearchResult``s streamed to a JSONL results file stamped
    with the spec hash and git metadata as each cell finishes;
  * ``resume=True`` skips cells the results file already holds, so a
    killed campaign finishes from where it stopped without re-evaluating.

The CLI lives in ``repro.dse``:  ``python -m repro.dse run study.json``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import subprocess
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Mapping

from repro.core.agents.base import AGENT_HYPER, KNOWN_AGENTS
from repro.core.backends import BACKEND_REGISTRY
from repro.core.dse import SearchResult, run_search
from repro.core.psa import ParameterSet, paper_psa
from repro.core.rewards import Evaluation, get_objective
from repro.core.scenario import Scenario, build_scenario, scenario_psa
from repro.core.systems import get_system


def _freeze(v: Any) -> Any:
    """JSON values -> canonical immutable-ish form (lists become tuples,
    dicts are copied) so two specs built from JSON and from Python literals
    compare equal."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, Mapping):
        return {k: _freeze(x) for k, x in v.items()}
    return v


def _thaw(v: Any) -> Any:
    """The inverse direction for JSON dumping: tuples -> lists."""
    if isinstance(v, tuple):
        return [_thaw(x) for x in v]
    if isinstance(v, Mapping):
        return {k: _thaw(x) for k, x in v.items()}
    return v


@dataclass(frozen=True)
class AgentSpec:
    """One column of the agent grid: an agent kind, an optional per-agent
    step budget (e.g. BO's cubic GP cost wants a smaller one), and agent
    hyperparameters (stored as sorted pairs so the spec stays frozen)."""
    kind: str
    steps: int | None = None
    hyper: tuple = ()

    def __post_init__(self):
        if self.kind not in KNOWN_AGENTS:
            raise ValueError(f"unknown agent kind {self.kind!r}; "
                             f"known: {sorted(KNOWN_AGENTS)}")
        if isinstance(self.hyper, Mapping):
            object.__setattr__(self, "hyper",
                               tuple(sorted(self.hyper.items())))
        else:
            object.__setattr__(self, "hyper",
                               tuple(sorted(tuple(kv) for kv in self.hyper)))
        bad = sorted(set(k for k, _ in self.hyper) - AGENT_HYPER[self.kind])
        if bad:
            raise ValueError(
                f"unknown hyper {bad} for agent kind {self.kind!r}; "
                f"known: {sorted(AGENT_HYPER[self.kind])} — a typo here "
                f"would otherwise TypeError a cell deep into the campaign")

    @classmethod
    def coerce(cls, v: "str | Mapping | AgentSpec") -> "AgentSpec":
        if isinstance(v, AgentSpec):
            return v
        if isinstance(v, str):
            return cls(v)
        v = dict(v)
        unknown = sorted(v.keys() - {"kind", "steps", "hyper"})
        if unknown:
            raise ValueError(f"unknown agent-spec keys {unknown}; "
                             f"known: ['kind', 'steps', 'hyper']")
        return cls(kind=v["kind"], steps=v.get("steps"),
                   hyper=v.get("hyper") or ())

    def to_dict(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind}
        if self.steps is not None:
            out["steps"] = self.steps
        if self.hyper:
            out["hyper"] = {k: _thaw(v) for k, v in self.hyper}
        return out


_SPEC_DEFAULT_CAPACITY_GB = 24.0


@dataclass(frozen=True)
class StudySpec:
    """A whole DSE experiment as data.

    Every name resolves through a registry (arch / system / scenario /
    objective) and the spec validates itself — including building the
    scenario and checking streaming-objective compatibility — at
    construction, so a bad study fails before any search runs.

    ``scenario_params`` are the registered scenario's constructor params
    (JSON-shaped; for ``"train"``, ``batch`` defaults to 1024 and ``seq``
    to the arch's max_seq, mirroring the old hand-assembly).  ``stacks``
    restricts the searched stacks, pinning the rest to the system preset's
    Table-3 defaults; ``psa_overrides`` pin individual parameters on top.
    """
    name: str
    arch: str
    system: str
    scenario: str = "train"
    scenario_params: Mapping[str, Any] = field(default_factory=dict)
    stacks: tuple | None = None          # None = full stack
    psa_overrides: Mapping[str, Any] = field(default_factory=dict)
    objective: str = "perf_per_bw"
    agents: tuple = (AgentSpec("ga"),)
    seeds: tuple = (0,)
    steps: int = 500
    batch_size: int = 32
    workers: int = 0
    max_pp: int = 4
    capacity_gb: float = _SPEC_DEFAULT_CAPACITY_GB
    # simulation backend every cell's evaluations run on (registry name
    # from ``repro.core.backends``; part of the spec hash — a vectorized
    # backend's results may differ within tolerance from the reference's)
    backend: str = "reference"
    # optional cross-campaign persistent eval store (JSONL): memoized
    # evaluations preload from here and fresh ones append back, so
    # successive studies over the same (arch x system x scenario x
    # objective x backend) stop re-evaluating known design points.
    # Hash-exempt like ``workers`` — reuse never changes results.
    eval_store_path: "str | None" = None

    def __post_init__(self):
        set_ = object.__setattr__
        set_(self, "scenario_params", _freeze(dict(self.scenario_params)))
        set_(self, "psa_overrides", _freeze(dict(self.psa_overrides)))
        set_(self, "agents",
             tuple(AgentSpec.coerce(a) for a in self.agents))
        set_(self, "seeds", tuple(int(s) for s in self.seeds))
        if self.stacks is not None:
            set_(self, "stacks", tuple(self.stacks))
        self.validate()

    # -- validation (spec time, not search time) -------------------------
    def validate(self) -> None:
        from repro.configs import ARCHS

        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; "
                             f"known: {sorted(ARCHS)}")
        get_system(self.system)           # raises on an unknown preset
        obj = get_objective(self.objective)
        sc = self.build_scenario()        # raises on bad kind/params
        if obj.streaming and not getattr(sc, "supports_stream_objectives",
                                         False):
            raise ValueError(
                f"objective {obj.name!r} needs a streaming scenario "
                f"(per-request metrics); scenario {self.scenario!r} only "
                f"supports scalar objectives")
        if self.backend not in BACKEND_REGISTRY:
            raise ValueError(f"unknown simulation backend {self.backend!r}; "
                             f"known: {sorted(BACKEND_REGISTRY)}")
        if not self.agents:
            raise ValueError("agents grid is empty")
        if not self.seeds:
            raise ValueError("seeds grid is empty")
        if self.steps < 1 or self.batch_size < 1:
            raise ValueError(f"steps ({self.steps}) and batch_size "
                             f"({self.batch_size}) must be >= 1")
        if self.stacks is not None:
            known = {"workload", "collective", "network", "compute",
                     "scenario"}
            bad = set(self.stacks) - known
            if bad:
                raise ValueError(f"unknown stacks {sorted(bad)}; "
                                 f"known: {sorted(known)}")
        self.build_pset()                 # raises on bad psa_overrides

    # -- JSON round trip -------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return {
            "name": self.name, "arch": self.arch, "system": self.system,
            "scenario": self.scenario,
            "scenario_params": _thaw(self.scenario_params),
            "stacks": list(self.stacks) if self.stacks is not None else None,
            "psa_overrides": _thaw(self.psa_overrides),
            "objective": self.objective,
            "agents": [a.to_dict() for a in self.agents],
            "seeds": list(self.seeds), "steps": self.steps,
            "batch_size": self.batch_size, "workers": self.workers,
            "max_pp": self.max_pp, "capacity_gb": self.capacity_gb,
            "backend": self.backend,
            "eval_store_path": self.eval_store_path,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "StudySpec":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown StudySpec keys {unknown}; "
                             f"known: {sorted(known)}")
        if d.get("stacks") is not None:
            d["stacks"] = tuple(d["stacks"])
        return cls(**d)

    def to_json(self, path: "str | Path | None" = None) -> str:
        text = json.dumps(self.to_dict(), indent=2, sort_keys=True)
        if path is not None:
            Path(path).write_text(text + "\n")
        return text

    @classmethod
    def from_json(cls, source: "str | Path") -> "StudySpec":
        """Load from a JSON string or a file path."""
        text = str(source)
        if not text.lstrip().startswith("{"):
            text = Path(source).read_text()
        return cls.from_dict(json.loads(text))

    def spec_hash(self) -> str:
        """Stable content hash of the canonical JSON form — stamps results
        so a JSONL file can't silently mix campaigns.  ``workers`` is
        excluded: it only parallelizes evaluation (results are bit-identical
        across the pool path), so a killed campaign may legitimately resume
        with a different pool size.  ``eval_store_path`` is excluded for the
        same reason — memo reuse never changes results.  ``backend`` IS
        hashed: backends may differ within tolerance."""
        d = self.to_dict()
        del d["workers"]
        del d["eval_store_path"]
        if d["backend"] == "reference":
            # drop the default so campaigns recorded before the backend
            # field existed (hashes computed without the key) stay
            # resumable; a non-default backend changes results and hashes
            del d["backend"]
        canon = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    def eval_signature(self) -> str:
        """Hash of the evaluation-relevant spec subset: two studies sharing
        it produce identical ``Evaluation``s for identical configs, so their
        persistent eval-store entries are interchangeable.  Search-shaping
        fields (agents/seeds/steps/stacks/overrides/budgets) only change
        WHICH points are visited, not their values."""
        d = {"arch": self.arch, "system": self.system,
             "scenario": self.scenario,
             "scenario_params": _thaw(self.scenario_params),
             "objective": self.objective, "capacity_gb": self.capacity_gb,
             "backend": self.backend}
        canon = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    # -- resolution through the registries -------------------------------
    def build_scenario(self) -> Scenario:
        from repro.configs import ARCHS

        params = dict(self.scenario_params)
        if self.scenario == "train":
            params.setdefault("batch", 1024)
            params.setdefault("seq", ARCHS[self.arch].max_seq)
        return build_scenario(self.scenario, params)

    def build_pset(self) -> ParameterSet:
        preset = get_system(self.system)
        ps = paper_psa(preset.n_npus, max_pp=self.max_pp)
        if self.stacks is not None:
            ps = ps.restrict(set(self.stacks), preset.stack_defaults())
        ps = scenario_psa(ps, self.build_scenario(), preset.n_npus)
        if self.psa_overrides:
            ps = ps.pin(dict(self.psa_overrides))
        return ps

    def build_env(self, eval_store: dict | None = None):
        from repro.configs import ARCHS
        from repro.core.env import CosmicEnv

        preset = get_system(self.system)
        return CosmicEnv(spec=ARCHS[self.arch], n_npus=preset.n_npus,
                         device=preset.device,
                         scenario=self.build_scenario(),
                         objective=self.objective,
                         capacity_gb=self.capacity_gb,
                         backend=self.backend,
                         eval_store=eval_store)

    # -- the campaign grid ------------------------------------------------
    def cells(self) -> list[tuple[str, AgentSpec, int]]:
        """The (agent x seed) grid as ``(cell_id, agent, seed)`` rows.  The
        id embeds the grid position, so duplicate (agent, seed) columns stay
        distinct cells."""
        out = []
        for ai, aspec in enumerate(self.agents):
            for seed in self.seeds:
                out.append((f"{ai}:{aspec.kind}:s{seed}", aspec, seed))
        return out


# ---------------------------------------------------------------------------
# Persistent (cross-campaign) eval store
# ---------------------------------------------------------------------------

def _json_default(o: Any) -> Any:
    """Detail dicts occasionally carry numpy scalars; coerce or stringify."""
    try:
        return float(o)
    except (TypeError, ValueError):
        return str(o)


def iter_jsonl_lenient(path: Path):
    """Yield parsed records from a JSONL file, skipping blank and malformed
    lines (a campaign killed mid-append leaves a torn tail).  The lenient
    reader for cache/inspection surfaces — resume's strict reader
    (``_read_results``) keeps its own corruption handling."""
    for line in path.read_text().splitlines():
        if not line.strip():
            continue
        try:
            yield json.loads(line)
        except json.JSONDecodeError:
            continue


class PersistentEvalStore:
    """A JSONL file of memoized (config -> Evaluation) pairs shared across
    campaigns.  Entries are stamped with the owning study's
    ``eval_signature()`` so one file can serve many studies without ever
    cross-hitting incompatible ones; malformed lines (a campaign killed
    mid-append) are skipped — this is a cache, not a ledger."""

    def __init__(self, path: "str | Path", signature: str) -> None:
        self.path = Path(path)
        self.signature = signature
        self.entries: list[tuple[dict, Evaluation]] = []
        self._known: set[str] = set()
        self._pending: list[str] = []
        if self.path.exists():
            for rec in iter_jsonl_lenient(self.path):
                if rec.get("sig") != signature:
                    continue
                config = rec.get("config")
                if not isinstance(config, dict) or "reward" not in rec:
                    continue
                self._known.add(self._canon(config))
                self.entries.append((config, Evaluation(
                    rec["reward"], rec["latency_ms"], rec["valid"],
                    rec.get("detail") or {})))

    @staticmethod
    def _canon(config: Mapping[str, Any]) -> str:
        return json.dumps(_thaw(dict(config)), sort_keys=True,
                          separators=(",", ":"), default=_json_default)

    def preload(self, env) -> int:
        """Install every matching entry into ``env.eval_store`` (keyed
        through the env's own canonicalization) and hook ``env.eval_record``
        so fresh evaluations queue for ``flush()``."""
        assert env.eval_store is not None, "env needs a shared eval_store"
        for config, ev in self.entries:
            cfg = {k: _freeze(v) for k, v in config.items()}
            env.eval_store[env._point_key(cfg)] = ev
        env.eval_record = self.record
        return len(self.entries)

    def record(self, config: Mapping[str, Any], ev: Evaluation) -> None:
        canon = self._canon(config)
        if canon in self._known:
            return
        self._known.add(canon)
        self._pending.append(json.dumps(
            {"sig": self.signature, "config": _thaw(dict(config)),
             "reward": ev.reward, "latency_ms": ev.latency_ms,
             "valid": ev.valid, "detail": _thaw(ev.detail)},
            default=_json_default))

    def flush(self) -> int:
        """Append queued fresh evaluations; returns how many were written."""
        if not self._pending:
            return 0
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self.path.open("a") as f:
            for line in self._pending:
                f.write(line + "\n")
        n = len(self._pending)
        self._pending = []
        return n


# ---------------------------------------------------------------------------
# Campaign execution
# ---------------------------------------------------------------------------

@dataclass
class CellOutcome:
    cell_id: str
    agent: str
    seed: int
    result: SearchResult
    store_hits: int = 0
    store_misses: int = 0
    resumed: bool = False


@dataclass
class StudyResult:
    spec: StudySpec
    outcomes: list[CellOutcome]
    store_hits: int
    store_misses: int
    distinct_points: int
    out: Path | None
    wall_s: float
    # persistent eval store accounting (spec.eval_store_path): entries
    # preloaded from disk, and fresh ones appended back after the campaign
    store_preloaded: int = 0
    store_persisted: int = 0

    @property
    def store_hit_rate(self) -> float:
        return self.store_hits / max(self.store_hits + self.store_misses, 1)

    @property
    def cells_run(self) -> int:
        return sum(not o.resumed for o in self.outcomes)

    @property
    def cells_skipped(self) -> int:
        return sum(o.resumed for o in self.outcomes)

    def best(self) -> CellOutcome | None:
        done = [o for o in self.outcomes if o.result.best_config is not None]
        return max(done, key=lambda o: o.result.best_reward) if done else None


def git_metadata() -> dict[str, Any]:
    """Best-effort provenance for the results file; {} outside a checkout."""
    try:
        rev = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        if rev.returncode != 0:
            return {}
        dirty = subprocess.run(["git", "status", "--porcelain"],
                               capture_output=True, text=True, timeout=10)
        return {"commit": rev.stdout.strip(),
                "dirty": bool(dirty.stdout.strip())}
    except (OSError, subprocess.SubprocessError):
        return {}


def _read_results(path: Path, spec_hash: str) -> dict[str, dict]:
    """Completed cell records keyed by cell_id.  A results file written for
    a DIFFERENT spec is an error — resuming must never mix campaigns.

    A campaign killed mid-append (the exact case resume exists for) can
    leave a truncated final line: that line is discarded — and trimmed off
    the file so appended records don't concatenate onto it — and its cell
    simply re-runs.  A malformed line anywhere else is corruption and
    raises."""
    lines = path.read_text().splitlines()
    done: dict[str, dict] = {}
    valid: list[str] = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                path.write_text("\n".join(valid) + "\n" if valid else "")
                break
            raise ValueError(f"{path} line {i + 1} is not valid JSON (and "
                             f"is not a truncated final line)") from None
        valid.append(line)
        if rec.get("spec_hash") != spec_hash:
            raise ValueError(
                f"{path} holds results for spec_hash "
                f"{rec.get('spec_hash')!r}, not {spec_hash!r} — refusing to "
                f"resume a different study into it")
        if rec.get("record") == "cell":
            if "cell_id" not in rec:
                raise ValueError(f"{path} line {i + 1}: cell record has no "
                                 f"cell_id — corrupt results file")
            done[rec["cell_id"]] = rec
    return done


def _result_from_record(rec: dict) -> SearchResult:
    result = rec.get("result")
    if not isinstance(result, dict):
        raise ValueError(
            f"cell record {rec.get('cell_id')!r} has no result payload — "
            f"corrupt results file")
    r = dict(result)
    if r.get("best_config") is not None:
        # JSON turned the config's tuples (coll_algo, topology, ...) into
        # lists; re-freeze so a resumed best_config round-trips through the
        # hashable memo/eval_store paths like a live one
        r["best_config"] = {k: _freeze(v) for k, v in r["best_config"].items()}
    known = {f.name for f in dataclasses.fields(SearchResult)}
    return SearchResult(**{k: v for k, v in r.items() if k in known})


def run_study(spec: StudySpec, *, out: "str | Path | None" = None,
              resume: bool = False,
              log: Callable[[str], None] | None = None) -> StudyResult:
    """Execute a ``StudySpec``'s (agent x seed) grid as one campaign.

    All cells share one ``eval_store`` (design points an earlier cell
    evaluated are free) and — when ``spec.workers > 1`` — one process pool.
    With ``out`` set, each finished cell is appended to the JSONL results
    file immediately; ``resume=True`` then skips cells already on disk
    (after checking the file's spec hash matches) and re-runs only the
    rest."""
    say = log or (lambda s: None)
    out_path = Path(out) if out is not None else None
    if resume and out_path is None:
        raise ValueError("resume=True needs a results file (out=...)")
    spec_hash = spec.spec_hash()

    done: dict[str, dict] = {}
    if out_path is not None and out_path.exists():
        if not resume:
            raise ValueError(
                f"results file {out_path} already exists — pass resume=True "
                f"(--resume) to continue that campaign, or delete it / "
                f"choose another out path to start fresh")
        done = _read_results(out_path, spec_hash)

    pset = spec.build_pset()
    store: dict = {}
    env = spec.build_env(eval_store=store)
    persist: PersistentEvalStore | None = None
    preloaded = 0
    if spec.eval_store_path:
        persist = PersistentEvalStore(spec.eval_store_path,
                                      spec.eval_signature())
        preloaded = persist.preload(env)
        say(f"eval store {persist.path}: preloaded {preloaded} "
            f"evaluation(s) [{persist.signature}]")
    # warm-start corpus for surrogate agents: built ONCE per campaign from
    # the store's in-memory entries (the JSONL was already read exactly
    # once, in the PersistentEvalStore constructor) and shared by every
    # cell — so all cells see the same corpus regardless of cell order,
    # and no cell re-reads the file
    warm_records = [
        ({k: _freeze(v) for k, v in cfg.items()}, ev.reward)
        for cfg, ev in persist.entries] if persist is not None else []
    outcomes: list[CellOutcome] = []
    persisted = 0
    t0 = time.time()

    writer = None
    if out_path is not None:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        # an existing-but-empty file (touched, or fully torn-trimmed) has no
        # header yet — treat it as fresh or the resumed file never gets one
        fresh = not (resume and out_path.exists()
                     and out_path.stat().st_size > 0)
        writer = out_path.open("w" if fresh else "a")
        if fresh:
            header = {"record": "study", "name": spec.name,
                      "spec_hash": spec_hash, "spec": spec.to_dict(),
                      "git": git_metadata(), "created_unix": time.time()}
            writer.write(json.dumps(header) + "\n")
            writer.flush()

    try:
        with env:
            for cell_id, aspec, seed in spec.cells():
                if cell_id in done:
                    rec = done[cell_id]
                    outcomes.append(CellOutcome(
                        cell_id, aspec.kind, seed,
                        _result_from_record(rec),
                        store_hits=rec.get("store_hits", 0),
                        store_misses=rec.get("store_misses", 0),
                        resumed=True))
                    say(f"cell {cell_id}: complete in results file, skipped")
                    continue
                h0, m0 = env.store_hits, env.store_misses
                env.history.clear()   # bound campaign memory; best is in res
                # fail-fast gate: statically verify a probe design point's
                # scheduling plan before the search burns steps on a space
                # whose every trace would hang or crash the simulator
                # (verdicts are memoized per trace — ~free on shared plans)
                from repro.core.analysis import preflight
                rep = preflight(env, pset, seed=seed)
                if rep is not None:
                    rep.raise_if_issues()
                res = run_search(pset, env, aspec.kind,
                                 steps=aspec.steps or spec.steps, seed=seed,
                                 batch_size=spec.batch_size,
                                 workers=spec.workers,
                                 warm_start=warm_records,
                                 **dict(aspec.hyper))
                cell = CellOutcome(cell_id, aspec.kind, seed, res,
                                   store_hits=env.store_hits - h0,
                                   store_misses=env.store_misses - m0)
                outcomes.append(cell)
                say(f"cell {cell_id}: best={res.best_reward:.4g} "
                    f"latency={res.best_latency_ms:.1f}ms "
                    f"steps_to_peak={res.steps_to_peak} "
                    f"points_per_s={res.points_per_s:.0f} "
                    f"store_hits={cell.store_hits}")
                if writer is not None:
                    rec = {"record": "cell", "cell_id": cell_id,
                           "agent": aspec.to_dict(), "seed": seed,
                           "spec_hash": spec_hash,
                           "result": dataclasses.asdict(res),
                           "store_hits": cell.store_hits,
                           "store_misses": cell.store_misses,
                           "finished_unix": time.time()}
                    writer.write(json.dumps(rec) + "\n")
                    writer.flush()
                if persist is not None:
                    # per-cell flush: a killed campaign keeps everything up
                    # to its last finished cell (the lenient reader skips a
                    # torn tail), and pending memory stays bounded
                    persisted += persist.flush()
    finally:
        if persist is not None:
            persisted += persist.flush()
        if writer is not None:
            writer.close()

    if persist is not None:
        say(f"eval store {persist.path}: persisted {persisted} new "
            f"evaluation(s)")
    return StudyResult(spec=spec, outcomes=outcomes,
                       store_hits=env.store_hits,
                       store_misses=env.store_misses,
                       distinct_points=len(store), out=out_path,
                       wall_s=time.time() - t0,
                       store_preloaded=preloaded, store_persisted=persisted)
