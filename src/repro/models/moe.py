"""Top-k routed Mixture-of-Experts — GShard/Switch-style grouped einsum
dispatch (the canonical TPU-native formulation).

Tokens are reshaped into (groups, group_size) aligned with the mesh: groups
shard over ('data','model'), so the dispatch einsum lowers to an all-to-all
into expert-sharded buffers — the paper's MoE collective pattern — and every
tensor stays partitioned by construction (scatter/gather-based dispatch made
XLA's SPMD partitioner replicate the (E,C,D) buffers: +42 GiB/device on
jamba; einsums never do).

Capacity is per (group, expert): C = group_size * top_k * cf / E, overflow
dropped (Switch semantics).  Position-within-expert is a cumsum; the
dispatch/combine tensors are (G, T_g, E, C) one-hots contracted on the MXU.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.layers import ParamDef
from repro.parallel.sharding import ShardingPlan

CAPACITY_FACTOR = 1.25
GROUP_SIZE = 256


def moe_defs(spec: ArchSpec) -> dict[str, ParamDef]:
    d, f, e = spec.d_model, spec.d_ff, spec.n_experts
    return {
        "router": ParamDef((d, e), ("embed", "expert")),
        "w_gate": ParamDef((e, d, f), ("expert", "embed", "ff")),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "ff")),
        "w_down": ParamDef((e, f, d), ("expert", "ff", "embed")),
    }


def expert_capacity(group_size: int, spec: ArchSpec, factor: float | None = None) -> int:
    factor = CAPACITY_FACTOR if factor is None else factor
    cap = int(group_size * spec.top_k * factor / spec.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8 (lane-friendly)


def _dispatch_tensors(logits, k: int, e: int, cap: int):
    """logits: (G, T, E) fp32 -> (dispatch mask, combine weights, aux).

    Pure one-hot/cumsum construction (no scatter): for each of the k routing
    slots, a token's position within its expert is the running count of
    earlier assignments to that expert (earlier tokens first, then earlier
    slots), and the (E, C) one-hot outer product places it in the buffer.
    """
    g, t, _ = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                    # (G,T,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    counts = jnp.zeros((g, 1, e), jnp.float32)
    dispatch = None
    combine = None
    dropped = 0.0
    for j in range(k):
        oh = jax.nn.one_hot(top_i[..., j], e, dtype=jnp.float32)      # (G,T,E)
        pos_in_e = jnp.cumsum(oh, axis=1) - oh + counts                # (G,T,E)
        pos = jnp.sum(pos_in_e * oh, axis=-1)                          # (G,T)
        keep = (pos < cap).astype(jnp.float32)
        oh_c = jax.nn.one_hot(pos, cap, dtype=jnp.float32)             # (G,T,C)
        d_j = jnp.einsum("gte,gtc->gtec", oh * keep[..., None], oh_c)
        c_j = d_j * top_w[..., j][..., None, None]
        dispatch = d_j if dispatch is None else dispatch + d_j
        combine = c_j if combine is None else combine + c_j
        dropped = dropped + jnp.sum(1.0 - keep)
        counts = counts + jnp.sum(oh, axis=1, keepdims=True)

    # Switch-style load-balance loss over the top-1 assignment
    fraction = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    lb_loss = e * jnp.sum(fraction * jnp.mean(probs, axis=(0, 1)))
    drop_frac = dropped / (g * t * k)
    return dispatch, combine, {"lb_loss": lb_loss, "drop_frac": drop_frac}


def moe_apply(p, x, spec: ArchSpec, plan: ShardingPlan,
              *, capacity_factor: float | None = None,
              group_size: int | None = None):
    """x: (B, S, D) -> (y, aux)."""
    b, s, d = x.shape
    e, k = spec.n_experts, spec.top_k
    group_size = GROUP_SIZE if group_size is None else group_size
    tg = min(group_size, s) if s > 1 else 1
    while s % tg:
        tg //= 2
    ng = (b * s) // tg
    cap = expert_capacity(tg, spec, capacity_factor)

    # chunk-MAJOR group order: G = chunk * B + b.  With the residual stream
    # sharded (batch -> data, seq -> model), this makes the (G, Tg, D) view
    # exactly shard-aligned under a ('model','data')-major group sharding —
    # no resharding of activations at the MoE boundary (the b-major order
    # forced XLA to all-gather the full residual every MoE layer).
    nc = s // tg
    xg = x.reshape(b, nc, tg, d).transpose(1, 0, 2, 3).reshape(ng, tg, d)
    xg = plan.constrain(xg, ("moe_groups", None, None))
    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(x.dtype)).astype(jnp.float32)
    dispatch, combine, aux = _dispatch_tensors(logits, k, e, cap)
    # the dispatch mask is a step function — its cotangent is mathematically
    # zero but structurally a giant (G,T,E,C) backward dot; routing gradients
    # flow through `combine` (Switch/GShard convention)
    dispatch = jax.lax.stop_gradient(dispatch).astype(x.dtype)
    combine = combine.astype(x.dtype)
    dispatch = plan.constrain(dispatch, ("moe_groups", None, None, None))
    combine = plan.constrain(combine, ("moe_groups", None, None, None))

    xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch)
    xe = plan.constrain(xe, ("moe_groups", "expert", None, None))
    gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    ye = plan.constrain(ye, ("moe_groups", "expert", None, None))
    y = jnp.einsum("gecd,gtec->gtd", ye, combine)
    y = plan.constrain(y, ("moe_groups", None, None))
    y = y.reshape(nc, b, tg, d).transpose(1, 0, 2, 3).reshape(b, s, d)
    return y, aux
