"""Mamba2 (SSD — state-space duality) mixer.

Training/prefill runs the chunked SSD algorithm (arXiv:2405.21060): the
sequence is split into chunks; within a chunk the *dual* quadratic form is a
pair of matmuls (MXU-friendly — this is the part the Pallas ``ssd_scan``
kernel tiles for VMEM), and chunk-to-chunk state is carried by an associative
recurrence.  Decode is the O(1) recurrent update.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.layers import ParamDef, rmsnorm
from repro.parallel.sharding import ShardingPlan

DEFAULT_CHUNK = 256


def mamba_defs(spec: ArchSpec) -> dict[str, ParamDef]:
    d, din = spec.d_model, spec.d_inner
    g, ds, nh, cw = spec.ssm_groups, spec.ssm_state, spec.ssm_heads, spec.ssm_conv
    return {
        "w_z": ParamDef((d, din), ("embed", "d_inner")),
        "w_x": ParamDef((d, din), ("embed", "d_inner")),
        "w_b": ParamDef((d, g * ds), ("embed", None)),
        "w_c": ParamDef((d, g * ds), ("embed", None)),
        "w_dt": ParamDef((d, nh), ("embed", None)),
        "conv_x": ParamDef((cw, din), (None, "d_inner")),
        "conv_b": ParamDef((cw, g * ds), (None, None)),
        "conv_c": ParamDef((cw, g * ds), (None, None)),
        "a_log": ParamDef((nh,), (None,), "ssm_a_log"),
        "dt_bias": ParamDef((nh,), (None,), "ssm_dt_bias"),
        "d_skip": ParamDef((nh,), (None,), "ones"),
        "norm": ParamDef((din,), ("d_inner",), "zeros"),
        "w_out": ParamDef((din, d), ("d_inner", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv along time.  x: (B,S,C); w: (cw, C)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):  # cw is 4: unrolled adds beat a conv op here
        out = out + pad[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    return jax.nn.silu(out)


def _segsum(t):
    """Stable 'segment sum' producing the lower-tri decay exponents.

    t: (..., L) -> (..., L, L) with out[i, j] = sum_{j < m <= i} t[m].
    """
    l = t.shape[-1]
    cs = jnp.cumsum(t, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int = DEFAULT_CHUNK, h0=None):
    """Chunked SSD scan (single pass over chunks).

    x:  (B, S, H, P)   inputs
    dt: (B, S, H)      positive step sizes
    a:  (H,)           negative decay rates
    b:  (B, S, G, N)   input projections (G groups broadcast over H)
    c:  (B, S, G, N)   output projections
    returns y: (B, S, H, P), final state (B, H, P, N)

    One ``lax.scan`` over chunks carries the (B, H, P, N) state; inside a
    chunk the dual quadratic form is two MXU matmuls.  Scanning (rather than
    materializing all chunks) keeps the O(L^2) intra-chunk tensors to ONE
    chunk's worth — the same streaming the Pallas ``ssd_scan`` kernel does
    in VMEM.
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    rep = h // g
    f32 = jnp.float32

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(bsz, nc, l, *t.shape[2:]), 1, 0)

    xc = to_chunks(x)                       # (nc,B,L,H,P)
    dtc = to_chunks(dt.astype(f32))         # (nc,B,L,H)
    bc = to_chunks(b)                       # (nc,B,L,G,N)
    cc = to_chunks(c)

    af = a.astype(f32)

    def body(hprev, inp):
        xi, dti, bi, ci = inp
        bh = jnp.repeat(bi, rep, axis=2) if rep > 1 else bi   # (B,L,H,N)
        ch = jnp.repeat(ci, rep, axis=2) if rep > 1 else ci
        da = dti * af                                          # (B,L,H)
        da_cum = jnp.cumsum(da, axis=1)
        seg = _segsum(jnp.moveaxis(da, -1, -2))                # (B,H,L,L)
        cb = jnp.einsum("blhn,bmhn->bhlm", ch.astype(f32), bh.astype(f32))
        att = cb * jnp.exp(seg)
        xdt = xi.astype(f32) * dti[..., None]
        y_diag = jnp.einsum("bhlm,bmhp->blhp", att, xdt)
        # contribution of the incoming state
        in_decay = jnp.exp(da_cum)                             # (B,L,H)
        y_off = jnp.einsum("blhn,bhpn->blhp", ch.astype(f32) * in_decay[..., None], hprev)
        # state update
        decay_to_end = jnp.exp(da_cum[:, -1:, :] - da_cum)     # (B,L,H)
        st = jnp.einsum("blhn,blhp->bhpn",
                        bh.astype(f32) * (dti * decay_to_end)[..., None],
                        xi.astype(f32))
        hnew = hprev * jnp.exp(da_cum[:, -1, :])[..., None, None] + st
        return hnew, (y_diag + y_off).astype(x.dtype)

    init = jnp.zeros((bsz, h, p, n), f32) if h0 is None else h0.astype(f32)
    # checkpoint per chunk: keeps the O(L^2) intra-chunk tensors out of the
    # scan's saved residuals (recomputed in backward)
    body = jax.checkpoint(body, prevent_cse=False)
    hlast, yc = jax.lax.scan(body, init, (xc, dtc, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(bsz, s, h, p)
    return y, hlast


def mamba_fwd(p, x, spec: ArchSpec, plan: ShardingPlan, *, chunk: int = DEFAULT_CHUNK):
    """x: (B, S, D) -> (B, S, D) (+ optional cache for prefill)."""
    bsz, s, d = x.shape
    din, g, ds, nh = spec.d_inner, spec.ssm_groups, spec.ssm_state, spec.ssm_heads
    hd = spec.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(x.dtype))
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(x.dtype))
    bi = jnp.einsum("bsd,de->bse", x, p["w_b"].astype(x.dtype))
    ci = jnp.einsum("bsd,de->bse", x, p["w_c"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))

    xi = _causal_conv(xi, p["conv_x"])
    bi = _causal_conv(bi, p["conv_b"])
    ci = _causal_conv(ci, p["conv_c"])

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = plan.constrain(xi.reshape(bsz, s, nh, hd), ("batch", None, "ssm_heads", None))
    dt = plan.constrain(dt, ("batch", None, "ssm_heads"))
    y, hlast = ssd_chunked(
        xh, dt, a,
        bi.reshape(bsz, s, g, ds), ci.reshape(bsz, s, g, ds), chunk)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, din)
    y = plan.constrain(y, ("batch", "seq", "d_inner"))
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], spec.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return out


def mamba_cache_defs(spec: ArchSpec, batch: int, dtype=jnp.bfloat16) -> dict[str, ParamDef]:
    din, g, ds, nh, hd, cw = (spec.d_inner, spec.ssm_groups, spec.ssm_state,
                              spec.ssm_heads, spec.ssm_head_dim, spec.ssm_conv)
    conv_ch = din + 2 * g * ds
    return {
        "conv": ParamDef((batch, cw - 1, conv_ch), ("batch", None, "d_inner"), "zeros"),
        "ssm": ParamDef((batch, nh, hd, ds), ("batch", None, "ssm_head_dim", None), "zeros"),
    }


def mamba_prefill(p, x, spec: ArchSpec, plan: ShardingPlan, cache,
                  *, chunk: int = DEFAULT_CHUNK):
    """Forward over the prompt + produce decode cache (conv tail + final state)."""
    bsz, s, d = x.shape
    din, g, ds, nh, hd = spec.d_inner, spec.ssm_groups, spec.ssm_state, spec.ssm_heads, spec.ssm_head_dim
    cw = spec.ssm_conv
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(x.dtype))
    xi0 = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(x.dtype))
    bi0 = jnp.einsum("bsd,de->bse", x, p["w_b"].astype(x.dtype))
    ci0 = jnp.einsum("bsd,de->bse", x, p["w_c"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    pre_conv = jnp.concatenate([xi0, bi0, ci0], axis=-1)  # raw pre-activation stream
    xi = _causal_conv(xi0, p["conv_x"])
    bi = _causal_conv(bi0, p["conv_b"])
    ci = _causal_conv(ci0, p["conv_c"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = plan.constrain(xi.reshape(bsz, s, nh, hd), ("batch", None, "ssm_heads", None))
    dt = plan.constrain(dt, ("batch", None, "ssm_heads"))
    y, hlast = ssd_chunked(
        xh, dt, a,
        bi.reshape(bsz, s, g, ds), ci.reshape(bsz, s, g, ds), chunk)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], spec.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    newc = {
        "conv": pre_conv[:, -(cw - 1):, :].astype(cache["conv"].dtype),
        "ssm": hlast.astype(cache["ssm"].dtype),
    }
    return out, newc


def mamba_decode(p, x, spec: ArchSpec, plan: ShardingPlan, cache):
    """One-token recurrent update.  x: (B, D)."""
    bsz, d = x.shape
    din, g, ds, nh, hd = spec.d_inner, spec.ssm_groups, spec.ssm_state, spec.ssm_heads, spec.ssm_head_dim
    cw = spec.ssm_conv
    z = x @ p["w_z"].astype(x.dtype)
    xi = x @ p["w_x"].astype(x.dtype)
    bi = x @ p["w_b"].astype(x.dtype)
    ci = x @ p["w_c"].astype(x.dtype)
    dt = jax.nn.softplus((x @ p["w_dt"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B, nh)

    new_raw = jnp.concatenate([xi, bi, ci], axis=-1)  # (B, conv_ch)
    window = jnp.concatenate([cache["conv"].astype(x.dtype), new_raw[:, None, :]], axis=1)  # (B,cw,C)
    wfull = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=1)  # (cw, C)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, wfull.astype(x.dtype)))
    xi = conv_out[:, :din]
    bi = conv_out[:, din : din + g * ds]
    ci = conv_out[:, din + g * ds :]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (nh,)
    decay = jnp.exp(dt * a)                                # (B, nh)
    xh = xi.reshape(bsz, nh, hd).astype(jnp.float32)
    bh = jnp.repeat(bi.reshape(bsz, g, ds), nh // g, axis=1).astype(jnp.float32)  # (B,nh,ds)
    chp = jnp.repeat(ci.reshape(bsz, g, ds), nh // g, axis=1).astype(jnp.float32)
    h = cache["ssm"].astype(jnp.float32)
    h = h * decay[..., None, None] + (dt[..., None] * xh)[..., None] * bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, chp).astype(x.dtype)
    y = y + xh.astype(x.dtype) * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(bsz, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], spec.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    newc = {
        "conv": window[:, 1:, :].astype(cache["conv"].dtype),
        "ssm": h.astype(cache["ssm"].dtype),
    }
    return out, newc
