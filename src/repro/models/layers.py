"""Parameter descriptors + primitive layers (pure-JAX, pytree params).

Every parameter is declared as a ``ParamDef(shape, axes, init)`` where
``axes`` are *logical* sharding axes consumed by ``repro.parallel.sharding``.
Modules are plain functions: ``<module>_defs(spec)`` returns a nested dict of
ParamDefs; ``init_tree`` materializes it; ``apply`` functions consume the
resulting pytree.  No framework dependency (flax-free) — this keeps pytrees
transparent for pjit sharding, checkpointing, and elastic resharding.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple  # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | ssm_a_log | ssm_dt_bias
    scale: float = 1.0


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(key, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a_log":  # A in [-1, -16): log for positivity
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if d.init == "ssm_dt_bias":  # dt in [1e-3, 1e-1] through softplus
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inv softplus
    fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
    std = d.scale / math.sqrt(fan_in)
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_tree(rng, defs, dtype=jnp.float32):
    """Materialize a nested dict of ParamDefs into arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def axes_tree(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def stack_defs(defs, n: int, axis_name=None):
    """Prepend a stacking dim (for scan-over-layers parameter stacks)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale),
        defs, is_leaf=is_def)


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(defs, is_leaf=is_def))


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)).astype(dt)) * (1.0 + weight.astype(dt))


def linear(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def embed_defs(vocab: int, d: int) -> ParamDef:
    return ParamDef((vocab, d), ("vocab", "embed"), "normal")


def take_embedding(table, tokens):
    return jnp.take(table, tokens, axis=0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (S,) or scalar broadcastable."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
