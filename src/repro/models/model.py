"""Top-level LM: embeddings/frontend -> block stack -> head.

Three entry points per architecture, matching the evaluation grid:
  * ``forward``     — full-sequence logits (training shapes)
  * ``prefill``     — prompt pass that also fills decode caches
  * ``decode_step`` — one token with caches (decode / long-context shapes)

``[audio]``/``[vlm]`` archs use the 'embeddings' frontend: ``input_specs``
supplies precomputed frame/patch embeddings (the modality encoder is a stub
per the assignment), and the backbone is exercised fully.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models import blocks
from repro.models.layers import ParamDef, abstract_tree, axes_tree, init_tree, rmsnorm, take_embedding
from repro.parallel.sharding import NULL_PLAN, ShardingPlan


def model_param_defs(spec: ArchSpec) -> dict[str, Any]:
    d, v = spec.d_model, spec.vocab_size
    defs: dict[str, Any] = {
        "stack": blocks.stack_param_defs(spec),
        "final_norm": ParamDef((d,), ("embed",), "zeros"),
    }
    if spec.frontend == "tokens":
        defs["embed"] = ParamDef((v, d), ("vocab", "embed"))
        if not spec.tie_embeddings:
            defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"))
    else:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"))
    return defs


def init_params(rng, spec: ArchSpec, dtype=jnp.float32):
    return init_tree(rng, model_param_defs(spec), dtype)


def abstract_params(spec: ArchSpec, dtype=jnp.float32):
    return abstract_tree(model_param_defs(spec), dtype)


def param_axes(spec: ArchSpec):
    return axes_tree(model_param_defs(spec))


def cache_defs(spec: ArchSpec, batch: int, seq: int, dtype=jnp.bfloat16):
    return blocks.stack_cache_defs(spec, batch, seq, dtype)


def init_caches(spec: ArchSpec, batch: int, seq: int, dtype=jnp.bfloat16):
    return init_tree(jax.random.PRNGKey(0), cache_defs(spec, batch, seq, dtype), dtype)


def abstract_caches(spec: ArchSpec, batch: int, seq: int, dtype=jnp.bfloat16):
    return abstract_tree(cache_defs(spec, batch, seq, dtype), dtype)


def cache_axes(spec: ArchSpec, batch: int, seq: int):
    return axes_tree(cache_defs(spec, batch, seq))


# ---------------------------------------------------------------------------

def _embed_in(params, inputs, spec: ArchSpec, plan: ShardingPlan, compute_dtype):
    if spec.frontend == "tokens":
        x = take_embedding(params["embed"], inputs).astype(compute_dtype)
    else:
        x = inputs.astype(compute_dtype)  # precomputed (B, S, D) embeddings
    return plan.constrain(x, ("batch", "seq", "embed"))


def _head(params, x, spec: ArchSpec, plan: ShardingPlan):
    x = rmsnorm(x, params["final_norm"], spec.norm_eps)
    if spec.frontend == "tokens" and spec.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"].astype(x.dtype))
    axes = ("batch", "seq", "vocab") if logits.ndim == 3 else ("batch", "vocab")
    return plan.constrain(logits, axes)


def forward(params, inputs, spec: ArchSpec, plan: ShardingPlan = NULL_PLAN,
            *, compute_dtype=jnp.float32, remat: str = "dots"):
    """inputs: (B, S) int32 tokens or (B, S, D) embeddings -> (logits, aux)."""
    x = _embed_in(params, inputs, spec, plan, compute_dtype)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x, aux = blocks.stack_train(params["stack"], x, positions, spec, plan, remat)
    return _head(params, x, spec, plan), aux


def forward_hidden(params, inputs, spec: ArchSpec, plan: ShardingPlan = NULL_PLAN,
                   *, compute_dtype=jnp.float32, remat: str = "dots"):
    """Like ``forward`` but stops before the LM head: returns the
    final-normed hidden states.  Pair with ``head_fn`` for chunked-CE
    training (the big-vocab memory optimization)."""
    x = _embed_in(params, inputs, spec, plan, compute_dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux = blocks.stack_train(params["stack"], x, positions, spec, plan, remat)
    x = rmsnorm(x, params["final_norm"], spec.norm_eps)
    return x, aux


def head_fn(params, spec: ArchSpec, plan: ShardingPlan = NULL_PLAN):
    """Closure projecting (already final-normed) hidden chunks to logits."""
    def f(h):
        if spec.frontend == "tokens" and spec.tie_embeddings:
            logits = jnp.einsum("...d,vd->...v", h, params["embed"].astype(h.dtype))
        else:
            logits = jnp.einsum("...d,dv->...v", h, params["lm_head"].astype(h.dtype))
        axes = ("batch", "seq", "vocab") if logits.ndim == 3 else ("batch", "vocab")
        return plan.constrain(logits, axes)
    return f


def prefill(params, inputs, caches, spec: ArchSpec, plan: ShardingPlan = NULL_PLAN,
            *, compute_dtype=jnp.bfloat16):
    """Prompt pass: returns (last-position logits (B, V), filled caches)."""
    x = _embed_in(params, inputs, spec, plan, compute_dtype)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x, newc = blocks.stack_prefill(params["stack"], x, positions, spec, plan, caches)
    logits = _head(params, x[:, -1, :], spec, plan)
    return logits, newc


def decode_step(params, caches, inputs, pos, spec: ArchSpec,
                plan: ShardingPlan = NULL_PLAN, *, compute_dtype=jnp.bfloat16):
    """One decode step.  inputs: (B,) int32 token ids or (B, D) embeddings;
    pos: scalar int32 position of the new token."""
    pos = jnp.asarray(pos, jnp.int32)
    if spec.frontend == "tokens":
        x = take_embedding(params["embed"], inputs).astype(compute_dtype)
    else:
        x = inputs.astype(compute_dtype)
    x = plan.constrain(x, ("batch", "embed"))
    x, newc = blocks.stack_decode(params["stack"], x, pos, spec, plan, caches)
    logits = _head(params, x, spec, plan)
    return logits, newc
