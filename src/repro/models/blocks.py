"""Decoder layers + the scan-over-repeating-blocks stack executor.

``ArchSpec.block_pattern()`` factors the layer stack into (pattern, repeats,
remainder).  Parameters (and decode caches) for the repeated pattern are
*stacked* along a leading dim and executed with ``jax.lax.scan``, keeping HLO
size O(|pattern|) — the difference between minutes and hours when compiling
for 512 devices.  Heterogeneous stacks (gemma3 local:global, jamba
mamba/attn/MoE interleave) fall out naturally: the pattern holds one params
subtree per sublayer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LayerDef
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models.layers import ParamDef, rmsnorm, stack_defs
from repro.parallel.sharding import ShardingPlan

REMAT_POLICIES = {
    "none": None,  # no remat
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    # keep the gathered KV across fwd->bwd: the backward recompute skips the
    # per-layer KV all-gather (collective-term optimization, §Perf)
    "save_kv": jax.checkpoint_policies.save_only_these_names("attn_kv"),
}


def layer_param_defs(spec: ArchSpec, ld: LayerDef) -> dict[str, Any]:
    d = spec.d_model
    defs: dict[str, Any] = {"norm1": ParamDef((d,), ("embed",), "zeros")}
    if ld.mixer == "mamba":
        defs["mixer"] = mb.mamba_defs(spec)
    else:
        defs["mixer"] = attn.attn_defs(spec)
    if ld.ffn != "none":
        defs["norm2"] = ParamDef((d,), ("embed",), "zeros")
        defs["ffn"] = moem.moe_defs(spec) if ld.ffn == "moe" else mlpm.mlp_defs(spec)
    return defs


def layer_cache_defs(spec: ArchSpec, ld: LayerDef, batch: int, seq: int,
                     dtype=jnp.bfloat16) -> dict[str, Any]:
    if ld.mixer == "mamba":
        return mb.mamba_cache_defs(spec, batch, dtype)
    window = spec.sliding_window if ld.mixer == "attn_local" else 0
    return attn.attn_cache_defs(spec, batch, seq, window=window, dtype=dtype)


def _apply_train(p, x, positions, ld: LayerDef, spec: ArchSpec, plan: ShardingPlan):
    h = rmsnorm(x, p["norm1"], spec.norm_eps)
    if ld.mixer == "mamba":
        y = mb.mamba_fwd(p["mixer"], h, spec, plan)
    else:
        window = spec.sliding_window if ld.mixer == "attn_local" else 0
        y = attn.attention_fwd(p["mixer"], h, positions, spec, plan, window=window)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ld.ffn != "none":
        h = rmsnorm(x, p["norm2"], spec.norm_eps)
        if ld.ffn == "moe":
            y, a = moem.moe_apply(p["ffn"], h, spec, plan)
            aux = aux + a["lb_loss"]
        else:
            y = mlpm.mlp_apply(p["ffn"], h, spec, plan)
        x = x + y
    return x, aux


def _apply_prefill(p, x, positions, ld, spec, plan, cache):
    h = rmsnorm(x, p["norm1"], spec.norm_eps)
    if ld.mixer == "mamba":
        y, newc = mb.mamba_prefill(p["mixer"], h, spec, plan, cache)
    else:
        window = spec.sliding_window if ld.mixer == "attn_local" else 0
        y, newc = attn.attn_prefill(p["mixer"], h, positions, spec, plan, cache, window=window)
    x = x + y
    if ld.ffn != "none":
        h = rmsnorm(x, p["norm2"], spec.norm_eps)
        if ld.ffn == "moe":
            y, _ = moem.moe_apply(p["ffn"], h, spec, plan)
        else:
            y = mlpm.mlp_apply(p["ffn"], h, spec, plan)
        x = x + y
    return x, newc


def _apply_decode(p, x, pos, ld, spec, plan, cache):
    h = rmsnorm(x, p["norm1"], spec.norm_eps)
    if ld.mixer == "mamba":
        y, newc = mb.mamba_decode(p["mixer"], h, spec, plan, cache)
    else:
        window = spec.sliding_window if ld.mixer == "attn_local" else 0
        y, newc = attn.attn_decode(p["mixer"], h, pos, spec, plan, cache, window=window)
    x = x + y
    if ld.ffn != "none":
        h = rmsnorm(x, p["norm2"], spec.norm_eps)
        if ld.ffn == "moe":
            y, _ = moem.moe_apply(p["ffn"], h[:, None, :], spec, plan)
            y = y[:, 0, :]
        else:
            y = mlpm.mlp_apply(p["ffn"], h[:, None, :], spec, plan)[:, 0, :]
        x = x + y
    return x, newc


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def stack_param_defs(spec: ArchSpec) -> dict[str, Any]:
    pattern, reps, rem = spec.block_pattern()
    blocks = {
        f"sub{j}": stack_defs(layer_param_defs(spec, ld), reps, None)
        for j, ld in enumerate(pattern)
    }
    tail = {f"tail{j}": layer_param_defs(spec, ld) for j, ld in enumerate(rem)}
    return {"blocks": blocks, "tail": tail}


def stack_cache_defs(spec: ArchSpec, batch: int, seq: int, dtype=jnp.bfloat16) -> dict[str, Any]:
    pattern, reps, rem = spec.block_pattern()
    blocks = {
        f"sub{j}": stack_defs(layer_cache_defs(spec, ld, batch, seq, dtype), reps, None)
        for j, ld in enumerate(pattern)
    }
    tail = {f"tail{j}": layer_cache_defs(spec, ld, batch, seq, dtype) for j, ld in enumerate(rem)}
    return {"blocks": blocks, "tail": tail}


def stack_train(params, x, positions, spec: ArchSpec, plan: ShardingPlan,
                remat: str = "dots"):
    pattern, reps, rem = spec.block_pattern()

    def sublayer(j, ld):
        def f(p, h):
            h, a = _apply_train(p, h, positions, ld, spec, plan)
            return plan.constrain(h, ("batch", "seq", "embed")), a
        if remat != "none":
            # checkpoint at SUBLAYER granularity: the backward pass only ever
            # holds one sublayer's recompute transients (vs. a whole
            # heterogeneous block's — 8x for jamba)
            f = jax.checkpoint(f, policy=REMAT_POLICIES[remat], prevent_cse=False)
        return f

    fns = [sublayer(j, ld) for j, ld in enumerate(pattern)]

    def block_body(carry, xs):
        h, aux = carry
        for j in range(len(pattern)):
            h, a = fns[j](xs[f"sub{j}"], h)
            aux = aux + a
        return (h, aux), None

    (x, aux), _ = jax.lax.scan(block_body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], length=reps)
    tail_fns = [sublayer(j, ld) for j, ld in enumerate(rem)]
    for j, ld in enumerate(rem):
        x, a = tail_fns[j](params["tail"][f"tail{j}"], x)
        aux = aux + a
    return x, aux


def stack_prefill(params, x, positions, spec: ArchSpec, plan: ShardingPlan, caches):
    pattern, reps, rem = spec.block_pattern()

    def block_body(h, xs):
        ps, cs = xs
        newcs = {}
        for j, ld in enumerate(pattern):
            h, newcs[f"sub{j}"] = _apply_prefill(ps[f"sub{j}"], h, positions, ld, spec, plan, cs[f"sub{j}"])
            h = plan.constrain(h, ("batch", "seq", "embed"))
        return h, newcs

    x, new_blocks = jax.lax.scan(block_body, x, (params["blocks"], caches["blocks"]), length=reps)
    new_tail = {}
    for j, ld in enumerate(rem):
        x, new_tail[f"tail{j}"] = _apply_prefill(
            params["tail"][f"tail{j}"], x, positions, ld, spec, plan, caches["tail"][f"tail{j}"])
    return x, {"blocks": new_blocks, "tail": new_tail}


def stack_decode(params, x, pos, spec: ArchSpec, plan: ShardingPlan, caches):
    pattern, reps, rem = spec.block_pattern()

    def block_body(h, xs):
        ps, cs = xs
        newcs = {}
        for j, ld in enumerate(pattern):
            h, newcs[f"sub{j}"] = _apply_decode(ps[f"sub{j}"], h, pos, ld, spec, plan, cs[f"sub{j}"])
        return h, newcs

    x, new_blocks = jax.lax.scan(block_body, x, (params["blocks"], caches["blocks"]), length=reps)
    new_tail = {}
    for j, ld in enumerate(rem):
        x, new_tail[f"tail{j}"] = _apply_decode(
            params["tail"][f"tail{j}"], x, pos, ld, spec, plan, caches["tail"][f"tail{j}"])
    return x, {"blocks": new_blocks, "tail": new_tail}
