"""Dense FFN: SwiGLU (llama-family) or GELU (gpt/gemma/musicgen-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.layers import ParamDef
from repro.parallel.sharding import ShardingPlan


def mlp_defs(spec: ArchSpec) -> dict[str, ParamDef]:
    d, f = spec.d_model, spec.d_ff
    defs = {
        "w_up": ParamDef((d, f), ("embed", "ff")),
        "w_down": ParamDef((f, d), ("ff", "embed")),
    }
    if spec.act == "silu":
        defs["w_gate"] = ParamDef((d, f), ("embed", "ff"))
    return defs


def mlp_apply(p, x, spec: ArchSpec, plan: ShardingPlan) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if spec.act == "silu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = plan.constrain(h, ("batch", "seq", "ff"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
