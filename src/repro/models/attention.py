"""GQA attention: training/prefill forward + cached decode.

Long sequences use a blockwise online-softmax formulation (pure jnp; the
Pallas flash-attention kernel in ``repro.kernels`` is the TPU-optimized twin
validated against the same math).  Sliding-window layers reuse the same code
with a band mask; decode keeps either a full cache (global layers) or a
ring-buffer cache (local layers).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchSpec
from repro.models.layers import ParamDef, apply_rope, linear
from repro.parallel.sharding import ShardingPlan

NEG_INF = -1e30


def attn_defs(spec: ArchSpec) -> dict[str, ParamDef]:
    d, h, g, hd = spec.d_model, spec.n_heads, spec.n_kv_heads, spec.resolved_head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wk": ParamDef((d, g, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, g, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("q_heads", "head_dim", "embed")),
    }
    if spec.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("q_heads", "head_dim"), "zeros")
        defs["bk"] = ParamDef((g, hd), ("kv_heads", "head_dim"), "zeros")
        defs["bv"] = ParamDef((g, hd), ("kv_heads", "head_dim"), "zeros")
    return defs


def _project_qkv(p, x, spec: ArchSpec):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"].astype(x.dtype))
    if spec.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return q, k, v


def _repeat_kv(k, n_heads: int):
    """(B, T, G, hd) -> (B, T, H, hd) by repeating each group."""
    b, t, g, hd = k.shape
    rep = n_heads // g
    if rep == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, g, rep, hd)).reshape(b, t, n_heads, hd)


def _sdpa_block(q, k, v, mask, scale):
    """Dense softmax attention on one (query-block x full-kv) tile."""
    s = jnp.einsum("bqhk,bthk->bhqt", q, k) * scale
    s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthk->bqhk", p, v)


def flash_attention_ref(q, k, v, positions, *, window: int = 0,
                        kv_chunk: int = 1024, scale: float | None = None):
    """Online-softmax attention, scanning over KV chunks (pure jnp).

    q, k, v: (B, S, H, hd) — k/v already repeated to H heads.  Peak memory is
    O(S * kv_chunk) per head instead of O(S^2).  This is also the oracle the
    Pallas flash-attention kernel is validated against.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    scale = scale or (1.0 / math.sqrt(hd))
    ck = min(kv_chunk, t)
    assert t % ck == 0, (t, ck)
    nck = t // ck
    f32 = jnp.float32
    kc = jnp.moveaxis(k.reshape(b, nck, ck, h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nck, ck, h, hd), 1, 0)
    starts = jnp.arange(nck, dtype=jnp.int32) * ck
    qpos = positions  # (s,)

    def body(carry, xs):
        m, l, acc = carry
        ki, vi, start = xs
        kpos = start + jnp.arange(ck, dtype=jnp.int32)
        sc = jnp.einsum("bqhk,bthk->bqht", q, ki).astype(f32) * scale
        mask = kpos[None, :] <= qpos[:, None]  # (s, ck)
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        sc = jnp.where(mask[None, :, None, :], sc, NEG_INF)
        mnew = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - mnew[..., None])
        alpha = jnp.exp(m - mnew)
        lnew = l * alpha + p.sum(axis=-1)
        accnew = acc * alpha[..., None] + jnp.einsum(
            "bqht,bthk->bqhk", p.astype(q.dtype), vi).astype(f32)
        return (mnew, lnew, accnew), None

    m0 = jnp.full((b, s, h), NEG_INF, f32)
    l0 = jnp.zeros((b, s, h), f32)
    a0 = jnp.zeros((b, s, h, hd), f32)
    # checkpoint per KV chunk: the scan's backward otherwise stacks every
    # chunk's (B,S,H,ck) probabilities = the full S x T score matrix in f32
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, starts))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attention_fwd(p, x, positions, spec: ArchSpec, plan: ShardingPlan,
                  *, window: int = 0, dense_threshold: int = 2048,
                  kv_chunk: int = 1024) -> jax.Array:
    """Causal (optionally sliding-window) self-attention over a full sequence.

    Layout policy (the TP/SP adaptation of the paper's Workload knobs):
      * heads divide the 'model' axis  -> Megatron head-sharded attention
        (all-gather small GQA KV, shard all S^2 work over heads),
      * otherwise                      -> sequence-sharded attention: Q keeps
        the residual stream's seq sharding, KV is gathered, S^2 work shards
        over the query-sequence dim.  Works for any head count (gemma3's 4
        heads, qwen2's 12, granite's 24 on a 16-way axis).
    Long sequences stream KV chunks with online softmax (flash-style) so
    activation memory is O(S * kv_chunk).
    """
    b, s, d = x.shape
    h, hd = spec.n_heads, spec.resolved_head_dim
    q, k, v = _project_qkv(p, x, spec)
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    # gather the (small, GQA) KV across the seq sharding FIRST, then the
    # head-repeat broadcast is purely local.  checkpoint_name lets the
    # 'save_kv' remat policy keep the gathered KV for the backward pass
    # instead of re-running the all-gather during recompute.
    k = plan.constrain(k, ("batch", None, None, None))
    v = plan.constrain(v, ("batch", None, None, None))
    k = checkpoint_name(k, "attn_kv")
    v = checkpoint_name(v, "attn_kv")
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    head_sharded = plan.can_shard("q_heads", h)
    if head_sharded:
        q = plan.constrain(q, ("batch", None, "q_heads", None))
        k = plan.constrain(k, ("batch", None, "q_heads", None))
        v = plan.constrain(v, ("batch", None, "q_heads", None))
    else:
        q = plan.constrain(q, ("batch", "seq", None, None))
    scale = 1.0 / math.sqrt(hd)

    if s <= dense_threshold:
        kpos, qpos = positions, positions
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        o = _sdpa_block(q, k, v, mask[None, None], scale)
    else:
        o = flash_attention_ref(q, k, v, positions, window=window,
                                kv_chunk=kv_chunk, scale=scale)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def attn_cache_defs(spec: ArchSpec, batch: int, seq: int, *, window: int = 0,
                    dtype=jnp.bfloat16) -> dict[str, ParamDef]:
    g, hd = spec.n_kv_heads, spec.resolved_head_dim
    t = min(window, seq) if window else seq
    defs = {
        "k": ParamDef((batch, t, g, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        "v": ParamDef((batch, t, g, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
    }
    if window:
        defs["kpos"] = ParamDef((t,), (None,), "zeros")  # holds pos+1 (0 = empty)
    return defs


def attn_prefill(p, x, positions, spec: ArchSpec, plan: ShardingPlan, cache,
                 *, window: int = 0):
    """Forward over the prompt, filling the cache.  Sequence length must
    equal the cache length (the dry-run prefill shape); ring-buffer caches
    keep the trailing ``window`` tokens."""
    b, s, d = x.shape
    y = attention_fwd(p, x, positions, spec, plan, window=window)
    q, k, v = _project_qkv(p, x, spec)
    k = apply_rope(k, positions, spec.rope_theta)
    t = cache["k"].shape[1]
    if window:
        # trailing `m` tokens, laid out at slot = pos % t
        m = min(s, t)
        tail_pos = positions[-m:]
        slots = tail_pos % t
        newk = jnp.zeros_like(cache["k"]).at[:, slots].set(k[:, -m:].astype(cache["k"].dtype))
        newv = jnp.zeros_like(cache["v"]).at[:, slots].set(v[:, -m:].astype(cache["v"].dtype))
        kpos = jnp.zeros_like(cache["kpos"]).at[slots].set((tail_pos + 1).astype(cache["kpos"].dtype))
        cache = {"k": newk, "v": newv, "kpos": kpos}
    else:
        assert s <= t, (s, t)
        newk = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        newv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        cache = {"k": newk, "v": newv}
    cache = constrain_cache(cache, plan)
    return y, cache


def constrain_cache(cache, plan: ShardingPlan):
    out = dict(cache)
    for n in ("k", "v"):
        out[n] = plan.constrain(cache[n], ("batch", "kv_seq", "kv_heads", "head_dim"))
    return out


def attn_decode(p, x, pos, spec: ArchSpec, plan: ShardingPlan, cache,
                *, window: int = 0):
    """One decode step.  x: (B, D); pos: scalar int32 (shared across batch).

    GQA is computed with grouped einsums (no head-repeat broadcast), so the
    KV cache keeps its kv_seq sharding: score/softmax reductions over the
    sharded T dim lower to all-reduces — distributed decode attention.
    """
    b, d = x.shape
    pos = jnp.asarray(pos, jnp.int32)
    h, g, hd = spec.n_heads, spec.n_kv_heads, spec.resolved_head_dim
    r = h // g
    q, k, v = _project_qkv(p, x[:, None, :], spec)  # (B,1,...)
    posv = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, posv, spec.rope_theta)
    k = apply_rope(k, posv, spec.rope_theta)

    t = cache["k"].shape[1]
    slot = (pos % t) if window else jnp.minimum(pos, t - 1)
    newk = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    newv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    if window:
        kpos = jax.lax.dynamic_update_slice(cache["kpos"], (pos + 1)[None].astype(cache["kpos"].dtype), (slot,))
        valid = (kpos > 0) & (kpos - 1 <= pos) & (kpos - 1 > pos - t)
        newc = {"k": newk, "v": newv, "kpos": kpos}
    else:
        valid = jnp.arange(t) <= pos
        newc = {"k": newk, "v": newv}
    newc = constrain_cache(newc, plan)

    qg = q[:, 0].reshape(b, g, r, hd)
    kk = newc["k"].astype(q.dtype)  # (B,T,G,hd), kv_seq-sharded
    vv = newc["v"].astype(q.dtype)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bgrk,btgk->bgrt", qg, kk) * scale  # (B,G,R,T)
    s = jnp.where(valid[None, None, None, :], s.astype(jnp.float32), NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bgrt,btgk->bgrk", pr, vv).reshape(b, h, hd)
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(x.dtype))
    return y, newc
