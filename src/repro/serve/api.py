"""Serving entry points: prefill_step / serve_step factories.

These are the functions the dry-run lowers for the inference cells
(``prefill_32k`` lowers prefill_step; ``decode_32k``/``long_500k`` lower
serve_step — one new token against a seq_len KV cache).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models import model as M
from repro.parallel.sharding import NULL_PLAN, ShardingPlan


def make_prefill_step(spec: ArchSpec, plan: ShardingPlan = NULL_PLAN,
                      compute_dtype=jnp.bfloat16):
    def prefill_step(params, inputs, caches):
        return M.prefill(params, inputs, caches, spec, plan, compute_dtype=compute_dtype)
    return prefill_step


def make_serve_step(spec: ArchSpec, plan: ShardingPlan = NULL_PLAN,
                    compute_dtype=jnp.bfloat16):
    def serve_step(params, caches, inputs, pos):
        return M.decode_step(params, caches, inputs, pos, spec, plan, compute_dtype=compute_dtype)
    return serve_step


def decode_inputs_abstract(spec: ArchSpec, batch: int, compute_dtype=jnp.bfloat16):
    """ShapeDtypeStructs for one decode step's new-token inputs."""
    if spec.frontend == "tokens":
        tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((batch, spec.d_model), compute_dtype)
    return tok, jax.ShapeDtypeStruct((), jnp.int32)


def prefill_inputs_abstract(spec: ArchSpec, batch: int, seq: int, compute_dtype=jnp.bfloat16):
    if spec.frontend == "tokens":
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq, spec.d_model), compute_dtype)
