"""Batched serving engine: prefill + decode loop with static batching.

Requests are padded/batched, prompts run through ``prefill`` (which fills
the caches), then tokens decode step-by-step with greedy or temperature
sampling.  The engine is deliberately mesh-agnostic: pass a plan and jit
shardings for pod-scale serving, or nothing for CPU smoke tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec
from repro.models import model as M
from repro.parallel.sharding import NULL_PLAN, ShardingPlan


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, spec: ArchSpec, params, *, plan: ShardingPlan = NULL_PLAN,
                 max_len: int = 256, dtype=jnp.float32):
        self.spec = spec
        self.params = params
        self.plan = plan
        self.max_len = max_len
        self.dtype = dtype
        self._prefill = jax.jit(
            lambda p, t, c: M.prefill(p, t, c, spec, plan, compute_dtype=dtype))
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, spec, plan,
                                               compute_dtype=dtype))

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> tuple[np.ndarray, ServeStats]:
        """prompts: (B, S) int32 (same length; pad upstream)."""
        b, s = prompts.shape
        assert s + max_new <= self.max_len
        stats = ServeStats()
        caches = M.init_caches(self.spec, b, self.max_len, dtype=self.dtype)

        t0 = time.time()
        logits, caches = self._prefill(self.params, jnp.asarray(prompts), caches)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new), np.int32)
        t0 = time.time()
        for i in range(max_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out[:, i] = np.asarray(tok)
            logits, caches = self._decode(self.params, caches, tok.astype(jnp.int32),
                                          jnp.asarray(s + i, jnp.int32))
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = b * max_new
        return out, stats
