"""End-to-end training driver.

Wires together: config registry (--arch), synthetic data pipeline with
prefetch, sharded train step (any mesh), async atomic checkpointing with
auto-resume, heartbeats, straggler monitoring, and failure injection for
fault-tolerance drills.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, reduced
from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import NULL_PLAN, plan_for_mesh
from repro.runtime.fault import Heartbeat, StragglerMonitor
from repro.train import optimizer as opt
from repro.train.train_step import (RunConfig, batch_axes, init_train_state,
                                    make_train_step, train_state_axes)


def build(spec, mesh, cfg: RunConfig, seed: int = 0):
    plan = plan_for_mesh(mesh) if mesh is not None else NULL_PLAN
    step_fn = make_train_step(spec, plan, cfg)
    state = init_train_state(jax.random.PRNGKey(seed), spec, cfg)
    if mesh is not None:
        from repro.parallel.sharding import tree_shardings
        ax = train_state_axes(spec, cfg)
        specs = jax.tree.map(lambda a, s: plan.spec(a, np.shape(s)), ax, state,
                             is_leaf=lambda x: isinstance(x, tuple) and all(
                                 isinstance(e, (str, type(None))) for e in x))
        sh = tree_shardings(mesh, specs)
        state = jax.device_put(state, sh)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
    else:
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
    return jit_step, state


def train_loop(args, spec, fail_at: int | None = None) -> int:
    cfg = RunConfig(
        compute_dtype=jnp.bfloat16 if args.bf16 else jnp.float32,
        param_dtype=jnp.bfloat16 if args.bf16 else jnp.float32,
        remat=args.remat, microbatches=args.microbatches,
        opt=opt.OptConfig(lr=args.lr, warmup_steps=args.warmup),
    )
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[: len(shape)]
        mesh = make_mesh(shape, names)

    jit_step, state = build(spec, mesh, cfg, args.seed)

    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    start = 0
    if ckpt and latest_step(args.ckpt_dir) is not None:
        state, start = restore(args.ckpt_dir, state)
        print(f"[train] resumed from step {start}", flush=True)

    data = SyntheticLM(spec, DataConfig(args.batch, args.seq, seed=args.seed))
    prefetch = Prefetcher(data, start_step=start, depth=2)
    hb = Heartbeat(Path(args.ckpt_dir or "/tmp") / "heartbeat.json") if args.ckpt_dir else None
    straggler = StragglerMonitor(k_sigma=args.straggler_sigma)

    losses = []
    it = iter(prefetch)
    try:
        for step, batch in it:
            if step >= args.steps:
                break
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.time()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if straggler.observe(step, dt):
                print(f"[straggler] step {step} took {dt:.3f}s "
                      f"(mean {straggler.mean:.3f}s) — mitigation hook fired", flush=True)
            if hb:
                hb.beat(step)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(state, step + 1)
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
        final = min(args.steps, step + 1)
    finally:
        prefetch.close()
    if ckpt:
        ckpt.save(state, final, block=True)
    print(f"[train] done at step {final}; loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
    return final


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bf16", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="", help="e.g. 2x2 (requires host devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-sigma", type=float, default=3.0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (fault drill)")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if args.reduced:
        spec = reduced(spec)
    train_loop(args, spec, fail_at=args.fail_at)


if __name__ == "__main__":
    main()
