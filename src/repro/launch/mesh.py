"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init, and
smoke tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic rescale)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def single_device_mesh():
    return jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
