"""Serving driver: batched generation through the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --batch 4 --prompt-len 16 --new 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_arch, reduced
from repro.models import model as M
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if args.reduced:
        spec = reduced(spec)
    if spec.frontend != "tokens":
        raise SystemExit(f"{args.arch} uses an embeddings frontend; "
                         "drive it via repro.models.model.prefill/decode_step "
                         "(see tests/test_perf_features.py)")
    params = M.init_params(jax.random.PRNGKey(args.seed), spec)
    eng = Engine(spec, params, max_len=args.prompt_len + args.new)
    prompts = np.random.default_rng(args.seed).integers(
        0, spec.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    out, stats = eng.generate(prompts, max_new=args.new,
                              temperature=args.temperature, seed=args.seed)
    print(f"[serve] prefill {stats.prefill_s*1e3:.0f} ms | "
          f"decode {stats.decode_tok_per_s:.1f} tok/s | {stats.tokens_out} tokens")
    for i, row in enumerate(out[:4]):
        print(f"  request {i}: {row.tolist()[:16]}{'...' if args.new > 16 else ''}")


if __name__ == "__main__":
    main()
