import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero device allocation
(ShapeDtypeStruct stand-ins only):
  * proof the sharding config is coherent (compile succeeds),
  * ``memory_analysis()``    -> per-device bytes (proves it fits HBM),
  * ``cost_analysis()``      -> XLA's per-while-iteration flops/bytes,
  * loop-aware totals        -> repro.core.hlo_analysis (trip-count aware),
  * per-kind collective bytes + replica-group sizes for the roofline's
    collective term.

Results land in results/dryrun/<arch>__<shape>__<mesh>[__tag].json and are
consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ASSIGNED, SHAPES, cell_is_runnable, get_arch
from repro.core.hlo_analysis import HloCostModel
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import plan_for_mesh, tree_specs
from repro.serve.api import (decode_inputs_abstract, make_prefill_step,
                             make_serve_step, prefill_inputs_abstract)
from repro.train import optimizer as opt
from repro.train.train_step import (RunConfig, abstract_train_state, batch_abstract,
                                    batch_axes, make_train_step, train_state_axes)
from repro.models.layers import axes_tree


def _shardings(mesh, plan, axes, abstract):
    from jax.sharding import NamedSharding
    specs = jax.tree.map(
        lambda ax, ab: plan.spec(ax, ab.shape),
        axes, abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# Baseline per-cell run knobs (the paper-faithful starting point).
# Hillclimb overrides are passed via --set key=value.
def default_knobs(arch: str, shape: str) -> dict:
    spec = get_arch(arch)
    knobs = {
        "remat": "full",
        "microbatches": 1,
        "fsdp": True,
        "sp": True,
        "donate": True,
    }
    # grad accumulation sized so the train_4k shape fits 16 GB HBM:
    # large models are dominated by per-microbatch activations + fp32 logits
    if shape == "train_4k":
        p = spec.param_count()
        if p > 4e10:
            knobs["microbatches"] = 8
        elif p > 1e10:
            knobs["microbatches"] = 4
        elif p > 5e9 or spec.vocab_size > 130_000:
            knobs["microbatches"] = 2
    return knobs


def run_cell(arch: str, shape_name: str, mesh_kind: str, knobs: dict,
             out_dir: Path, tag: str = "") -> dict:
    spec = get_arch(arch)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "kind": shape.kind, "knobs": dict(knobs),
        "params": spec.param_count(), "active_params": spec.active_param_count(),
    }
    if not cell_is_runnable(spec, shape):
        rec["status"] = "skipped"
        rec["why"] = "long_500k requires a sub-quadratic mixer (see DESIGN.md)"
        _write(out_dir, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    # attn_dp / mamba_dp: replicate those weights over 'model' and compute
    # the mixer fully sequence-sharded — removes the per-layer Megatron
    # AG+AR pair at the cost of weight replication.  Optimizer states stay
    # FULLY sharded via a separate plan (ZeRO-2-style split).
    rules = None
    drop = []
    if knobs.get("attn_dp"):
        drop += ["q_heads", "kv_heads"]
    if knobs.get("mamba_dp"):
        drop += ["d_inner", "ssm_heads"]
    if drop:
        from repro.parallel.sharding import _default_rules
        rules = _default_rules(knobs["fsdp"], knobs["sp"])
        for k in drop:
            rules[k] = []
    plan = plan_for_mesh(mesh, fsdp=knobs["fsdp"], sp=knobs["sp"], rules=rules)
    plan_opt = plan_for_mesh(mesh, fsdp=True, sp=knobs["sp"]) if drop else plan
    if knobs.get("moe_group"):
        import repro.models.moe as _moem
        _moem.GROUP_SIZE = int(knobs["moe_group"])
    cfg = RunConfig(compute_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
                    remat=knobs["remat"], microbatches=knobs["microbatches"],
                    loss_chunk=knobs.get("loss_chunk", 0))
    t0 = time.time()
    try:
        if shape.kind == "train":
            state_abs = abstract_train_state(spec, cfg)
            state_ax = train_state_axes(spec, cfg)
            b_abs = batch_abstract(spec, shape.global_batch, shape.seq_len, cfg.compute_dtype)
            b_ax = batch_axes(spec)
            step = make_train_step(spec, plan, cfg, opt_plan=plan_opt if drop else None)
            state_sh = {
                k: _shardings(mesh, plan_opt if k in ("m", "v", "master") else plan,
                              state_ax[k], state_abs[k])
                for k in state_abs
            }
            in_sh = (state_sh, _shardings(mesh, plan, b_ax, b_abs))
            jf = jax.jit(step, in_shardings=in_sh,
                         donate_argnums=(0,) if knobs["donate"] else ())
            with mesh:
                lowered = jf.lower(state_abs, b_abs)
        else:
            params_abs = M.abstract_params(spec, cfg.param_dtype)
            params_ax = M.param_axes(spec)
            p_sh = _shardings(mesh, plan, params_ax, params_abs)
            caches_abs = M.abstract_caches(spec, shape.global_batch, shape.seq_len, jnp.bfloat16)
            caches_ax = M.cache_axes(spec, shape.global_batch, shape.seq_len)
            c_sh = _shardings(mesh, plan, caches_ax, caches_abs)
            if shape.kind == "prefill":
                inp_abs = prefill_inputs_abstract(spec, shape.global_batch, shape.seq_len, cfg.compute_dtype)
                i_ax = ("batch", None) if spec.frontend == "tokens" else ("batch", None, None)
                from jax.sharding import NamedSharding
                i_sh = NamedSharding(mesh, plan.spec(i_ax, inp_abs.shape))
                fn = make_prefill_step(spec, plan, cfg.compute_dtype)
                jf = jax.jit(fn, in_shardings=(p_sh, i_sh, c_sh),
                             donate_argnums=(2,) if knobs["donate"] else ())
                with mesh:
                    lowered = jf.lower(params_abs, inp_abs, caches_abs)
            else:  # decode
                tok_abs, pos_abs = decode_inputs_abstract(spec, shape.global_batch, cfg.compute_dtype)
                t_ax = ("batch",) if spec.frontend == "tokens" else ("batch", None)
                from jax.sharding import NamedSharding
                t_sh = NamedSharding(mesh, plan.spec(t_ax, tok_abs.shape))
                pos_sh = NamedSharding(mesh, plan.spec((), ()))
                fn = make_serve_step(spec, plan, cfg.compute_dtype)
                jf = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                             donate_argnums=(1,) if knobs["donate"] else ())
                with mesh:
                    lowered = jf.lower(params_abs, caches_abs, tok_abs, pos_abs)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": ma.argument_size_in_bytes + ma.output_size_in_bytes
                                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        }
        if shape.kind in ("decode", "prefill"):
            # XLA:CPU has no native bf16 dot: it materializes an f32 shadow
            # of the (whole, layer-stacked) KV cache inside the decode scan
            # (verified via --xla_dump buffer assignment).  TPU executes
            # bf16 dots natively, so the shadow does not exist there.
            import numpy as _np
            cache_bytes = 0
            for sh_leaf, ab_leaf in zip(jax.tree.leaves(c_sh), jax.tree.leaves(caches_abs)):
                local = sh_leaf.shard_shape(ab_leaf.shape)
                cache_bytes += int(_np.prod(local)) * ab_leaf.dtype.itemsize
            rec["memory"]["kv_cache_bytes_per_device"] = cache_bytes
            rec["memory"]["tpu_adjusted_peak"] = (
                rec["memory"]["peak_bytes_per_device"]
                - (2 * cache_bytes if shape.kind == "decode" else 0))
        ca = compiled.cost_analysis() or {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed", "transcendentals")}
        t2 = time.time()
        txt = compiled.as_text()
        model_ = HloCostModel(txt)
        tot = model_.analyze()
        rec["analysis_s"] = round(time.time() - t2, 2)
        rec["hlo"] = {
            "flops_per_device": tot.flops,
            "bytes_per_device": tot.bytes_accessed,
            "fused_bytes_per_device": tot.bytes_fused,
            "transcendentals": tot.transcendentals,
            "collective_bytes": dict(tot.collective_bytes),
            "collective_counts": dict(tot.collective_counts),
            "collective_by_group": {f"{k}@{g}": v for (k, g), v in tot.collective_by_group.items()},
            "unknown_trip_loops": model_.unknown_trip_loops,
            "hlo_text_bytes": len(txt),
        }
        rec["n_chips"] = n_chips
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        n_active = spec.active_param_count()
        mult = 6 if shape.kind == "train" else 2
        rec["model_flops"] = float(mult * n_active * tokens)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(out_dir, rec)
    return rec


def _write(out_dir: Path, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = out_dir / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    path.write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = ""
    if status == "ok":
        gb = rec["memory"]["peak_bytes_per_device"] / 2**30
        extra = (f" mem/dev={gb:.2f}GiB flops/dev={rec['hlo']['flops_per_device']:.3e}"
                 f" coll/dev={sum(rec['hlo']['collective_bytes'].values()):.3e}B"
                 f" compile={rec.get('compile_s')}s")
    elif status == "error":
        extra = " " + rec["error"][:160]
    print(f"[dryrun] {rec['arch']}:{rec['shape']}:{rec['mesh']}{tag} -> {status}{extra}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="knob override key=value (remat, microbatches, fsdp, sp, donate)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    out = Path(args.out)

    n_ok = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                knobs = default_knobs(arch, shape)
                for kv in args.set:
                    k, v = kv.split("=", 1)
                    knobs[k] = (v if k == "remat"
                                else v.lower() in ("1", "true")
                                if k in ("fsdp", "sp", "donate", "attn_dp", "mamba_dp")
                                else int(v))
                rec = run_cell(arch, shape, mesh_kind, knobs, out, args.tag)
                n_ok += rec["status"] in ("ok", "skipped")
                n_err += rec["status"] == "error"
    print(f"[dryrun] done: {n_ok} ok/skipped, {n_err} errors", flush=True)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
