"""Checkpointing: atomic, async-capable, elastic.

* atomic      — write to <dir>.tmp then rename; a crash mid-write can never
                corrupt the latest checkpoint.
* async       — ``AsyncCheckpointer`` snapshots to host memory synchronously
                (cheap) and persists on a background thread, overlapping I/O
                with the next train steps.
* elastic     — ``restore`` takes a target sharding tree: any checkpoint can
                be loaded onto any mesh (device_put against the new
                shardings), which is the re-scale path after losing a pod.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir: str | Path, tree, step: int, meta: dict | None = None) -> Path:
    """Atomic checkpoint write.  Returns the final directory."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **{k.replace("/", "\x1f"): v for k, v in arrays.items()})
    (tmp / "meta.json").write_text(json.dumps({
        "step": step, "keys": list(arrays.keys()),
        "time": time.time(), **(meta or {}),
    }))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*") if p.is_dir()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, like_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding — the ELASTIC path: the
    checkpoint may have been written from any mesh; arrays are device_put
    against the new layout."""
    ckpt_dir = Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    with np.load(d / "arrays.npz") as z:
        arrays = {k.replace("\x1f", "/"): z[k] for k in z.files}
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    out_leaves = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves_with_path))
    for (path, like), sh in zip(leaves_with_path, sh_leaves):
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {like.shape}")
        arr = arr.astype(like.dtype)
        out_leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out_leaves), step


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, persist in the background."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, tree, step: int, meta: dict | None = None, block: bool = False):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # sync snapshot

        def _persist():
            try:
                save(self.ckpt_dir, host_tree, step, meta)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_persist, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1]) for p in self.ckpt_dir.glob("step_*"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:08d}", ignore_errors=True)
