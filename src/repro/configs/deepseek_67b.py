"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import DEEPSEEK_67B as SPEC

__all__ = ["SPEC"]
