"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import VIT_BASE as SPEC

__all__ = ["SPEC"]
