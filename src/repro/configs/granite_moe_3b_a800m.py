"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import GRANITE_MOE_3B as SPEC

__all__ = ["SPEC"]
