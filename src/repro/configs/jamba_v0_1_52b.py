"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import JAMBA_52B as SPEC

__all__ = ["SPEC"]
