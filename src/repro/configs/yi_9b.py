"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import YI_9B as SPEC

__all__ = ["SPEC"]
