"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import QWEN2_1_5B as SPEC

__all__ = ["SPEC"]
