"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import GPT3_13B as SPEC

__all__ = ["SPEC"]
