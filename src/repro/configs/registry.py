"""--arch <id> registry: 10 assigned architectures + the paper's 4 workloads."""
from __future__ import annotations

from repro.configs.base import ArchSpec

# ---------------------------------------------------------------------------
# Assigned architectures (LM family; exact configs from the task sheet).
# ---------------------------------------------------------------------------

MAMBA2_130M = ArchSpec(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50_280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    tie_embeddings=True, max_seq=1_048_576,
)

YI_9B = ArchSpec(
    name="yi-9b", family="dense", n_layers=48, d_model=4_096,
    n_heads=32, n_kv_heads=4, d_ff=11_008, vocab_size=64_000,
    rope_theta=5_000_000.0,
)

DEEPSEEK_67B = ArchSpec(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8_192,
    n_heads=64, n_kv_heads=8, d_ff=22_016, vocab_size=102_400,
)

GEMMA3_1B = ArchSpec(
    name="gemma3-1b", family="dense", n_layers=26, d_model=1_152,
    n_heads=4, n_kv_heads=1, d_ff=6_912, vocab_size=262_144,
    head_dim=256, act="gelu", tie_embeddings=True,
    sliding_window=512, local_global_pattern=5, max_seq=1_048_576,
)

QWEN2_1_5B = ArchSpec(
    name="qwen2-1.5b", family="dense", n_layers=28, d_model=1_536,
    n_heads=12, n_kv_heads=2, d_ff=8_960, vocab_size=151_936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

PHI3_VISION_4_2B = ArchSpec(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3_072,
    n_heads=32, n_kv_heads=32, d_ff=8_192, vocab_size=32_064,
    frontend="embeddings",
)

MOONSHOT_16B_A3B = ArchSpec(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2_048,
    n_heads=16, n_kv_heads=16, d_ff=1_408, vocab_size=163_840,
    n_experts=64, top_k=6,
)

GRANITE_MOE_3B = ArchSpec(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1_536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49_155,
    n_experts=40, top_k=8,
)

MUSICGEN_MEDIUM = ArchSpec(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1_536,
    n_heads=24, n_kv_heads=24, d_ff=6_144, vocab_size=2_048,
    act="gelu", frontend="embeddings",
)

JAMBA_52B = ArchSpec(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4_096,
    n_heads=32, n_kv_heads=8, d_ff=14_336, vocab_size=65_536,
    n_experts=16, top_k=2, moe_every=2,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    attn_every=8, max_seq=1_048_576,
)

ASSIGNED: dict[str, ArchSpec] = {
    s.name: s
    for s in (
        MAMBA2_130M, YI_9B, DEEPSEEK_67B, GEMMA3_1B, QWEN2_1_5B,
        PHI3_VISION_4_2B, MOONSHOT_16B_A3B, GRANITE_MOE_3B,
        MUSICGEN_MEDIUM, JAMBA_52B,
    )
}

# ---------------------------------------------------------------------------
# The paper's own evaluation workloads (Table 2) — targets for the COSMIC
# Workload Trace Generator and the figure benchmarks.
# ---------------------------------------------------------------------------

GPT3_175B = ArchSpec(
    name="gpt3-175b", family="dense", n_layers=96, d_model=12_288,
    n_heads=96, n_kv_heads=96, d_ff=49_152, vocab_size=50_257,
    act="gelu", max_seq=2_048,
)

GPT3_13B = ArchSpec(
    name="gpt3-13b", family="dense", n_layers=40, d_model=5_140,
    n_heads=40, n_kv_heads=40, d_ff=20_560, vocab_size=50_257,
    act="gelu", max_seq=2_048,
)

VIT_BASE = ArchSpec(
    name="vit-base", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3_072, vocab_size=1_000,
    act="gelu", max_seq=256, frontend="embeddings",
)

VIT_LARGE = ArchSpec(
    name="vit-large", family="dense", n_layers=24, d_model=1_024,
    n_heads=16, n_kv_heads=16, d_ff=4_096, vocab_size=1_000,
    act="gelu", max_seq=256, frontend="embeddings",
)

PAPER_WORKLOADS: dict[str, ArchSpec] = {
    s.name: s for s in (GPT3_175B, GPT3_13B, VIT_BASE, VIT_LARGE)
}

ARCHS: dict[str, ArchSpec] = {**ASSIGNED, **PAPER_WORKLOADS}


def get_arch(name: str) -> ArchSpec:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None
