"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import MUSICGEN_MEDIUM as SPEC

__all__ = ["SPEC"]
