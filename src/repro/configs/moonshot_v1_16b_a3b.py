"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import MOONSHOT_16B_A3B as SPEC

__all__ = ["SPEC"]
