"""Architecture specifications.

``ArchSpec`` is the single source of truth shared by three consumers:

  * the JAX model zoo (``repro.models``) — builds real parameter pytrees,
  * the COSMIC Workload Trace Generator (``repro.core.workload``) — expands
    the symbolic operator templates of the paper,
  * the launcher (``repro.launch``) — input specs + sharding plans.

Each assigned architecture gets one module in ``repro/configs/`` exporting
``SPEC``.  ``repro.configs.registry`` maps ``--arch <id>`` to it.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Literal, Sequence

Family = Literal["dense", "ssm", "moe", "hybrid", "vlm", "audio"]
MixerKind = Literal["attn_full", "attn_local", "mamba"]
FFNKind = Literal["mlp", "moe", "none"]


@dataclass(frozen=True)
class LayerDef:
    """One decoder layer = a token mixer + an FFN."""

    mixer: MixerKind
    ffn: FFNKind


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"  # mlp activation: silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    max_seq: int = 32_768

    # -- attention pattern -----------------------------------------------
    sliding_window: int = 0        # >0 enables local attention layers
    local_global_pattern: int = 0  # N -> N local layers then 1 global layer

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # MoE FFN every k-th layer (jamba: 2)

    # -- Mamba2 / SSD -------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_every: int = 0            # hybrid: 1 attention layer per k layers

    # -- modality frontend ---------------------------------------------------
    # 'tokens' -> int32 token ids; 'embeddings' -> precomputed (B, S, D)
    # frame/patch embeddings supplied by the (stubbed) modality frontend.
    frontend: str = "tokens"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    # ------------------------------------------------------------------
    def layer_defs(self) -> list[LayerDef]:
        """Fully materialized per-layer plan (length == n_layers)."""
        out: list[LayerDef] = []
        for i in range(self.n_layers):
            # mixer
            if self.family == "ssm":
                mixer: MixerKind = "mamba"
            elif self.attn_every:  # hybrid: 1 attention per attn_every layers
                mixer = "attn_full" if (i % self.attn_every) == (self.attn_every // 2) else "mamba"
            elif self.local_global_pattern:
                p = self.local_global_pattern
                mixer = "attn_full" if (i % (p + 1)) == p else "attn_local"
            elif self.sliding_window:
                mixer = "attn_local"
            else:
                mixer = "attn_full"
            # ffn
            if self.family == "ssm":
                ffn: FFNKind = "none"
            elif self.n_experts and ((i % self.moe_every) == (self.moe_every - 1)):
                ffn = "moe"
            else:
                ffn = "mlp"
            out.append(LayerDef(mixer, ffn))
        return out

    def block_pattern(self) -> tuple[list[LayerDef], int, list[LayerDef]]:
        """(repeating pattern, n_repeats, remainder) for scan-over-blocks.

        The stack is executed as ``scan`` over ``n_repeats`` copies of
        ``pattern`` followed by the unscanned ``remainder`` layers.  This
        keeps the HLO size O(len(pattern)) instead of O(n_layers), which is
        what makes 512-device compiles tractable.
        """
        defs = self.layer_defs()
        # find the smallest repeating unit
        for plen in range(1, len(defs) + 1):
            reps = len(defs) // plen
            if reps >= 1 and defs[: plen * reps] == defs[:plen] * reps:
                # require the remainder (if any) to be a prefix of the pattern
                rem = defs[plen * reps:]
                if rem == defs[: len(rem)]:
                    return defs[:plen], reps, rem
        return defs, 1, []

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the realized model (embedding included)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        total += d  # final norm
        for ld in self.layer_defs():
            total += d  # pre-mixer norm
            if ld.mixer.startswith("attn"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:  # mamba2
                din, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
                zxbcdt = d * (2 * din + 2 * self.ssm_groups * ds + nh)
                conv = (din + 2 * self.ssm_groups * ds) * self.ssm_conv
                total += zxbcdt + conv + nh + nh + din * d  # +A_log +D +out_proj
                total += din  # gate norm
            if ld.ffn != "none":
                total += d  # pre-ffn norm
            if ld.ffn == "mlp":
                total += 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
            elif ld.ffn == "moe":
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        for ld in self.layer_defs():
            if ld.ffn == "moe":
                total -= (self.n_experts - self.top_k) * 3 * self.d_model * self.d_ff
        return total


@dataclass(frozen=True)
class ShapeSpec:
    """One (input shape × step kind) cell of the evaluation grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_GRID: tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES = {s.name: s for s in SHAPE_GRID}

# Archs for which the 500k-decode cell is runnable (sub-quadratic mixers).
LONG_CONTEXT_ARCHS = frozenset({"mamba2-130m", "jamba-v0.1-52b", "gemma3-1b"})


def cell_is_runnable(arch: "ArchSpec", shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return arch.name in LONG_CONTEXT_ARCHS
    return True


def reduced(spec: ArchSpec, **overrides) -> ArchSpec:
    """A tiny same-family config for CPU smoke tests."""
    pattern, _, rem = spec.block_pattern()
    n_small = max(len(pattern) * min(2, max(1, spec.n_layers // len(pattern))), 1)
    base = dict(
        n_layers=min(spec.n_layers, n_small + len(rem)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(spec.n_kv_heads, 4) if spec.n_kv_heads else 0,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        max_seq=128,
    )
    if spec.sliding_window:
        base["sliding_window"] = 16
    if spec.n_experts:
        base["n_experts"] = min(spec.n_experts, 4)
        base["top_k"] = min(spec.top_k, 2)
    if spec.ssm_state:
        base["ssm_state"] = 16
        base["ssm_head_dim"] = 16
    base.update(overrides)
    return dataclasses.replace(spec, name=spec.name + "-reduced", **base)
