"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import GEMMA3_1B as SPEC

__all__ = ["SPEC"]
