from repro.configs.base import (ArchSpec, LayerDef, ShapeSpec, SHAPES,
                                SHAPE_GRID, LONG_CONTEXT_ARCHS,
                                cell_is_runnable, reduced)
from repro.configs.registry import ARCHS, ASSIGNED, PAPER_WORKLOADS, get_arch
