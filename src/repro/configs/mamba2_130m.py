"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import MAMBA2_130M as SPEC

__all__ = ["SPEC"]
