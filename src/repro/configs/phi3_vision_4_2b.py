"""Config module for --arch; exact spec lives in registry."""
from repro.configs.registry import PHI3_VISION_4_2B as SPEC

__all__ = ["SPEC"]
