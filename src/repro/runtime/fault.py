"""Fault tolerance + straggler mitigation for the training loop.

Designed for thousands of nodes, exercised here with injected failures:

* ``Heartbeat``          — per-host liveness file; the coordinator treats a
                           stale heartbeat as node failure.
* ``StragglerMonitor``   — online mean/std of step times; a step slower than
                           mean + k*sigma is flagged; the mitigation hook
                           (e.g. shrink microbatch, skip host, re-shard) is
                           pluggable and its decisions are logged.
* ``run_with_restarts``  — crash-restart supervisor: runs the train loop,
                           restores from the latest checkpoint after a
                           failure, retries up to ``max_restarts``.  This is
                           the single-process analogue of a cluster
                           controller rescheduling a failed job.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable


class Heartbeat:
    def __init__(self, path: str | Path, host_id: int = 0):
        self.path = Path(path)
        self.host_id = host_id
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int):
        self.path.write_text(json.dumps({
            "host": self.host_id, "step": step, "time": time.time()}))

    def is_alive(self, timeout_s: float = 60.0) -> bool:
        if not self.path.exists():
            return False
        try:
            t = json.loads(self.path.read_text())["time"]
        except (json.JSONDecodeError, KeyError):
            return False
        return (time.time() - t) < timeout_s


@dataclass
class StragglerMonitor:
    """Welford-online step-time statistics with an outlier threshold."""

    k_sigma: float = 3.0
    min_samples: int = 8
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0
    events: list[dict] = field(default_factory=list)

    def observe(self, step: int, dt_s: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if self.n >= self.min_samples:
            std = math.sqrt(self.m2 / max(self.n - 1, 1))
            if dt_s > self.mean + self.k_sigma * max(std, 1e-9):
                is_straggler = True
                self.events.append({"step": step, "dt_s": dt_s,
                                    "mean_s": self.mean, "std_s": std})
        # update stats (stragglers excluded so one hiccup doesn't poison the
        # baseline)
        if not is_straggler:
            self.n += 1
            d = dt_s - self.mean
            self.mean += d / self.n
            self.m2 += d * (dt_s - self.mean)
        return is_straggler


@dataclass
class RestartReport:
    completed_steps: int
    restarts: int
    failures: list[str]


def run_with_restarts(make_loop: Callable[[int], int], *, target_step: int,
                      max_restarts: int = 3) -> RestartReport:
    """Supervise ``make_loop(start_step) -> reached_step`` until it reaches
    ``target_step``, restarting from the last checkpoint on failure.

    ``make_loop`` is expected to restore its own state from the checkpoint
    directory (the same path a real cluster controller would hand a
    rescheduled worker)."""
    restarts = 0
    failures: list[str] = []
    step = 0
    while step < target_step:
        try:
            step = make_loop(step)
        except Exception as e:  # noqa: BLE001 — injected/real failures
            failures.append(f"{type(e).__name__}: {e}")
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts; failures: {failures}") from e
    return RestartReport(step, restarts, failures)
