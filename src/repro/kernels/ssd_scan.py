"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch*heads, n_chunks) — chunks iterate sequentially carrying the
(head_dim, d_state) SSM state in VMEM scratch.  Per chunk, the intra-chunk
dual form is two MXU matmuls on (L x L) tiles plus the decay mask; the
inter-chunk recurrence is a rank-L update of the carried state.  This is
the TPU-native streaming of the SSD algorithm: O(L^2) tensors never leave
VMEM, HBM traffic is O(S * (hd + ds)) per head.

Validated in interpret mode against ``repro.kernels.ref.ssd_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)      # (L, hd)
    dt = dt_ref[0].astype(jnp.float32)    # (L,)
    a = a_ref[0].astype(jnp.float32)      # scalar decay rate for this head
    b = b_ref[0].astype(jnp.float32)      # (L, ds)
    c = c_ref[0].astype(jnp.float32)      # (L, ds)

    da = dt * a                           # (L,)
    da_cum = jnp.cumsum(da)               # (L,)
    l_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    m_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = da_cum[:, None] - da_cum[None, :]
    decay = jnp.where(l_idx >= m_idx, jnp.exp(seg), 0.0)  # (L, L)

    # intra-chunk dual form: (C B^T ∘ decay) @ (x * dt)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += C @ h_prev with in-chunk decay
    h = h_ref[...]                        # (ds, hd)
    y += jnp.exp(da_cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h = exp(sum da) * h + B^T (x * dt * decay_to_end)
    decay_end = jnp.exp(da_cum[-1] - da_cum)  # (L,)
    upd = jax.lax.dot_general(b, xdt * decay_end[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(da_cum[-1]) * h + upd

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool | None = None):
    """Fused SSD scan over one sequence.

    x:  (BH, S, hd)    — per-head inputs (heads folded into batch)
    dt: (BH, S)
    a:  (BH,)          — per-head decay rate (negative)
    b:  (BH, S, ds)    — already broadcast from groups to heads
    c:  (BH, S, ds)
    returns y: (BH, S, hd), final state (BH, ds, hd)
    """
    bh, s, hd = x.shape
    ds = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nc = s // chunk
    grid = (bh, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, chunk, ds), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, ds), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ds, hd), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), x.dtype),
            jax.ShapeDtypeStruct((bh, ds, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ds, hd), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, hlast
