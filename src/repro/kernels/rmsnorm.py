"""Pallas TPU fused RMSNorm: one HBM read, one write per row block.

Grid over row blocks; the full feature dim sits in VMEM per tile (d_model
up to ~12k in bf16 is ~24 KB/row — comfortably VMEM-resident at
block_rows=128), fp32 reduction on-chip, single fused scale-and-write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 128,
            interpret: bool | None = None):
    """x: (rows, d); w: (d,). Returns (rows, d) of x.dtype."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
