"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """Dense masked softmax attention.  q/k/v: (BH, S, hd)."""
    bh, s, hd = q.shape
    t = k.shape[1]
    scale = scale or 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bqk,btk->bqt", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    sc = jnp.where(mask[None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqt,btk->bqk", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, a, b, c):
    """Naive sequential SSM recurrence (the mathematical definition).

    x: (BH,S,hd); dt: (BH,S); a: (BH,); b,c: (BH,S,ds)
    h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t (outer) b_t ;  y_t = h_t c_t
    """
    bh, s, hd = x.shape
    ds = b.shape[-1]
    f32 = jnp.float32

    def per_seq(xs, dts, av, bs, cs):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt * av)
            h = decay * h + bt[:, None] * (dtt * xt)[None, :]
            y = jnp.einsum("nh,n->h", h, ct)
            return h, y

        h0 = jnp.zeros((ds, hd), f32)
        hl, ys = jax.lax.scan(step, h0, (xs.astype(f32), dts.astype(f32),
                                         bs.astype(f32), cs.astype(f32)))
        return ys, hl

    y, hlast = jax.vmap(per_seq)(x, dt, a, b, c)
    return y.astype(x.dtype), hlast


def rmsnorm_ref(x, w, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
