"""Pallas TPU flash attention: blockwise online-softmax, VMEM-resident tiles.

Grid: (batch*heads, q_blocks, kv_blocks) — the kv dim iterates sequentially
carrying (m, l, acc) in VMEM scratch; q/k/v tiles stream HBM->VMEM per
BlockSpec; block sizes default to 128x128 so the QK^T and PV contractions
land on MXU-aligned shapes.  Fully-masked tiles (beyond the causal diagonal
or outside the sliding window) are skipped.  Validated in interpret mode
against ``repro.kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    run = jnp.bool_(True)
    if causal:  # tile fully above the diagonal
        run &= k_start <= q_start + block_q - 1
    if window:  # tile fully left of the window
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)                  # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q, k, v: (BH, S, hd) with k/v already repeated to q heads.

    Returns (BH, S, hd).  Sequence lengths must be block multiples
    (ops.py pads).
    """
    bh, s, hd = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = scale or 1.0 / math.sqrt(hd)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (bh, s // block_q, t // block_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=t,
        causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
