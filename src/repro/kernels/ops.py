"""jit'd public wrappers around the Pallas kernels.

These adapt model-layout tensors (B, S, H, hd / GQA groups) to kernel
layouts (heads folded into batch, padded to block multiples) and expose a
``use_pallas`` switch: models default to the pure-jnp path (the dry-run
compiles on the CPU backend where TPU-Pallas cannot lower); on TPU the
kernels drop in via these wrappers.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def mha_flash(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128,
              interpret: bool | None = None):
    """q: (B, S, H, hd); k/v: (B, T, G, hd) (GQA groups).  Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, g = k.shape[1], k.shape[2]
    rep = h // g
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    o = flash_attention(qf, kf, vf, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, interpret=interpret)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, *, chunk: int = 128, interpret: bool | None = None):
    """Model layout: x (B,S,H,hd); dt (B,S,H); a (H,); b/c (B,S,G,ds)."""
    bsz, s, h, hd = x.shape
    g, ds = b.shape[2], b.shape[3]
    rep = h // g
    if rep > 1:
        b = jnp.repeat(b, rep, axis=2)
        c = jnp.repeat(c, rep, axis=2)
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, hd)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    af = jnp.broadcast_to(a[None, :], (bsz, h)).reshape(bsz * h)
    bf = b.transpose(0, 2, 1, 3).reshape(bsz * h, s, ds)
    cf = c.transpose(0, 2, 1, 3).reshape(bsz * h, s, ds)
    y, hl = ssd_scan(xf, dtf, af, bf, cf, chunk=chunk, interpret=interpret)
    y = y.reshape(bsz, h, s, hd).transpose(0, 2, 1, 3)
    hl = hl.reshape(bsz, h, ds, hd).transpose(0, 1, 3, 2)  # (B,H,hd,ds)
    return y, hl


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_rmsnorm(x, w, *, eps: float = 1e-5, interpret: bool | None = None):
    """x: (..., d) any leading shape."""
    shape = x.shape
    rows = math.prod(shape[:-1])
    d = shape[-1]
    block = 128
    while rows % block and block > 1:
        block //= 2
    out = rmsnorm_kernel(x.reshape(rows, d), w, eps=eps, block_rows=block,
                         interpret=interpret)
    return out.reshape(shape)
