"""Scenario layer: TrainScenario bit-identity with the pre-scenario engine,
disaggregated-serving degeneracy and multi-pool simulation, request-stream
serving with queueing, multi-tenant partition safety, the PR-3 modeling
fixes (per-physical-dim collective algorithms, PP remainder layers), and
batched/process-pool evaluation per scenario type."""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.rewards import evaluate
from repro.core.scenario import (DisaggServeScenario, MultiTenantScenario,
                                 RequestStreamScenario, Scenario, Tenant,
                                 TrainScenario, scenario_psa)
from repro.core.simulator import SystemConfig, simulate
from repro.core.space import DesignSpace
from repro.core.topology import partition_cluster, sub_network, system_2
from repro.core.workload import (Op, Parallelism, Trace, TraceBuilder,
                                 compose_phases, generate_trace)

SPEC = ARCHS["gpt3-13b"]


def _env(scenario=None, **kw):
    kw.setdefault("batch", 1024)
    kw.setdefault("seq", 2048)
    return CosmicEnv(spec=SPEC, n_npus=1024, device=SYSTEM_2_DEVICE,
                     scenario=scenario, **kw)


def _disagg_scenario(**kw):
    kw.setdefault("batch", 64)
    kw.setdefault("seq", 2048)
    return DisaggServeScenario(**kw)


def _tenants():
    return (Tenant("train-13b", SPEC, 512, 2048, "train", slo_ms=5e5,
                   weight=2.0),
            Tenant("serve-1.5b", ARCHS["qwen2-1.5b"], 64, 2048, "serve",
                   slo_ms=5e4, device_name="system3-h100"))


def _sample_configs(pset, n, seed=0):
    space = DesignSpace(pset)
    rng = np.random.default_rng(seed)
    return [space.sample(rng) for _ in range(n)]


# ---------------------------------------------------------------------------
# (a) TrainScenario == pre-refactor engine, bit for bit
# ---------------------------------------------------------------------------

def _pre_refactor_evaluate(env: CosmicEnv, config: dict):
    """The seed repo's CosmicEnv.evaluate_config, verbatim: build the
    parallelization + network + system stacks and call rewards.evaluate."""
    from repro.core.topology import build_network

    par = Parallelism(env.n_npus, config["dp"], config["sp"], config["pp"],
                      bool(config["weight_sharded"]))
    net = build_network(config["topology"], config["npus_per_dim"],
                        config["bw_per_dim"])
    sys_cfg = SystemConfig(network=net, device=env.device,
                           coll_algo=tuple(config["coll_algo"]),
                           chunks=int(config["chunks"]),
                           sched_policy=config["sched_policy"],
                           multidim_coll=config["multidim_coll"])
    return evaluate(env.spec, par, sys_cfg, batch=env.batch, seq=env.seq,
                    mode=env.mode, objective=env.objective,
                    capacity_gb=env.capacity_gb)


# rewards/latencies recorded by running THIS sweep (gpt3-13b, system2,
# paper_psa(1024), rng seed 7) on the pre-scenario engine at commit 9d735d8
# (PR 1) — golden values, independent of the current code.  Entry 7 was
# re-pinned after the PR-3 collective-algorithm attribution fix: its config
# puts DP on outer network dims whose per-dim algorithms the pre-fix
# simulator mis-resolved from position 0 (was 3.057855450484146e-08 /
# 22553.557703103957); the other seven are bit-identical to PR 1.
_PR1_GOLDEN = [
    (5.606140838198029e-08, 16215.985047354485, True),
    (4.152428749523412e-08, 16608.477656128038, True),
    (0.0, float("inf"), False),
    (0.0, float("inf"), False),
    (7.517698102017199e-08, 20464.530940735993, True),
    (0.0, float("inf"), False),
    (0.0, float("inf"), False),
    (3.057920050568171e-08, 22553.081247979546, True),
]


def test_train_scenario_bit_identical_to_pre_refactor(clear_dse_caches):
    env = _env()
    assert isinstance(env.scenario, TrainScenario)  # legacy ctor still works
    for i, cfg in enumerate(_sample_configs(paper_psa(1024), 25, seed=7)):
        got = env.step(cfg)
        if i < len(_PR1_GOLDEN):
            assert (got.reward, got.latency_ms, got.valid) == _PR1_GOLDEN[i]
        want = _pre_refactor_evaluate(env, cfg)
        assert (got.reward, got.latency_ms, got.valid) == \
            (want.reward, want.latency_ms, want.valid)


def test_decode_tokens_threads_through_serve_path(clear_dse_caches):
    cfgs = _sample_configs(paper_psa(1024), 12, seed=3)
    short = _env(mode="serve", batch=64, decode_tokens=8)
    long = _env(mode="serve", batch=64, decode_tokens=256)
    pairs = [(short.step(c), long.step(c)) for c in cfgs]
    valid = [(a, b) for a, b in pairs if a.valid]
    assert valid, "no valid serve configs sampled"
    for a, b in valid:
        assert b.latency_ms > a.latency_ms
        dec = a.detail["decode_ms"]
        assert b.latency_ms - a.latency_ms == pytest.approx(248 * dec)


# ---------------------------------------------------------------------------
# (b) DisaggServeScenario: monolithic degeneracy + multi-pool simulation
# ---------------------------------------------------------------------------

def test_disagg_full_pool_degenerates_to_monolithic(clear_dse_caches):
    sc = _disagg_scenario()
    mono = TrainScenario(sc.batch, sc.seq, "serve", sc.decode_tokens)
    env_d, env_m = _env(sc), _env(mono)
    found = 0
    for cfg in _sample_configs(scenario_psa(paper_psa(1024), sc, 1024), 20,
                               seed=1):
        cfg = dict(cfg, prefill_frac=1.0)
        a = env_d.evaluate_config(cfg)
        b = env_m.evaluate_config(cfg)
        assert (a.reward, a.latency_ms, a.valid) == \
            (b.reward, b.latency_ms, b.valid)
        found += a.valid
    assert found, "no valid monolithic configs sampled"


def test_disagg_pools_are_simulated_separately(clear_dse_caches):
    sc = _disagg_scenario()
    env = _env(sc)
    for cfg in _sample_configs(scenario_psa(paper_psa(1024), sc, 1024), 30,
                               seed=2):
        cfg = dict(cfg, prefill_frac=0.5)
        ev = env.evaluate_config(cfg)
        if not ev.valid:
            continue
        assert ev.detail["prefill_npus"] == 512
        assert ev.detail["decode_npus"] <= 512
        assert ev.detail["p50_token_latency_ms"] > 0
        traces = sc.traces(env.context(cfg))
        combined = traces["combined"]
        assert {op.pool for op in combined.ops} == {0, 1}
        assert any(op.group == "xfer" for op in combined.ops)
        return
    pytest.fail("no valid disagg config sampled")


def test_multi_pool_simulator_xfer_and_streams(clear_dse_caches):
    par_a = Parallelism(512, dp=8, sp=1, pp=1)
    par_b = Parallelism(512, dp=4, sp=1, pp=1)
    pre = generate_trace(SPEC, par_a, batch=64, seq=2048, mode="prefill")
    dec = generate_trace(SPEC, par_b, batch=64, seq=2048, mode="decode")
    tr = compose_phases([(pre, 0), (dec, 1)], transfers=[1e9])
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=("ring",) * 4, chunks=2)
    res = simulate(tr, cfg, par_a, pools={0: par_a, 1: par_b})
    assert set(res.pool_compute_us) == {0, 1}
    assert all(v > 0 for v in res.pool_compute_us.values())
    assert res.comm_busy_us.get("xfer", 0) > 0
    # the phases are dependency-chained: the makespan covers both pools
    assert res.makespan_us >= max(res.pool_compute_us.values())
    # per-op recording is opt-in
    assert res.per_op_us == {}
    rec = simulate(tr, cfg, par_a, pools={0: par_a, 1: par_b},
                   record_per_op=True)
    assert len(rec.per_op_us) == len(tr.ops)


def test_decode_latency_does_not_get_free_pp_speedup(clear_dse_caches):
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=("ring",) * 4, chunks=2)
    lat = {}
    for pp in (1, 4):
        par = Parallelism(1024, dp=16, sp=1, pp=pp)
        tr = generate_trace(SPEC, par, batch=64, seq=2048, mode="decode")
        lat[pp] = simulate(tr, cfg, par).latency_ms
    # the token still traverses every layer, plus cross-stage hops
    assert lat[4] >= lat[1]


# ---------------------------------------------------------------------------
# (c) PR-3 modeling fixes: per-physical-dim collective algorithms, PP
#     remainder layers, simulator repeat/delay op semantics
# ---------------------------------------------------------------------------

# system2 under tp=4, dp=64: TP occupies physical dim 0, DP carves dims
# 1-3 — the regression config for the per-dim algorithm attribution fix
_ALGO_PAR = Parallelism(1024, dp=64, sp=1, pp=1)


def _dp_only_trace() -> Trace:
    tb = TraceBuilder()
    u = tb.comp("x", 1e9, 1e6, [])
    tb.coll("dp.ar", "all_reduce", 1e9, "dp", [u])
    return Trace(tb.ops)


def _algo_makespan(coll_algo: tuple) -> float:
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=coll_algo, chunks=2)
    return simulate(_dp_only_trace(), cfg, _ALGO_PAR).makespan_us


def test_coll_algo_follows_physical_dims(clear_dse_caches):
    """Pinned regression for the `_group_net` attribution fix: a DP
    collective riding physical dims 1-3 must be priced with THOSE dims'
    algorithms.  Pre-fix, `coll_algo[:3]` was sliced from position 0, so
    the outermost slot was dead for DP and the TP dim's slot leaked in —
    exactly the opposite of both assertions below."""
    base = _algo_makespan(("ring", "ring", "ring", "ring"))
    outer = _algo_makespan(("ring", "ring", "ring", "dbt"))
    inner = _algo_makespan(("dbt", "ring", "ring", "ring"))
    # changing only the outermost (DP-occupied) dim's algorithm moves time
    assert outer != base
    # changing only the TP dim's algorithm leaves the DP collective alone
    assert inner == base
    # pinned post-fix values (SYSTEM_2_DEVICE, system_2 fabric)
    assert base == pytest.approx(9420.035714285714, rel=1e-9)
    assert outer == pytest.approx(9416.035714285714, rel=1e-9)


def test_pp_stage_models_remainder_layers(clear_dse_caches):
    """34 layers @ pp=4 must model a 9-layer (ceil) stage, not 8 (floor):
    the largest stage's compute, so PP never under-counts FLOPs."""
    spec = dataclasses.replace(SPEC, n_layers=34)
    # same (dp, sp, tp=16) in both, so per-layer op costs are identical and
    # only the stage slicing differs
    par4 = Parallelism(1024, dp=16, sp=1, pp=4)
    par1 = Parallelism(256, dp=16, sp=1, pp=1)
    tr4 = generate_trace(spec, par4, batch=64, seq=2048, mode="train")
    tr1 = generate_trace(spec, par1, batch=64, seq=2048, mode="train")
    n_stage = sum(op.name.endswith(".mixer.fwd") for op in tr4.ops)
    assert n_stage == math.ceil(34 / 4) == 9
    # de-bubbled stage compute x pp covers every layer (34 identical
    # layers: 9 * 4 = 36 modeled layer-slots >= 34, never fewer)
    f4 = sum(op.flops for op in tr4.ops
             if op.name.endswith(".mixer.fwd")) / tr4.meta["bubble"]
    f1 = sum(op.flops for op in tr1.ops if op.name.endswith(".mixer.fwd"))
    assert f4 * 4 >= f1
    assert f4 * 4 == pytest.approx(f1 * 36 / 34)


def test_simulator_repeat_and_delay_ops(clear_dse_caches):
    """`repeat` condenses k back-to-back executions into one op (k x the
    single duration); `delay` ops shift their dependents' start without
    occupying compute or comm resources."""
    one = Trace([Op(0, "c", "comp", [], flops=1e12, bytes=1e9)])
    rep = Trace([Op(0, "c", "comp", [], flops=1e12, bytes=1e9, repeat=5)])
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=("ring",) * 4, chunks=2)
    par = Parallelism(1024, dp=64, sp=1, pp=1)
    t1 = simulate(one, cfg, par).makespan_us
    t5 = simulate(rep, cfg, par).makespan_us
    assert t5 == pytest.approx(5 * t1)

    delayed = Trace([Op(0, "rel", "delay", [], delay_us=1234.5),
                     Op(1, "c", "comp", [0], flops=1e12, bytes=1e9)])
    res = simulate(delayed, cfg, par, record_per_op=True)
    assert res.makespan_us == pytest.approx(1234.5 + t1)
    assert res.op_finish_us[0] == pytest.approx(1234.5)
    assert res.comm_busy_us == {}          # the timer is not communication
    assert res.compute_busy_us == pytest.approx(t1)


# ---------------------------------------------------------------------------
# (d) RequestStreamScenario: queueing, pipelined multi-wave traces,
#     streaming rewards
# ---------------------------------------------------------------------------

def _stream_scenario(**kw):
    kw.setdefault("n_requests", 16)
    kw.setdefault("seq", 2048)
    kw.setdefault("decode_tokens", 8)
    kw.setdefault("rate_rps", 16.0)
    kw.setdefault("max_batch", 8)
    return RequestStreamScenario(**kw)


# a known-valid design point on system2's stacks (prefill pool 896 NPUs)
_STREAM_CFG = dict(dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
                   coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
                   multidim_coll="baseline",
                   topology=("ring", "fc", "ring", "switch"),
                   npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100),
                   prefill_frac=0.875, decode_batch=4,
                   batch_window_ms=200.0, max_inflight=2)


def test_request_stream_wave_formation_golden():
    """Deterministic queueing golden: replayed 10ms inter-arrival gaps,
    max_batch=3 — waves close on fill or on window expiry."""
    sc = RequestStreamScenario(n_requests=6, arrival_gaps_ms=(10.0,),
                               max_batch=3)
    assert sc.arrivals_ms() == (10.0, 20.0, 30.0, 40.0, 50.0, 60.0)
    # wide window: waves fill to max_batch and release at the filling arrival
    assert sc.form_waves(100.0) == (((0, 1, 2), 30.0), ((3, 4, 5), 60.0))
    # 15ms window: pairs release at open+15
    assert sc.form_waves(15.0) == (((0, 1), 25.0), ((2, 3), 45.0),
                                   ((4, 5), 65.0))
    # no batching window: every request is its own wave, released on arrival
    assert sc.form_waves(0.0) == tuple(
        ((i,), 10.0 * (i + 1)) for i in range(6))


def test_request_stream_deterministic(clear_dse_caches):
    """Same scenario fields + config -> bit-identical Evaluation across
    fresh scenario/env instances (the Poisson arrivals are seeded)."""
    a = _env(_stream_scenario(), objective="goodput").evaluate_config(_STREAM_CFG)
    b = _env(_stream_scenario(), objective="goodput").evaluate_config(_STREAM_CFG)
    assert a.valid and b.valid
    assert (a.reward, a.latency_ms) == (b.reward, b.latency_ms)
    assert a.detail == b.detail


def test_request_stream_trace_is_pipelined_multiwave(clear_dse_caches):
    sc = _stream_scenario()
    env = _env(sc, objective="goodput")
    tr = sc.traces(env.context(_STREAM_CFG))["stream"]
    marks = tr.meta["wave_marks"]
    assert len(marks) >= 2                      # an actual request stream
    assert {op.pool for op in tr.ops} == {0, 1}  # both pools populated
    # one release delay and one KV xfer per admitted wave
    assert sum(op.kind == "delay" for op in tr.ops) == len(marks)
    assert sum(op.group == "xfer" for op in tr.ops) == len(marks)
    ev = env.evaluate_config(_STREAM_CFG)
    assert ev.valid
    d = ev.detail
    assert d["waves"] == len(marks)
    assert sum(d["wave_sizes"]) == sc.n_requests
    assert 0 < d["ttft_p50_ms"] <= d["ttft_p99_ms"]
    assert 0 < d["tpot_p50_ms"] <= d["tpot_p99_ms"]
    assert d["latency_p99_ms"] >= d["ttft_p99_ms"]


def test_request_stream_slo_gates_goodput(clear_dse_caches):
    """Goodput counts only requests meeting BOTH SLOs; impossible SLOs
    zero it while the latency percentiles are unchanged."""
    loose = _env(_stream_scenario(), objective="goodput") \
        .evaluate_config(_STREAM_CFG)
    tight = _env(_stream_scenario(ttft_slo_ms=1e-3, tpot_slo_ms=1e-3),
                 objective="goodput").evaluate_config(_STREAM_CFG)
    assert loose.valid and tight.valid
    assert loose.detail["goodput_rps"] > 0 and loose.reward > 0
    assert tight.detail["goodput_rps"] == 0 and tight.reward == 0
    assert tight.detail["ttft_p99_ms"] == loose.detail["ttft_p99_ms"]


def test_request_stream_batching_window_trades_ttft(clear_dse_caches):
    """A wider admission window queues requests longer: p50 TTFT must not
    shrink when the only change is a bigger batch_window_ms."""
    env = _env(_stream_scenario(), objective="goodput")
    narrow = env.evaluate_config(dict(_STREAM_CFG, batch_window_ms=0.0))
    wide = env.evaluate_config(dict(_STREAM_CFG, batch_window_ms=1000.0))
    assert narrow.valid and wide.valid
    assert wide.detail["waves"] <= narrow.detail["waves"]
    assert wide.detail["ttft_p50_ms"] >= narrow.detail["ttft_p50_ms"]


def test_request_stream_waves_respect_decode_capacity(clear_dse_caches):
    """An admitted wave never exceeds the decode pool's resident capacity
    (replicas * decode_batch), even when the scenario's max_batch is
    larger — otherwise the simulated decode would hold more requests than
    the memory gate checked."""
    sc = RequestStreamScenario(n_requests=48, seq=2048, decode_tokens=4,
                               rate_rps=1000.0, max_batch=32)
    env = CosmicEnv(spec=ARCHS["qwen2-1.5b"], n_npus=64,
                    device=SYSTEM_2_DEVICE, scenario=sc, objective="goodput")
    # n_dec = 8, decode_batch=2 -> replicas=8 (tp=1), capacity 16 < 32
    cfg = dict(_STREAM_CFG, dp=2, decode_batch=2, batch_window_ms=1000.0,
               max_inflight=2)
    ev = env.evaluate_config(cfg)
    assert ev.valid
    assert ev.detail["decode_replicas"] * 2 == 16
    assert max(ev.detail["wave_sizes"]) <= 16
    assert sum(ev.detail["wave_sizes"]) == sc.n_requests


def test_goodput_objective_requires_streaming_scenario():
    """Construction-time gate: streaming objectives need a scenario that
    resolves per-request metrics — not a KeyError deep inside a search."""
    _env(_stream_scenario(), objective="goodput")  # fine
    with pytest.raises(ValueError, match="streaming"):
        _env(TrainScenario(64, 2048, "serve"), objective="goodput")
    with pytest.raises(ValueError, match="streaming"):
        _env(objective="goodput")  # legacy batch/seq TrainScenario path
    with pytest.raises(ValueError, match="unknown objective"):
        _env(objective="not-an-objective")


def test_pipelined_multiwave_beats_analytic_composition(clear_dse_caches):
    """The acceptance point: on a multi-wave load the pipelined multi-wave
    disagg trace (wave k+1 prefill overlapping wave k decode) must beat
    the analytic single-wave composition."""
    spec = ARCHS["qwen2-1.5b"]
    cfg = dict(_STREAM_CFG, decode_batch=2)
    for k in ("batch_window_ms", "max_inflight"):
        cfg.pop(k)
    evs = {}
    for pipelined in (True, False):
        sc = DisaggServeScenario(512, 2048, 64, pipelined=pipelined)
        env = CosmicEnv(spec=spec, n_npus=1024, device=SYSTEM_2_DEVICE,
                        scenario=sc, objective="latency")
        evs[pipelined] = env.evaluate_config(cfg)
    assert evs[True].valid and evs[False].valid
    assert evs[True].detail["waves"] >= 2
    assert evs[True].latency_ms < evs[False].latency_ms


# ---------------------------------------------------------------------------
# (e) MultiTenantScenario: disjoint partitions, invalid gates to 0
# ---------------------------------------------------------------------------

def test_multi_tenant_partitions_disjoint_and_gated(clear_dse_caches):
    sc = MultiTenantScenario(tenants=_tenants())
    env = _env(sc)
    pset = scenario_psa(paper_psa(1024), sc, 1024)
    n_valid = 0
    for cfg in _sample_configs(pset, 15, seed=5):
        ev = env.evaluate_config(cfg)
        assert sum(cfg["tenant_npus"]) <= 1024  # sampler respects sum_le
        if not ev.valid:
            assert ev.reward == 0.0
            continue
        n_valid += 1
        ranges = [tuple(t["range"]) for t in ev.detail["tenants"].values()]
        for i, (lo_i, hi_i) in enumerate(ranges):
            for lo_j, hi_j in ranges[i + 1:]:
                assert hi_i <= lo_j or hi_j <= lo_i, \
                    f"partitions share NPUs: {ranges}"
        assert 0.0 <= ev.reward <= 1.0
    assert n_valid, "no valid multi-tenant configs sampled"
    # oversubscription gates to reward 0 even if a repaired config slips past
    base = _sample_configs(pset, 1, seed=6)[0]
    over = dict(base, tenant_npus=(1024, 1024))
    ev = env.evaluate_config(over)
    assert not ev.valid and ev.reward == 0.0


def test_partition_cluster_heterogeneous_devices():
    from repro.core.compute import SYSTEM_3_DEVICE

    net = system_2()
    cluster = partition_cluster(net, (512, 256),
                                (SYSTEM_2_DEVICE, SYSTEM_3_DEVICE))
    a, b = cluster.partitions
    assert a.npu_range() == (0, 512) and b.npu_range() == (512, 768)
    assert b.device.name == "system3-h100"
    assert sub_network(net, 512).n_npus == 512
    with pytest.raises(ValueError):
        partition_cluster(net, (1024, 512), (SYSTEM_2_DEVICE,) * 2)


# ---------------------------------------------------------------------------
# (f) step_batch + process pool works with every scenario type
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_scenario", [
    lambda: TrainScenario(1024, 2048),
    lambda: _disagg_scenario(),
    lambda: _stream_scenario(),
    lambda: MultiTenantScenario(tenants=_tenants()),
], ids=["train", "disagg", "request-stream", "multi-tenant"])
def test_step_batch_and_pool_per_scenario(make_scenario, clear_dse_caches):
    sc = make_scenario()
    assert isinstance(sc, Scenario)  # structural protocol check
    pset = scenario_psa(paper_psa(1024), sc, 1024)
    cfgs = _sample_configs(pset, 6, seed=11)
    serial_env = _env(make_scenario())
    serial = [serial_env.step(c) for c in cfgs]
    with _env(make_scenario()) as pool_env:
        pooled = pool_env.step_batch(cfgs, workers=2)
    for a, b in zip(pooled, serial):
        assert (a.reward, a.latency_ms, a.valid) == \
            (b.reward, b.latency_ms, b.valid)
    assert [r.config for r in pool_env.history] == cfgs


def test_sum_le_repair_respects_fixed_slots():
    from repro.core.psa import Constraint, Parameter, ParameterSet

    pset = ParameterSet(
        [Parameter("a", "scenario", (128, 256, 512, 1024)),
         Parameter("b", "scenario", (128, 256, 512, 1024))],
        [Constraint("sum_le", ("a", "b"), 1024)],
        fixed={"a": 768})
    space = DesignSpace(pset)
    rng = np.random.default_rng(0)
    for _ in range(10):
        cfg = space.sample(rng)
        assert cfg["a"] == 768 and cfg["a"] + cfg["b"] <= 1024


# ---------------------------------------------------------------------------
# shared cross-search eval store
# ---------------------------------------------------------------------------

def test_shared_eval_store_dedupes_across_envs(clear_dse_caches):
    store: dict = {}
    cfgs = _sample_configs(paper_psa(1024), 5, seed=13)
    env_a = _env(eval_store=store)
    first = env_a.step_batch(cfgs)
    assert env_a.store_misses == len(store) > 0
    env_b = _env(eval_store=store)
    second = env_b.step_batch(cfgs)
    assert env_b.store_misses == 0
    assert env_b.store_hits == len({tuple(sorted(c.items())) for c in cfgs})
    for a, b in zip(first, second):
        assert a is b  # the stored Evaluation instance is shared
    # a different env signature must not collide in the same store
    env_c = _env(eval_store=store, batch=512)
    env_c.step(cfgs[0])
    assert env_c.store_hits == 0 and env_c.store_misses == 1


# ---------------------------------------------------------------------------
# scenario registry rejects unknown / typo'd parameter keys
# ---------------------------------------------------------------------------

def test_build_scenario_rejects_unknown_params():
    from repro.core.scenario import build_scenario

    with pytest.raises(ValueError) as ei:
        build_scenario("request-stream", {"n_requests": 8, "rate_rsp": 9.0})
    # the error names the typo AND the valid keys
    assert "rate_rsp" in str(ei.value) and "rate_rps" in str(ei.value)


def test_build_multi_tenant_rejects_unknown_tenant_keys():
    from repro.core.scenario import build_scenario

    with pytest.raises(ValueError) as ei:
        build_scenario("multi-tenant", {"tenants": [
            {"name": "t0", "arch": "qwen2-1.5b", "batch": 64, "seq": 512,
             "slo": 100.0}]})       # typo: the field is slo_ms
    msg = str(ei.value)
    assert "'slo'" in msg and "slo_ms" in msg and "t0" in msg


def test_build_multi_tenant_still_accepts_known_keys():
    from repro.core.scenario import build_scenario

    sc = build_scenario("multi-tenant", {"tenants": [
        {"name": "t0", "arch": "qwen2-1.5b", "batch": 64, "seq": 512,
         "phase": "serve", "slo_ms": 100.0, "decode_tokens": 8}]})
    assert sc.tenants[0].slo_ms == 100.0
