"""Scenario layer: TrainScenario bit-identity with the pre-scenario engine,
disaggregated-serving degeneracy and multi-pool simulation, multi-tenant
partition safety, and batched/process-pool evaluation per scenario type."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.rewards import evaluate
from repro.core.scenario import (DisaggServeScenario, MultiTenantScenario,
                                 Scenario, Tenant, TrainScenario,
                                 scenario_psa)
from repro.core.simulator import SystemConfig, simulate
from repro.core.space import DesignSpace
from repro.core.topology import partition_cluster, sub_network, system_2
from repro.core.workload import Parallelism, compose_phases, generate_trace

SPEC = ARCHS["gpt3-13b"]


def _env(scenario=None, **kw):
    kw.setdefault("batch", 1024)
    kw.setdefault("seq", 2048)
    return CosmicEnv(spec=SPEC, n_npus=1024, device=SYSTEM_2_DEVICE,
                     scenario=scenario, **kw)


def _disagg_scenario(**kw):
    kw.setdefault("batch", 64)
    kw.setdefault("seq", 2048)
    return DisaggServeScenario(**kw)


def _tenants():
    return (Tenant("train-13b", SPEC, 512, 2048, "train", slo_ms=5e5,
                   weight=2.0),
            Tenant("serve-1.5b", ARCHS["qwen2-1.5b"], 64, 2048, "serve",
                   slo_ms=5e4, device_name="system3-h100"))


def _sample_configs(pset, n, seed=0):
    space = DesignSpace(pset)
    rng = np.random.default_rng(seed)
    return [space.sample(rng) for _ in range(n)]


# ---------------------------------------------------------------------------
# (a) TrainScenario == pre-refactor engine, bit for bit
# ---------------------------------------------------------------------------

def _pre_refactor_evaluate(env: CosmicEnv, config: dict):
    """The seed repo's CosmicEnv.evaluate_config, verbatim: build the
    parallelization + network + system stacks and call rewards.evaluate."""
    from repro.core.topology import build_network

    par = Parallelism(env.n_npus, config["dp"], config["sp"], config["pp"],
                      bool(config["weight_sharded"]))
    net = build_network(config["topology"], config["npus_per_dim"],
                        config["bw_per_dim"])
    sys_cfg = SystemConfig(network=net, device=env.device,
                           coll_algo=tuple(config["coll_algo"]),
                           chunks=int(config["chunks"]),
                           sched_policy=config["sched_policy"],
                           multidim_coll=config["multidim_coll"])
    return evaluate(env.spec, par, sys_cfg, batch=env.batch, seq=env.seq,
                    mode=env.mode, objective=env.objective,
                    capacity_gb=env.capacity_gb)


# rewards/latencies recorded by running THIS sweep (gpt3-13b, system2,
# paper_psa(1024), rng seed 7) on the pre-scenario engine at commit 9d735d8
# (PR 1) — golden values, independent of the current code
_PR1_GOLDEN = [
    (5.606140838198029e-08, 16215.985047354485, True),
    (4.152428749523412e-08, 16608.477656128038, True),
    (0.0, float("inf"), False),
    (0.0, float("inf"), False),
    (7.517698102017199e-08, 20464.530940735993, True),
    (0.0, float("inf"), False),
    (0.0, float("inf"), False),
    (3.057855450484146e-08, 22553.557703103957, True),
]


def test_train_scenario_bit_identical_to_pre_refactor(clear_dse_caches):
    env = _env()
    assert isinstance(env.scenario, TrainScenario)  # legacy ctor still works
    for i, cfg in enumerate(_sample_configs(paper_psa(1024), 25, seed=7)):
        got = env.step(cfg)
        if i < len(_PR1_GOLDEN):
            assert (got.reward, got.latency_ms, got.valid) == _PR1_GOLDEN[i]
        want = _pre_refactor_evaluate(env, cfg)
        assert (got.reward, got.latency_ms, got.valid) == \
            (want.reward, want.latency_ms, want.valid)


def test_decode_tokens_threads_through_serve_path(clear_dse_caches):
    cfgs = _sample_configs(paper_psa(1024), 12, seed=3)
    short = _env(mode="serve", batch=64, decode_tokens=8)
    long = _env(mode="serve", batch=64, decode_tokens=256)
    pairs = [(short.step(c), long.step(c)) for c in cfgs]
    valid = [(a, b) for a, b in pairs if a.valid]
    assert valid, "no valid serve configs sampled"
    for a, b in valid:
        assert b.latency_ms > a.latency_ms
        dec = a.detail["decode_ms"]
        assert b.latency_ms - a.latency_ms == pytest.approx(248 * dec)


# ---------------------------------------------------------------------------
# (b) DisaggServeScenario: monolithic degeneracy + multi-pool simulation
# ---------------------------------------------------------------------------

def test_disagg_full_pool_degenerates_to_monolithic(clear_dse_caches):
    sc = _disagg_scenario()
    mono = TrainScenario(sc.batch, sc.seq, "serve", sc.decode_tokens)
    env_d, env_m = _env(sc), _env(mono)
    found = 0
    for cfg in _sample_configs(scenario_psa(paper_psa(1024), sc, 1024), 20,
                               seed=1):
        cfg = dict(cfg, prefill_frac=1.0)
        a = env_d.evaluate_config(cfg)
        b = env_m.evaluate_config(cfg)
        assert (a.reward, a.latency_ms, a.valid) == \
            (b.reward, b.latency_ms, b.valid)
        found += a.valid
    assert found, "no valid monolithic configs sampled"


def test_disagg_pools_are_simulated_separately(clear_dse_caches):
    sc = _disagg_scenario()
    env = _env(sc)
    for cfg in _sample_configs(scenario_psa(paper_psa(1024), sc, 1024), 30,
                               seed=2):
        cfg = dict(cfg, prefill_frac=0.5)
        ev = env.evaluate_config(cfg)
        if not ev.valid:
            continue
        assert ev.detail["prefill_npus"] == 512
        assert ev.detail["decode_npus"] <= 512
        assert ev.detail["p50_token_latency_ms"] > 0
        traces = sc.traces(env.context(cfg))
        combined = traces["combined"]
        assert {op.pool for op in combined.ops} == {0, 1}
        assert any(op.group == "xfer" for op in combined.ops)
        return
    pytest.fail("no valid disagg config sampled")


def test_multi_pool_simulator_xfer_and_streams(clear_dse_caches):
    par_a = Parallelism(512, dp=8, sp=1, pp=1)
    par_b = Parallelism(512, dp=4, sp=1, pp=1)
    pre = generate_trace(SPEC, par_a, batch=64, seq=2048, mode="prefill")
    dec = generate_trace(SPEC, par_b, batch=64, seq=2048, mode="decode")
    tr = compose_phases([(pre, 0), (dec, 1)], transfers=[1e9])
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=("ring",) * 4, chunks=2)
    res = simulate(tr, cfg, par_a, pools={0: par_a, 1: par_b})
    assert set(res.pool_compute_us) == {0, 1}
    assert all(v > 0 for v in res.pool_compute_us.values())
    assert res.comm_busy_us.get("xfer", 0) > 0
    # the phases are dependency-chained: the makespan covers both pools
    assert res.makespan_us >= max(res.pool_compute_us.values())
    # per-op recording is opt-in
    assert res.per_op_us == {}
    rec = simulate(tr, cfg, par_a, pools={0: par_a, 1: par_b},
                   record_per_op=True)
    assert len(rec.per_op_us) == len(tr.ops)


def test_decode_latency_does_not_get_free_pp_speedup(clear_dse_caches):
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=("ring",) * 4, chunks=2)
    lat = {}
    for pp in (1, 4):
        par = Parallelism(1024, dp=16, sp=1, pp=pp)
        tr = generate_trace(SPEC, par, batch=64, seq=2048, mode="decode")
        lat[pp] = simulate(tr, cfg, par).latency_ms
    # the token still traverses every layer, plus cross-stage hops
    assert lat[4] >= lat[1]


# ---------------------------------------------------------------------------
# (c) MultiTenantScenario: disjoint partitions, invalid gates to 0
# ---------------------------------------------------------------------------

def test_multi_tenant_partitions_disjoint_and_gated(clear_dse_caches):
    sc = MultiTenantScenario(tenants=_tenants())
    env = _env(sc)
    pset = scenario_psa(paper_psa(1024), sc, 1024)
    n_valid = 0
    for cfg in _sample_configs(pset, 15, seed=5):
        ev = env.evaluate_config(cfg)
        assert sum(cfg["tenant_npus"]) <= 1024  # sampler respects sum_le
        if not ev.valid:
            assert ev.reward == 0.0
            continue
        n_valid += 1
        ranges = [tuple(t["range"]) for t in ev.detail["tenants"].values()]
        for i, (lo_i, hi_i) in enumerate(ranges):
            for lo_j, hi_j in ranges[i + 1:]:
                assert hi_i <= lo_j or hi_j <= lo_i, \
                    f"partitions share NPUs: {ranges}"
        assert 0.0 <= ev.reward <= 1.0
    assert n_valid, "no valid multi-tenant configs sampled"
    # oversubscription gates to reward 0 even if a repaired config slips past
    base = _sample_configs(pset, 1, seed=6)[0]
    over = dict(base, tenant_npus=(1024, 1024))
    ev = env.evaluate_config(over)
    assert not ev.valid and ev.reward == 0.0


def test_partition_cluster_heterogeneous_devices():
    from repro.core.compute import SYSTEM_3_DEVICE

    net = system_2()
    cluster = partition_cluster(net, (512, 256),
                                (SYSTEM_2_DEVICE, SYSTEM_3_DEVICE))
    a, b = cluster.partitions
    assert a.npu_range() == (0, 512) and b.npu_range() == (512, 768)
    assert b.device.name == "system3-h100"
    assert sub_network(net, 512).n_npus == 512
    with pytest.raises(ValueError):
        partition_cluster(net, (1024, 512), (SYSTEM_2_DEVICE,) * 2)


# ---------------------------------------------------------------------------
# (d) step_batch + process pool works with every scenario type
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_scenario", [
    lambda: TrainScenario(1024, 2048),
    lambda: _disagg_scenario(),
    lambda: MultiTenantScenario(tenants=_tenants()),
], ids=["train", "disagg", "multi-tenant"])
def test_step_batch_and_pool_per_scenario(make_scenario, clear_dse_caches):
    sc = make_scenario()
    assert isinstance(sc, Scenario)  # structural protocol check
    pset = scenario_psa(paper_psa(1024), sc, 1024)
    cfgs = _sample_configs(pset, 6, seed=11)
    serial_env = _env(make_scenario())
    serial = [serial_env.step(c) for c in cfgs]
    with _env(make_scenario()) as pool_env:
        pooled = pool_env.step_batch(cfgs, workers=2)
    for a, b in zip(pooled, serial):
        assert (a.reward, a.latency_ms, a.valid) == \
            (b.reward, b.latency_ms, b.valid)
    assert [r.config for r in pool_env.history] == cfgs


def test_sum_le_repair_respects_fixed_slots():
    from repro.core.psa import Constraint, Parameter, ParameterSet

    pset = ParameterSet(
        [Parameter("a", "scenario", (128, 256, 512, 1024)),
         Parameter("b", "scenario", (128, 256, 512, 1024))],
        [Constraint("sum_le", ("a", "b"), 1024)],
        fixed={"a": 768})
    space = DesignSpace(pset)
    rng = np.random.default_rng(0)
    for _ in range(10):
        cfg = space.sample(rng)
        assert cfg["a"] == 768 and cfg["a"] + cfg["b"] <= 1024


# ---------------------------------------------------------------------------
# shared cross-search eval store
# ---------------------------------------------------------------------------

def test_shared_eval_store_dedupes_across_envs(clear_dse_caches):
    store: dict = {}
    cfgs = _sample_configs(paper_psa(1024), 5, seed=13)
    env_a = _env(eval_store=store)
    first = env_a.step_batch(cfgs)
    assert env_a.store_misses == len(store) > 0
    env_b = _env(eval_store=store)
    second = env_b.step_batch(cfgs)
    assert env_b.store_misses == 0
    assert env_b.store_hits == len({tuple(sorted(c.items())) for c in cfgs})
    for a, b in zip(first, second):
        assert a is b  # the stored Evaluation instance is shared
    # a different env signature must not collide in the same store
    env_c = _env(eval_store=store, batch=512)
    env_c.step(cfgs[0])
    assert env_c.store_hits == 0 and env_c.store_misses == 1
