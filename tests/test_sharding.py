"""ShardingPlan rule-table behaviour: divisibility fallbacks, priority,
uniqueness — the logic the whole dry-run stands on."""
from __future__ import annotations

import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import NULL_PLAN, ShardingPlan

POD = ShardingPlan(axis_sizes={"data": 16, "model": 16})
MULTI = ShardingPlan(axis_sizes={"pod": 2, "data": 16, "model": 16})


def test_ff_takes_model():
    assert POD.spec(("embed", "ff"), (4096, 11008)) == P("data", "model")


def test_nondivisible_falls_back_to_none():
    # 12 q heads can't shard over 16
    s = POD.spec(("embed", "q_heads", "head_dim"), (1536, 12, 128))
    assert s == P("data",)  # trailing Nones trimmed


def test_mesh_axis_used_once():
    # expert takes 'model'; ff must NOT reuse it
    s = POD.spec(("expert", "embed", "ff"), (64, 2048, 1408))
    assert s == P("model", "data")


def test_expert_nondivisible_frees_model_for_ff():
    # granite: 40 experts % 16 != 0 -> ff gets model instead
    s = POD.spec(("expert", "embed", "ff"), (40, 1536, 512))
    assert s == P(None, "data", "model")


def test_batch_spans_pod_and_data():
    s = MULTI.spec(("batch", None, "embed"), (256, 4096, 1024))
    assert s == P(("pod", "data"),)  # embed falls back: data used by batch


def test_batch_unshardable_gives_seq_to_kv():
    # long_500k: batch=1 -> kv_seq gets (data, model)
    s = POD.spec(("batch", "kv_seq", "kv_heads", "head_dim"), (1, 524288, 8, 128))
    assert s == P(None, ("data", "model"))


def test_batch_shardable_kv_seq_takes_model():
    s = POD.spec(("batch", "kv_seq", "kv_heads", "head_dim"), (128, 32768, 7, 128))
    assert s == P("data", "model")


def test_can_shard():
    assert POD.can_shard("q_heads", 32)
    assert not POD.can_shard("q_heads", 12)
    assert POD.can_shard("ff", 8960)
    assert not NULL_PLAN.can_shard("ff", 8960)


def test_null_plan_constrain_is_identity():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert NULL_PLAN.constrain(x, ("batch", "embed")) is x


def test_sp_toggle():
    no_sp = ShardingPlan(axis_sizes={"data": 16, "model": 16}, sp=False)
    assert POD.spec(("batch", "seq", "embed"), (256, 4096, 1024)) == P("data", "model")
    assert no_sp.spec(("batch", "seq", "embed"), (256, 4096, 1024)) == P("data",)


def test_fsdp_toggle():
    no_fsdp = ShardingPlan(axis_sizes={"data": 16, "model": 16}, fsdp=False)
    assert no_fsdp.spec(("embed", "ff"), (4096, 11008)) == P(None, "model")


def test_moe_groups_model_major():
    s = POD.spec(("moe_groups", None, None), (1024, 256, 4096))
    assert s == P(("model", "data"),)
