"""Study API tests: StudySpec JSON round-trip, registry-built envs
bit-identical to hand-constructed equivalents, campaign resume, shared
eval_store accounting, the heterogeneous request-length stream, and the
``repro.dse`` CLI."""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.dse import run_search
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.scenario import (RequestStreamScenario, TrainScenario,
                                 build_scenario, list_scenarios,
                                 scenario_psa)
from repro.core.study import AgentSpec, StudySpec, run_study
from repro.core.systems import get_system, list_systems

ARCH = "qwen2-1.5b"


def _train_spec(**over) -> StudySpec:
    kw = dict(name="t", arch=ARCH, system="system2", scenario="train",
              scenario_params={"batch": 64, "seq": 2048},
              objective="perf_per_bw", agents=("ga",), seeds=(0,),
              steps=20, batch_size=5)
    kw.update(over)
    return StudySpec(**kw)


# ---------------------------------------------------------------------------
# (a) spec: JSON round trip + spec-time validation
# ---------------------------------------------------------------------------

def test_studyspec_json_roundtrip():
    spec = _train_spec(
        scenario="request-stream",
        scenario_params={"n_requests": 16, "seq": 1024, "decode_tokens": 8,
                         "rate_rps": 4.0, "prompt_len_range": [256, 512]},
        objective="goodput",
        agents=("ga", {"kind": "bo", "steps": 10, "hyper": {"candidates": 24}}),
        seeds=[0, 1], stacks=["workload", "scenario"],
        psa_overrides={"chunks": 2})
    text = spec.to_json()
    back = StudySpec.from_json(text)
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()
    # lists arriving from JSON were canonicalized to tuples
    assert back.scenario_params["prompt_len_range"] == (256, 512)
    assert back.agents[1] == AgentSpec("bo", steps=10,
                                       hyper={"candidates": 24})
    # a changed field changes the hash...
    assert _train_spec(steps=21).spec_hash() != _train_spec().spec_hash()
    # ...except workers, which only parallelizes evaluation (results are
    # bit-identical across the pool path) and must not block a resume
    assert _train_spec(workers=4).spec_hash() == _train_spec().spec_hash()


def test_studyspec_rejects_bad_names_at_spec_time():
    with pytest.raises(ValueError, match="unknown arch"):
        _train_spec(arch="not-a-model")
    with pytest.raises(ValueError, match="unknown system"):
        _train_spec(system="system9")
    with pytest.raises(ValueError, match="unknown scenario kind"):
        _train_spec(scenario="not-a-scenario")
    with pytest.raises(ValueError, match="unknown objective"):
        _train_spec(objective="not-an-objective")
    with pytest.raises(ValueError, match="unknown agent kind"):
        _train_spec(agents=("sgd",))
    with pytest.raises(ValueError, match="unknown hyper"):
        # a typo'd hyper name must fail at spec time, not TypeError a cell
        # deep into the campaign
        _train_spec(agents=({"kind": "bo", "hyper": {"pool": 24}},))
    with pytest.raises(ValueError, match="streaming"):
        _train_spec(objective="goodput")  # train can't stream
    with pytest.raises(ValueError, match="unknown pinned parameter"):
        _train_spec(psa_overrides={"not_a_param": 3})
    with pytest.raises(ValueError, match="outside the parameter's choices"):
        _train_spec(psa_overrides={"chunks": 3})
    with pytest.raises(ValueError, match="unknown TrainScenario"):
        _train_spec(scenario_params={"batch": 64, "seq": 2048, "bogus": 1})
    with pytest.raises(ValueError, match="unknown StudySpec keys"):
        StudySpec.from_dict(dict(_train_spec().to_dict(), extra=1))


def test_registries_list_builtins():
    assert {"train", "disagg-serve", "request-stream",
            "multi-tenant"} <= set(list_scenarios())
    assert {"system1", "system2", "system3"} <= set(list_systems())
    assert get_system("system2").n_npus == 1024


# ---------------------------------------------------------------------------
# (b) registry-built env/pset bit-identical to hand-constructed equivalents
# ---------------------------------------------------------------------------

def test_spec_built_search_bit_identical_to_hand_assembled_ga50():
    """GA@50 through the Study front door == GA@50 over a hand-wired
    env/pset (the pre-study assembly), reward for reward."""
    spec = _train_spec(steps=50, batch_size=10)

    hand_ps = paper_psa(1024, max_pp=4)
    hand_env = CosmicEnv(spec=ARCHS[ARCH], n_npus=1024,
                         device=SYSTEM_2_DEVICE,
                         scenario=TrainScenario(64, 2048),
                         objective="perf_per_bw")
    want = run_search(hand_ps, hand_env, "ga", steps=50, seed=3,
                      batch_size=10)
    got = run_search(spec.build_pset(), spec.build_env(), "ga", steps=50,
                     seed=3, batch_size=10)
    assert got.best_reward == want.best_reward
    assert got.best_config == want.best_config
    assert got.reward_curve == want.reward_curve


def test_registry_scenario_reward_matches_hand_constructed_stream():
    sc_hand = RequestStreamScenario(n_requests=16, seq=1024, decode_tokens=8,
                                    rate_rps=4.0)
    sc_reg = build_scenario("request-stream",
                            {"n_requests": 16, "seq": 1024,
                             "decode_tokens": 8, "rate_rps": 4.0})
    assert sc_reg == sc_hand
    spec = _train_spec(scenario="request-stream",
                       scenario_params={"n_requests": 16, "seq": 1024,
                                        "decode_tokens": 8, "rate_rps": 4.0},
                       objective="goodput")
    env_reg = spec.build_env()
    env_hand = CosmicEnv(spec=ARCHS[ARCH], n_npus=1024,
                         device=SYSTEM_2_DEVICE, scenario=sc_hand,
                         objective="goodput")
    from repro.core.space import DesignSpace
    pset = scenario_psa(paper_psa(1024, max_pp=4), sc_hand, 1024)
    space = DesignSpace(pset)
    rng = np.random.default_rng(0)
    for _ in range(5):
        cfg = space.sample(rng)
        assert env_reg.evaluate_config(cfg).reward == \
            env_hand.evaluate_config(cfg).reward


# ---------------------------------------------------------------------------
# (c) campaign: shared store, JSONL persistence, resume
# ---------------------------------------------------------------------------

def test_shared_eval_store_across_cells():
    """Two identical GA cells in one campaign: the second re-proposes the
    exact same points (same agent seed) and must hit the shared store for
    every one of them."""
    spec = _train_spec(agents=("ga", "ga"), steps=15, batch_size=5)
    res = run_study(spec)
    first, second = res.outcomes
    assert first.result.best_reward == second.result.best_reward
    assert second.store_hits == 15           # every point was free
    assert res.store_hits + res.store_misses == 30  # per-occurrence accounting
    assert res.distinct_points == res.store_misses


def test_campaign_persists_and_resumes(tmp_path):
    out = tmp_path / "campaign.jsonl"
    spec = _train_spec(agents=("ga",), seeds=(0, 1), steps=12, batch_size=4)
    full = run_study(spec, out=out)
    assert full.cells_run == 2
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert lines[0]["record"] == "study"
    assert lines[0]["spec_hash"] == spec.spec_hash()
    assert [l["cell_id"] for l in lines[1:]] == ["0:ga:s0", "0:ga:s1"]
    assert all(l["spec_hash"] == spec.spec_hash() for l in lines[1:])

    # chop the campaign in half: only the missing cell may run on resume
    out.write_text("\n".join(json.dumps(l) for l in lines[:2]) + "\n")
    half = run_study(spec, out=out, resume=True)
    assert half.cells_run == 1 and half.cells_skipped == 1
    assert [o.resumed for o in half.outcomes] == [True, False]
    # resumed + re-run rewards match the uninterrupted campaign bit for bit
    assert [o.result.best_reward for o in half.outcomes] == \
        [o.result.best_reward for o in full.outcomes]

    # fully complete file: nothing runs, results reconstructed from disk
    done = run_study(spec, out=out, resume=True)
    assert done.cells_run == 0 and done.cells_skipped == 2
    assert [o.result.best_reward for o in done.outcomes] == \
        [o.result.best_reward for o in full.outcomes]
    # a resumed best_config round-trips through JSON with its tuples intact
    # (hashable again — usable as a memoized env step input)
    resumed_cfg = done.best().result.best_config
    assert resumed_cfg == full.best().result.best_config
    env = spec.build_env()
    assert env.step(resumed_cfg).reward == done.best().result.best_reward


def test_resume_refuses_foreign_results_file(tmp_path):
    out = tmp_path / "campaign.jsonl"
    run_study(_train_spec(steps=8, batch_size=4), out=out)
    other = _train_spec(steps=9, batch_size=4)
    with pytest.raises(ValueError, match="refusing to resume"):
        run_study(other, out=out, resume=True)


def test_resume_needs_results_file():
    with pytest.raises(ValueError, match="results file"):
        run_study(_train_spec(), resume=True)


def test_refuses_to_overwrite_existing_results(tmp_path):
    """Re-running without --resume must never truncate a finished
    campaign's results file."""
    out = tmp_path / "campaign.jsonl"
    spec = _train_spec(steps=8, batch_size=4)
    run_study(spec, out=out)
    before = out.read_text()
    with pytest.raises(ValueError, match="already exists"):
        run_study(spec, out=out)
    assert out.read_text() == before


def test_resume_discards_truncated_final_line(tmp_path):
    """A campaign killed mid-append leaves a partial trailing record: resume
    drops it (re-running that cell) instead of crashing on it, and trims it
    so appended records don't concatenate onto the fragment."""
    out = tmp_path / "campaign.jsonl"
    spec = _train_spec(agents=("ga",), seeds=(0, 1), steps=12, batch_size=4)
    full = run_study(spec, out=out)
    lines = out.read_text().splitlines()
    out.write_text("\n".join(lines[:2]) + "\n" + lines[2][:40])  # torn write
    res = run_study(spec, out=out, resume=True)
    assert res.cells_run == 1 and res.cells_skipped == 1
    assert [o.result.best_reward for o in res.outcomes] == \
        [o.result.best_reward for o in full.outcomes]
    # the rewritten file is whole again: a second resume runs nothing
    again = run_study(spec, out=out, resume=True)
    assert again.cells_run == 0 and again.cells_skipped == 2
    # a torn line anywhere else is corruption, not a torn tail
    lines = out.read_text().splitlines()
    out.write_text("\n".join([lines[0], lines[1][:40], lines[2]]) + "\n")
    with pytest.raises(ValueError, match="not valid JSON"):
        run_study(spec, out=out, resume=True)


# ---------------------------------------------------------------------------
# (d) heterogeneous request lengths
# ---------------------------------------------------------------------------

_STREAM_CFG = dict(dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
                   coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
                   multidim_coll="baseline",
                   topology=("ring", "fc", "ring", "switch"),
                   npus_per_dim=(4, 8, 4, 8),
                   bw_per_dim=(400, 200, 150, 100), prefill_frac=0.875,
                   decode_batch=8, batch_window_ms=50.0, max_inflight=2)


def _stream_env(sc):
    return CosmicEnv(spec=ARCHS[ARCH], n_npus=1024, device=SYSTEM_2_DEVICE,
                     scenario=sc, objective="goodput")


def test_request_shapes_default_homogeneous():
    sc = RequestStreamScenario(n_requests=8, seq=1024, decode_tokens=16)
    assert sc.request_shapes() == ((1024, 16),) * 8
    assert not sc.heterogeneous()


def test_request_shapes_seeded_deterministic_and_bounded():
    sc = RequestStreamScenario(n_requests=32, seq=1024, decode_tokens=16,
                               prompt_len_range=(256, 2048),
                               decode_len_range=(4, 64), seed=5)
    shapes = sc.request_shapes()
    assert shapes == sc.request_shapes()          # memoized + deterministic
    assert sc.heterogeneous()
    assert all(256 <= p <= 2048 and 4 <= d <= 64 for p, d in shapes)
    assert len({p for p, _ in shapes}) > 1        # actually heterogeneous
    # a different seed draws different lengths
    other = RequestStreamScenario(n_requests=32, seq=1024, decode_tokens=16,
                                  prompt_len_range=(256, 2048),
                                  decode_len_range=(4, 64), seed=6)
    assert other.request_shapes() != shapes


def test_request_shapes_replayed_trace_cycles():
    sc = RequestStreamScenario(n_requests=5, seq=1024, decode_tokens=16,
                               prompt_lens=(100, 700),
                               decode_lens=(8, 2, 4))
    assert sc.request_shapes() == \
        ((100, 8), (700, 2), (100, 4), (700, 8), (100, 2))


def test_heterogeneous_lengths_change_metrics_and_stay_valid():
    homog = RequestStreamScenario(n_requests=24, seq=1024, decode_tokens=16)
    het = RequestStreamScenario(n_requests=24, seq=1024, decode_tokens=16,
                                prompt_len_range=(256, 2048),
                                decode_len_range=(4, 64))
    ev_h = _stream_env(homog).evaluate_config(_STREAM_CFG)
    ev_x = _stream_env(het).evaluate_config(_STREAM_CFG)
    assert ev_h.valid and ev_x.valid
    assert ev_x.reward != ev_h.reward
    d = ev_x.detail
    assert d["prompt_len_max"] <= 2048 and d["decode_len_max"] <= 64
    assert "prompt_len_mean" not in ev_h.detail   # only reported when het
    # shorter-than-wave-max requests finish earlier than the wave: p50 e2e
    # latency can't exceed the homogeneous-style wave completion ceiling
    assert d["latency_p99_ms"] > 0


def test_heterogeneous_range_validation():
    sc = RequestStreamScenario(n_requests=4, prompt_len_range=(0, 8))
    with pytest.raises(ValueError, match="prompt"):
        sc.request_shapes()
    sc = RequestStreamScenario(n_requests=4, decode_len_range=(9, 3))
    with pytest.raises(ValueError, match="decode"):
        sc.request_shapes()


def test_heterogeneous_params_via_study_spec():
    spec = _train_spec(
        scenario="request-stream", objective="goodput",
        scenario_params={"n_requests": 12, "seq": 1024, "decode_tokens": 8,
                         "rate_rps": 4.0, "prompt_len_range": [128, 512],
                         "decode_lens": [4, 8]})
    sc = spec.build_scenario()
    assert sc.prompt_len_range == (128, 512)
    assert sc.decode_lens == (4, 8)
    assert sc.heterogeneous()


# ---------------------------------------------------------------------------
# (e) the CLI
# ---------------------------------------------------------------------------

def test_cli_run_and_resume(tmp_path, capsys):
    from repro.dse import main

    spec_path = tmp_path / "smoke.json"
    out_path = tmp_path / "smoke.results.jsonl"
    _train_spec(steps=8, batch_size=4).to_json(spec_path)

    assert main(["run", str(spec_path), "--out", str(out_path)]) == 0
    assert "cells_run=1" in capsys.readouterr().out
    assert out_path.exists()

    assert main(["run", str(spec_path), "--out", str(out_path),
                 "--resume"]) == 0
    assert "cells_run=0 cells_skipped=1" in capsys.readouterr().out

    for cmd in ("list-scenarios", "list-systems", "list-objectives",
                "list-backends"):
        assert main([cmd]) == 0
    listed = capsys.readouterr().out
    assert "request-stream" in listed and "system2" in listed \
        and "goodput" in listed and "reference" in listed


# ---------------------------------------------------------------------------
# (f) simulation-backend selection on the spec
# ---------------------------------------------------------------------------

def test_spec_backend_field_roundtrip_and_validation():
    spec = _train_spec(backend="reference")
    assert StudySpec.from_json(spec.to_json()) == spec
    # the backend changes results (within tolerance), so it changes the hash
    assert _train_spec(backend="jax").spec_hash() != spec.spec_hash()
    # ...but the default backend hashes as if the field didn't exist, so
    # campaigns recorded before PR 5 stay resumable
    import hashlib

    d = spec.to_dict()
    for k in ("workers", "eval_store_path", "backend"):
        del d[k]
    pre_pr5 = hashlib.sha256(json.dumps(
        d, sort_keys=True, separators=(",", ":")).encode()).hexdigest()[:16]
    assert spec.spec_hash() == pre_pr5
    with pytest.raises(ValueError, match="unknown simulation backend"):
        _train_spec(backend="not-a-backend")
    env = spec.build_env()
    assert env.backend == "reference"
    # old spec JSONs (no backend key) load with the default
    d = spec.to_dict()
    del d["backend"]
    assert StudySpec.from_dict(d).backend == "reference"


def test_cli_backend_override(tmp_path, capsys):
    pytest.importorskip("jax")
    from repro.dse import main

    spec_path = tmp_path / "s.json"
    _train_spec(steps=6, batch_size=3).to_json(spec_path)
    assert main(["run", str(spec_path), "--backend", "jax",
                 "--out", str(tmp_path / "r.jsonl")]) == 0
    assert "backend=jax" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# (g) cross-campaign persistent eval store
# ---------------------------------------------------------------------------

def test_persistent_eval_store_reused_across_campaigns(tmp_path):
    store_path = tmp_path / "evals.jsonl"
    spec = _train_spec(steps=10, batch_size=5,
                       eval_store_path=str(store_path))
    # eval_store_path is hash-exempt (reuse never changes results)
    assert spec.spec_hash() == _train_spec(steps=10,
                                           batch_size=5).spec_hash()

    first = run_study(spec, out=tmp_path / "r1.jsonl")
    assert first.store_preloaded == 0
    assert first.store_persisted == first.distinct_points > 0
    assert store_path.exists()

    second = run_study(spec, out=tmp_path / "r2.jsonl")
    assert second.store_preloaded == first.store_persisted
    assert second.store_misses == 0          # every point came from disk
    assert second.store_hit_rate == 1.0
    assert second.store_persisted == 0       # nothing new to write back
    # and the campaign's results are identical to the fresh one's
    assert [o.result.best_reward for o in second.outcomes] == \
        [o.result.best_reward for o in first.outcomes]
    assert [o.result.reward_curve for o in second.outcomes] == \
        [o.result.reward_curve for o in first.outcomes]


def test_persistent_eval_store_isolates_incompatible_studies(tmp_path):
    """Entries are stamped with the evaluation signature: a study over a
    different (arch/objective/...) must not preload another's results."""
    store_path = tmp_path / "evals.jsonl"
    spec_a = _train_spec(steps=6, batch_size=3,
                         eval_store_path=str(store_path))
    run_study(spec_a, out=tmp_path / "a.jsonl")

    spec_b = _train_spec(steps=6, batch_size=3, objective="latency",
                         eval_store_path=str(store_path))
    assert spec_b.eval_signature() != spec_a.eval_signature()
    res_b = run_study(spec_b, out=tmp_path / "b.jsonl")
    assert res_b.store_preloaded == 0
    assert res_b.store_persisted > 0

    # ...while a search-shape change (steps/agents) still shares entries
    spec_c = _train_spec(steps=4, batch_size=2, agents=("rw",),
                         eval_store_path=str(store_path))
    assert spec_c.eval_signature() == spec_a.eval_signature()
    assert run_study(spec_c, out=tmp_path / "c.jsonl").store_preloaded > 0


def test_persistent_eval_store_survives_torn_tail(tmp_path):
    store_path = tmp_path / "evals.jsonl"
    spec = _train_spec(steps=6, batch_size=3,
                       eval_store_path=str(store_path))
    run_study(spec, out=tmp_path / "a.jsonl")
    with store_path.open("a") as f:
        f.write('{"sig": "torn')  # killed mid-append
    res = run_study(spec, out=tmp_path / "b.jsonl")
    assert res.store_preloaded > 0 and res.store_misses == 0


# ---------------------------------------------------------------------------
# (h) the results-comparison CLI
# ---------------------------------------------------------------------------

def test_cli_compare_results_files(tmp_path, capsys):
    from repro.dse import main

    spec_path = tmp_path / "s.json"
    _train_spec(steps=8, batch_size=4, agents=("ga", "rw")).to_json(spec_path)
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    assert main(["run", str(spec_path), "--out", str(a), "--quiet"]) == 0
    assert main(["run", str(spec_path), "--out", str(b), "--quiet"]) == 0
    capsys.readouterr()

    assert main(["compare", str(a), str(b)]) == 0
    got = capsys.readouterr()
    assert "0:ga:s0" in got.out and "1:rw:s0" in got.out
    assert "winner: tie" in got.out          # identical campaigns
    assert "warning" not in got.err          # same spec hash

    # a different study into b -> hash-mismatch warning + a winner
    b2 = tmp_path / "b2.jsonl"
    spec2 = tmp_path / "s2.json"
    _train_spec(steps=12, batch_size=4, agents=("ga", "rw"),
                seeds=(1,)).to_json(spec2)
    assert main(["run", str(spec2), "--out", str(b2), "--quiet"]) == 0
    capsys.readouterr()
    assert main(["compare", str(a), str(b2)]) == 0
    got = capsys.readouterr()
    assert "spec hashes differ" in got.err
    assert "winner:" in got.out

    assert main(["compare", str(a), str(tmp_path / "missing.jsonl")]) == 2


# ---------------------------------------------------------------------------
# (h) CLI robustness: empty/torn JSONL, resume on an empty file, lint/analyze
# ---------------------------------------------------------------------------

def test_cli_compare_empty_and_torn_files_exit_2(tmp_path, capsys):
    from repro.dse import main

    spec_path = tmp_path / "s.json"
    _train_spec(steps=4, batch_size=2).to_json(spec_path)
    good = tmp_path / "good.jsonl"
    assert main(["run", str(spec_path), "--out", str(good), "--quiet"]) == 0
    capsys.readouterr()

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert main(["compare", str(good), str(empty)]) == 2
    assert "error:" in capsys.readouterr().err

    # header written, then killed mid-first-cell: lenient reader drops the
    # torn tail, no cells remain -> clean exit 2, no traceback
    torn = tmp_path / "torn.jsonl"
    torn.write_text(good.read_text().split("\n")[0] + "\n"
                    + '{"record": "cell", "cell_id": "0:ga:s0", "res')
    assert main(["compare", str(good), str(torn)]) == 2
    assert "no cell records" in capsys.readouterr().err

    assert main(["analyze", str(empty)]) == 2
    assert "error:" in capsys.readouterr().err


def test_cli_resume_on_empty_results_file_writes_header(tmp_path, capsys):
    from repro.dse import main

    spec_path = tmp_path / "s.json"
    _train_spec(steps=4, batch_size=2).to_json(spec_path)
    out = tmp_path / "r.jsonl"
    out.write_text("")      # e.g. `touch`ed by a scheduler before the run
    assert main(["run", str(spec_path), "--out", str(out), "--resume",
                 "--quiet"]) == 0
    capsys.readouterr()
    first = json.loads(out.read_text().splitlines()[0])
    assert first["record"] == "study"       # header present, not cells-only
    # and the file now resumes cleanly
    assert main(["run", str(spec_path), "--out", str(out), "--resume",
                 "--quiet"]) == 0
    assert "cells_run=0 cells_skipped=1" in capsys.readouterr().out


def test_cli_lint_and_analyze(tmp_path, capsys):
    from repro.dse import main

    spec_path = tmp_path / "s.json"
    _train_spec(steps=4, batch_size=2).to_json(spec_path)
    assert main(["lint", str(spec_path)]) == 0
    out = capsys.readouterr().out
    assert "ok" in out and "cells=1" in out

    # unknown scenario param -> spec doesn't build -> exit 2
    bad = tmp_path / "bad.json"
    d = _train_spec().to_dict()
    d["scenario_params"]["batcj"] = 64
    bad.write_text(json.dumps(d))
    assert main(["lint", str(bad)]) == 2
    assert "batcj" in capsys.readouterr().err

    # unsatisfiable pins -> lint reports, exit 1
    unsat = tmp_path / "unsat.json"
    d2 = _train_spec(psa_overrides={"dp": 1024, "sp": 1024}).to_dict()
    unsat.write_text(json.dumps(d2))
    assert main(["lint", str(unsat)]) == 1
    got = capsys.readouterr()
    assert "constraint-unsat" in got.out

    # analyze: bottleneck-attribution table over a finished campaign
    res = tmp_path / "r.jsonl"
    assert main(["run", str(spec_path), "--out", str(res), "--quiet"]) == 0
    capsys.readouterr()
    assert main(["analyze", str(res)]) == 0
    table = capsys.readouterr().out
    assert "cp%" in table and "0:ga:s0" in table and "bound" in table
