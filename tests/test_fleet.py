"""Fleet serving subsystem (PR 8): seeded arrival generators, router and
autoscaler policies, the 1-replica reduction to RequestStreamScenario
(bit-identical, golden-pinned, under both backends), the continuous-batching
engine knobs, and the provisioned-cost goodput-per-dollar objective."""
from __future__ import annotations

import json

import pytest

from repro.configs import ARCHS
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.env import CosmicEnv
from repro.core.fleet import (ARRIVAL_KINDS, FleetScenario, ROUTER_POLICIES,
                              arrival_times_ms, autoscale_active,
                              route_requests)
from repro.core.scenario import RequestStreamScenario
from repro.core.study import StudySpec
from repro.core.workload import WaveSegment, compose_request_waves, Wave

SPEC = ARCHS["gpt3-13b"]

# the known-valid system2 design point from test_scenarios, plus the fleet
# scenario-stack knobs
_CFG = dict(dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
            coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
            multidim_coll="baseline",
            topology=("ring", "fc", "ring", "switch"),
            npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100),
            prefill_frac=0.875, decode_batch=4,
            batch_window_ms=200.0, max_inflight=2)
_FLEET_CFG = dict(_CFG, router="round-robin", autoscale_target=0.0,
                  autoscale_cooldown_s=10.0)

_STREAM_KW = dict(n_requests=16, seq=2048, decode_tokens=8, rate_rps=16.0,
                  max_batch=8, seed=3)


def _env(scenario, **kw):
    kw.setdefault("objective", "goodput")
    return CosmicEnv(spec=SPEC, n_npus=1024, device=SYSTEM_2_DEVICE,
                     scenario=scenario, **kw)


# ---------------------------------------------------------------------------
# (a) arrival generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_arrivals_seeded_deterministic_and_monotone(kind):
    kw = dict(rate_rps=8.0, gaps_ms=(10.0, 20.0))
    a = arrival_times_ms(kind, 64, seed=7, **kw)
    b = arrival_times_ms(kind, 64, seed=7, **kw)
    assert a == b
    assert len(a) == 64
    # strictly positive first arrival, non-negative gaps throughout
    assert a[0] > 0.0
    assert all(t1 >= t0 for t0, t1 in zip(a, a[1:]))
    if kind != "replayed":  # replay ignores the seed by design
        assert arrival_times_ms(kind, 64, seed=8, **kw) != a


def test_diurnal_realized_rate_tracks_nominal():
    """Over whole periods the diurnal realized rate converges to the mean
    of base and peak; within a period the peak half-cycle is denser."""
    base, peak, period = 8.0, 24.0, 30.0
    times = arrival_times_ms("diurnal", 4000, rate_rps=base, peak_rps=peak,
                             period_s=period, seed=1)
    realized = len(times) / (times[-1] / 1e3)
    assert 0.85 * (base + peak) / 2 < realized < 1.15 * (base + peak) / 2
    # rate(t) peaks at period/2 (1-cos profile): middle-of-period halves
    # hold more arrivals than the edges
    in_peak = sum(1 for t in times
                  if period / 4 <= (t / 1e3) % period < 3 * period / 4)
    assert in_peak > len(times) - in_peak


def test_bursty_realized_rate_and_burst_density():
    rate = 8.0
    times = arrival_times_ms("bursty", 4000, rate_rps=rate, burst_factor=6.0,
                             burst_s=2.0, seed=2)
    realized = len(times) / (times[-1] / 1e3)
    # MMPP time-average rate sits between the calm and burst rates
    assert rate * 0.5 < realized < rate * 6.0
    # bursts exist: the tightest 5% of gaps are far tighter than the mean gap
    gaps = sorted(t1 - t0 for t0, t1 in zip(times, times[1:]))
    mean_gap = (times[-1] - times[0]) / (len(times) - 1)
    assert gaps[len(gaps) // 20] < mean_gap / 2


def test_arrivals_rejects_unknown_kind_and_missing_replay():
    with pytest.raises(ValueError, match="unknown arrival kind"):
        arrival_times_ms("lunar", 4)
    with pytest.raises(ValueError, match="arrival_gaps_ms"):
        arrival_times_ms("replayed", 4)


def test_fleet_poisson_matches_engine_arrivals():
    """The fleet's poisson generator makes the exact draws the engine
    makes — the 1-replica reduction depends on it."""
    eng = RequestStreamScenario(n_requests=32, rate_rps=8.0, seed=5)
    fl = FleetScenario(n_requests=32, rate_rps=8.0, seed=5,
                       arrival="poisson")
    assert fl.arrivals_ms() == eng.arrivals_ms()


def test_replayed_arrivals_roundtrip_through_study_json():
    """A replayed trace survives StudySpec JSON serialization exactly."""
    spec = StudySpec(
        name="replay-rt", arch="qwen2-1.5b", system="system2",
        scenario="fleet", objective="goodput_per_dollar",
        scenario_params=dict(n_requests=8, seq=1024, decode_tokens=8,
                             arrival="replayed",
                             arrival_gaps_ms=(12.5, 40.0, 7.25),
                             replicas=2),
        steps=2, batch_size=2)
    rt = StudySpec.from_json(spec.to_json())
    assert rt == spec
    sc = rt.build_scenario()
    assert isinstance(sc, FleetScenario)
    assert sc.arrivals_ms() == FleetScenario(
        n_requests=8, arrival="replayed",
        arrival_gaps_ms=(12.5, 40.0, 7.25)).arrivals_ms()
    # cycled gap replay, absolute times
    assert sc.arrivals_ms()[:4] == (12.5, 52.5, 59.75, 72.25)


# ---------------------------------------------------------------------------
# (b) router policies
# ---------------------------------------------------------------------------

def test_router_round_robin_cycles_active_replicas():
    assign = route_requests("round-robin", tuple(range(6)), [3] * 6,
                            [1.0] * 6, tuple(range(6)), 3)
    assert assign == (0, 1, 2, 0, 1, 2)
    # requests only ever land on active replicas
    assign = route_requests("round-robin", tuple(range(6)), [1] * 3 + [2] * 3,
                            [1.0] * 6, tuple(range(6)), 2)
    assert all(r < a for r, a in zip(assign, [1] * 3 + [2] * 3))


def test_router_least_outstanding_prefers_idle_replica():
    # request 0 parks 100ms of work on replica 0; the next two arrivals
    # (within that window) go to the idle replicas, then back to 0
    assign = route_requests("least-outstanding", (0.0, 1.0, 2.0, 3.0),
                            [3] * 4, [100.0, 1.0, 1.0, 1.0],
                            tuple(range(4)), 3)
    assert assign == (0, 1, 2, 1)


def test_router_prefix_hash_is_session_sticky():
    groups = (4, 9, 4, 9, 4, 2)
    assign = route_requests("prefix-hash", tuple(range(6)), [3] * 6,
                            [1.0] * 6, groups, 3)
    by_group = {}
    for g, r in zip(groups, assign):
        by_group.setdefault(g, set()).add(r)
    assert all(len(rs) == 1 for rs in by_group.values())
    with pytest.raises(ValueError, match="unknown router"):
        route_requests("random", (0.0,), [1], [1.0], (0,), 1)


# ---------------------------------------------------------------------------
# (c) autoscaler
# ---------------------------------------------------------------------------

def test_autoscaler_static_when_target_disabled():
    act = autoscale_active((0.0, 50_000.0), epoch_ms=10_000.0,
                           min_replicas=1, max_replicas=4, target_util=0.0,
                           cooldown_epochs=3, replica_rps=2.0)
    assert act == (4,) * 6


def test_autoscaler_scales_up_fast_and_down_slow():
    # 4 epochs of heavy traffic (80 rps vs an effective 20 rps/replica at
    # target 0.8) then idle: scale-up jumps (after one observation epoch),
    # scale-down sheds one replica per cooldown epoch
    heavy = tuple(i * 12.5 for i in range(3200))      # 80 rps for 40s
    act = autoscale_active(heavy + (90_000.0,), epoch_ms=10_000.0,
                           min_replicas=1, max_replicas=4, target_util=0.8,
                           cooldown_epochs=1, replica_rps=25.0)
    assert act[0] == 1                 # capacity decided before arrivals
    assert max(act) == 4               # jumps to the demanded count
    assert act.index(4) <= 2           # ...quickly
    tail = act[5:]                     # idle epochs: one shed per epoch
    assert all(a >= b >= b_next or True for a, b, b_next in
               zip(tail, tail[1:], tail[2:]))
    assert sorted(tail, reverse=True) == list(tail)
    assert act[-1] >= 1                # never below min_replicas


def test_autoscaler_cooldown_delays_decisions():
    heavy = tuple(i * 12.5 for i in range(3200))
    fast = autoscale_active(heavy, epoch_ms=10_000.0, min_replicas=1,
                            max_replicas=4, target_util=0.8,
                            cooldown_epochs=1, replica_rps=25.0)
    slow = autoscale_active(heavy, epoch_ms=10_000.0, min_replicas=1,
                            max_replicas=4, target_util=0.8,
                            cooldown_epochs=3, replica_rps=25.0)
    assert sum(slow) <= sum(fast)      # cooldown holds capacity back
    assert max(slow) <= 4 and min(slow) >= 1


# ---------------------------------------------------------------------------
# (d) 1-replica reduction: FleetScenario == RequestStreamScenario
# ---------------------------------------------------------------------------

_STREAM_METRIC_KEYS = ("goodput_rps", "ttft_p50_ms", "ttft_p99_ms",
                       "tpot_p50_ms", "tpot_p99_ms", "latency_p99_ms",
                       "n_ok", "horizon_ms")


@pytest.mark.parametrize("backend", ["reference", "jax"])
def test_one_replica_static_fleet_reduces_to_engine(backend,
                                                    clear_dse_caches):
    """A 1-replica static fleet IS the engine: bit-identical stream
    metrics and reward under both simulation backends."""
    a = _env(RequestStreamScenario(**_STREAM_KW),
             backend=backend).evaluate_config(_CFG)
    b = _env(FleetScenario(**_STREAM_KW, replicas=1, arrival="poisson",
                           routers=("round-robin",),
                           autoscale_targets=(0.0,)),
             backend=backend).evaluate_config(_FLEET_CFG)
    assert a.valid and b.valid
    assert b.reward == a.reward
    for k in _STREAM_METRIC_KEYS:
        assert b.detail[k] == a.detail[k], k
    assert b.detail["replica_requests"] == [_STREAM_KW["n_requests"]]
    # golden pin: the reduction must not drift silently
    assert a.reward == pytest.approx(13.668876414816836, abs=0.0)


@pytest.mark.parametrize("backend", ["reference", "jax"])
def test_one_replica_goodput_per_cost_unchanged(backend, clear_dse_caches):
    """Satellite 1: autoscaler-aware pricing leaves the single-replica
    static goodput_per_cost bit-identical to the pre-fleet formula
    (provisioned time == horizon -> cost == net.dollar_cost())."""
    a = _env(RequestStreamScenario(**_STREAM_KW), backend=backend,
             objective="goodput_per_cost").evaluate_config(_CFG)
    b = _env(FleetScenario(**_STREAM_KW, replicas=1, arrival="poisson",
                           routers=("round-robin",),
                           autoscale_targets=(0.0,)),
             backend=backend,
             objective="goodput_per_cost").evaluate_config(_FLEET_CFG)
    assert a.valid and b.valid
    assert b.reward == a.reward
    assert a.reward == pytest.approx(2.966336027521015, abs=0.0)
    # goodput_per_dollar is the same number here (fleet-first-class alias)
    c = _env(FleetScenario(**_STREAM_KW, replicas=1, arrival="poisson",
                           routers=("round-robin",),
                           autoscale_targets=(0.0,)),
             backend=backend,
             objective="goodput_per_dollar").evaluate_config(_FLEET_CFG)
    assert c.reward == a.reward


# ---------------------------------------------------------------------------
# (e) continuous-batching engine knobs
# ---------------------------------------------------------------------------

def _knob_scenario(**kw):
    # near-simultaneous arrivals + small waves: the queue is deep enough
    # that the decode-admission gates actually bind
    base = dict(_STREAM_KW, rate_rps=1000.0, max_batch=2)
    base.update(kw)
    base.setdefault("admissions", ("gated", "continuous"))
    base.setdefault("prefill_chunk_choices", (1, 4))
    base.setdefault("preempt_choices", (0, 1))
    return RequestStreamScenario(**base)


def test_engine_knobs_add_psa_params_only_when_searched():
    base = RequestStreamScenario(**_STREAM_KW)
    names = {p.name for p in base.psa_params()}
    assert {"admission", "prefill_chunks", "preempt",
            "kv_headroom"}.isdisjoint(names)
    ext = _knob_scenario(kv_headrooms=(0.2, 0.8))
    names = {p.name for p in ext.psa_params()}
    assert {"admission", "prefill_chunks", "preempt",
            "kv_headroom"} <= names


def test_continuous_admission_joins_earlier(clear_dse_caches):
    """Continuous admission gates a wave's decode on the previous wave's
    FIRST decode token instead of its completion — strictly earlier, so
    makespan can only improve."""
    sc = _knob_scenario()
    gated = _env(sc).evaluate_config(dict(_CFG, admission="gated"))
    cont = _env(sc).evaluate_config(dict(_CFG, admission="continuous"))
    assert gated.valid and cont.valid
    assert gated.detail["admission"] == "gated"
    assert cont.detail["admission"] == "continuous"
    assert cont.detail["makespan_ms"] < gated.detail["makespan_ms"]
    assert cont.reward >= gated.reward


def test_chunked_prefill_cuts_critical_transfer(clear_dse_caches):
    """Chunked prefill streams KV to the decode pool: only the last chunk
    sits on the critical path, so TTFT-bearing makespan shrinks."""
    sc = _knob_scenario()
    whole = _env(sc).evaluate_config(dict(_CFG, prefill_chunks=1))
    chunked = _env(sc).evaluate_config(dict(_CFG, prefill_chunks=4))
    assert whole.valid and chunked.valid
    assert chunked.detail["prefill_chunks"] == 4
    assert chunked.detail["ttft_p99_ms"] <= whole.detail["ttft_p99_ms"]
    assert chunked.detail["makespan_ms"] <= whole.detail["makespan_ms"]


def test_preemption_reorders_decode_chain(clear_dse_caches):
    """With mixed priority tiers, preemptive admission chains a wave's
    decode behind the last wave of equal-or-higher priority, letting
    high-tier waves bypass low-tier ones."""
    sc = _knob_scenario(priority_frac=0.5)
    tiers = sc.request_tiers()
    assert set(tiers) == {0, 1}       # the 50/50 split actually mixed
    fifo = _env(sc).evaluate_config(dict(_CFG, preempt=0))
    pre = _env(sc).evaluate_config(dict(_CFG, preempt=1))
    assert fifo.valid and pre.valid
    assert bool(pre.detail["preempt"]) and not fifo.detail["preempt"]
    # the schedule actually changed
    assert pre.detail["makespan_ms"] != fifo.detail["makespan_ms"]


def test_kv_headroom_caps_inflight(clear_dse_caches):
    """A tight KV paging budget throttles admission below the searched
    max_inflight; a loose one leaves it alone."""
    sc = RequestStreamScenario(**_STREAM_KW, kv_headrooms=(0.0001, 1.0),
                               admissions=("gated",))
    loose = _env(sc).evaluate_config(dict(_CFG, kv_headroom=1.0,
                                          max_inflight=2))
    tight = _env(sc).evaluate_config(dict(_CFG, kv_headroom=0.0001,
                                          max_inflight=2))
    assert loose.valid and tight.valid
    assert loose.detail["effective_max_inflight"] == 2
    assert tight.detail["effective_max_inflight"] == 1
    assert tight.detail["kv_inflight_cap"] == 1
    assert tight.detail["makespan_ms"] >= loose.detail["makespan_ms"]


def test_default_engine_unchanged_by_knob_plumbing(clear_dse_caches):
    """Satellite guard: with no knob choice tuples, the engine's params,
    trace composition, and reward are exactly the pre-PR ones (golden)."""
    sc = RequestStreamScenario(**_STREAM_KW)
    ev = _env(sc).evaluate_config(_CFG)
    assert ev.valid
    assert ev.reward == pytest.approx(13.668876414816836, abs=0.0)
    assert "admission" not in ev.detail


def test_transfer_chunks_background_op():
    """compose_request_waves with transfer_chunks>1 emits one critical
    chunk plus a background remainder op that depends on it, conserving
    total bytes."""
    from repro.core.workload import generate_trace, Parallelism
    par = Parallelism(n_npus=1, dp=1, sp=1, pp=1)
    t = generate_trace(ARCHS["qwen2-1.5b"], par, batch=1, seq=256,
                       mode="inference")

    def mk(chunks):
        w = Wave([WaveSegment(t, 0, 1, 8e9, transfer_chunks=chunks),
                  WaveSegment(t, 1)], 0.0, [])
        return compose_request_waves([w])

    whole = mk(1)
    split = mk(4)
    xfers1 = [op for op in whole.ops if op.group == "xfer"]
    xfers4 = [op for op in split.ops if op.group == "xfer"]
    assert len(xfers1) == 1 and len(xfers4) == 2
    assert sum(o.size_bytes for o in xfers4) == pytest.approx(8e9)
    crit, bg = xfers4
    assert bg.name.endswith("xfer_bg")
    assert bg.deps == [crit.uid]
    assert crit.size_bytes == pytest.approx(2e9)


# ---------------------------------------------------------------------------
# (f) multi-replica fleet: cost, traces, lint
# ---------------------------------------------------------------------------

def _fleet(**kw):
    kw.setdefault("n_requests", 32)
    kw.setdefault("seq", 2048)
    kw.setdefault("decode_tokens", 8)
    kw.setdefault("rate_rps", 32.0)
    kw.setdefault("max_batch", 8)
    kw.setdefault("replicas", 2)
    kw.setdefault("seed", 3)
    return FleetScenario(**kw)


def test_fleet_two_replicas_evaluates_and_prices(clear_dse_caches):
    sc = _fleet(arrival="diurnal", epoch_s=1.0, autoscale_targets=(0.0, 0.8))
    env = _env(sc, objective="goodput_per_dollar")
    static = env.evaluate_config(dict(_FLEET_CFG, router="least-outstanding"))
    assert static.valid
    d = static.detail
    assert d["replicas"] == 2 and d["router"] == "least-outstanding"
    assert sum(d["replica_requests"]) == 32
    assert all(n > 0 for n in d["replica_requests"])  # both replicas used
    assert d["provisioned_cost"] > 0
    # static full-fleet provisioning prices both partitions for the whole
    # horizon: cost equals the sum of the replica partition costs
    assert d["active_per_epoch"] == [2] * len(d["active_per_epoch"])
    # autoscaling can only lower the provisioned bill
    scaled = env.evaluate_config(dict(_FLEET_CFG,
                                      router="least-outstanding",
                                      autoscale_target=0.8,
                                      autoscale_cooldown_s=1.0))
    assert scaled.valid
    assert scaled.detail["provisioned_cost"] <= d["provisioned_cost"]


def test_fleet_traces_expose_every_replica(clear_dse_caches):
    sc = _fleet()
    env = _env(sc)
    traces = sc.traces(env.context(_FLEET_CFG))
    assert set(traces) == {"replica0", "replica1"}
    assert all(len(tr.ops) > 0 for tr in traces.values())


def test_fleet_invalid_partition_is_gated(clear_dse_caches):
    sc = _fleet(replicas=3)           # 1024 % 3 != 0
    ev = _env(sc).evaluate_config(_FLEET_CFG)
    assert not ev.valid
    assert "replica" in json.dumps(ev.detail)


def test_fleet_canonicalization_pins_dead_knobs():
    sc = _fleet()
    cfg = dict(router="prefix-hash", autoscale_target=0.0,
               autoscale_cooldown_s=30.0)
    canon = sc.canonical(cfg)
    assert canon["autoscale_cooldown_s"] == sc.autoscale_cooldowns_s[0]
    assert canon["router"] == "prefix-hash"     # live with 2 replicas
    one = _fleet(replicas=1)
    assert one.canonical(cfg)["router"] == one.routers[0]


def test_fleet_lint_info_surfaces_shape():
    info = _fleet(arrival="bursty").lint_info()
    assert info == {"replicas": 2, "arrival": "bursty",
                    "fleet_requests": 32}
    assert set(ROUTER_POLICIES) == {"round-robin", "least-outstanding",
                                    "prefix-hash"}


def test_prefix_affinity_router_gets_cache_hits(clear_dse_caches):
    """With few sessions and a prefix cache, session-sticky routing reuses
    prompt KV: effective prompt work drops vs round-robin scatter."""
    sc = _fleet(n_sessions=4, prefix_hit_frac=0.9, rate_rps=64.0)
    env = _env(sc)
    rr = env.evaluate_config(dict(_FLEET_CFG, router="round-robin"))
    ph = env.evaluate_config(dict(_FLEET_CFG, router="prefix-hash"))
    assert rr.valid and ph.valid
    # affinity routing can only help or tie aggregate service time here
    assert ph.detail["makespan_ms"] <= rr.detail["makespan_ms"] * 1.25
