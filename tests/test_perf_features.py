"""Beyond-paper perf features: chunked CE, save_kv remat, MoE dispatch
semantics, analyzer slice accounting."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.hlo_analysis import HloCostModel
from repro.models import model as M
from repro.models import moe as moem
from repro.models.layers import init_tree
from repro.parallel.sharding import NULL_PLAN
from repro.train.loss import chunked_cross_entropy, cross_entropy
from repro.train.train_step import RunConfig, init_train_state, make_train_step


def _batch(spec, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return {"inputs": rng.integers(0, spec.vocab_size, (b, s)).astype(np.int32),
            "labels": rng.integers(0, spec.vocab_size, (b, s)).astype(np.int32)}


def test_chunked_ce_matches_dense():
    b, s, d, v = 2, 32, 16, 64
    rng = jax.random.PRNGKey(0)
    hidden = jax.random.normal(rng, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.2
    labels = jax.random.randint(rng, (b, s), 0, v)
    dense = cross_entropy(hidden @ w, labels)
    for chunk in (4, 8, 32):
        ch = chunked_cross_entropy(hidden, lambda h: h @ w, labels, chunk=chunk)
        np.testing.assert_allclose(float(dense), float(ch), rtol=1e-6)


def test_chunked_ce_gradients_match():
    b, s, d, v = 2, 16, 8, 32
    hidden = jax.random.normal(jax.random.PRNGKey(0), (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.2
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    g1 = jax.grad(lambda w_: cross_entropy(hidden @ w_, labels))(w)
    g2 = jax.grad(lambda w_: chunked_cross_entropy(hidden, lambda h: h @ w_, labels, chunk=4))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("knobs", [
    dict(remat="save_kv"),
    dict(remat="full", loss_chunk=8),
    dict(remat="save_kv", loss_chunk=8, microbatches=2),
])
def test_train_step_variants_match_plain(knobs):
    """Every perf knob must be numerically equivalent to the plain step."""
    spec = reduced(ARCHS["qwen2-1.5b"], n_layers=2)
    batch = _batch(spec, 4, 32)
    rng = jax.random.PRNGKey(0)
    c0 = RunConfig(remat="none")
    c1 = RunConfig(remat="none").with_(**knobs)
    s0, m0 = jax.jit(make_train_step(spec, cfg=c0))(init_train_state(rng, spec, c0), batch)
    s1, m1 = jax.jit(make_train_step(spec, cfg=c1))(init_train_state(rng, spec, c1), batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s0["params"]), jax.tree.leaves(s1["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)


def test_moe_capacity_drops_and_aux():
    spec = reduced(ARCHS["granite-moe-3b-a800m"])
    p = init_tree(jax.random.PRNGKey(0), moem.moe_defs(spec))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, spec.d_model))
    # tight capacity must drop tokens; generous must not
    logits = jnp.einsum("bsd,de->bse", x.reshape(2, 64, -1), p["router"]).astype(jnp.float32)
    _, _, aux_tight = moem._dispatch_tensors(
        logits.reshape(2, 64, -1).reshape(2 * 64 // 64, 64, spec.n_experts), spec.top_k,
        spec.n_experts, cap=8)
    _, _, aux_loose = moem._dispatch_tensors(
        logits.reshape(2 * 64 // 64, 64, spec.n_experts), spec.top_k,
        spec.n_experts, cap=256)
    assert float(aux_tight["drop_frac"]) > 0.0
    assert float(aux_loose["drop_frac"]) == 0.0
    assert float(aux_loose["lb_loss"]) >= 1.0  # >= E * (1/E) at balance


def test_moe_group_size_alignment_fallback():
    """non-divisible group sizes fall back cleanly (tg halves until it
    divides the sequence)."""
    spec = reduced(ARCHS["granite-moe-3b-a800m"])
    p = init_tree(jax.random.PRNGKey(0), moem.moe_defs(spec))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, spec.d_model))  # 24 % 16 != 0
    y, aux = moem.moe_apply(p, x, spec, NULL_PLAN, group_size=16)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_dispatch_mask_stop_gradient():
    """routing gradients flow via combine only: grads wrt router exist, and
    the dispatch path contributes none."""
    spec = reduced(ARCHS["granite-moe-3b-a800m"])
    p = init_tree(jax.random.PRNGKey(0), moem.moe_defs(spec))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, spec.d_model))

    def f(params):
        y, _ = moem.moe_apply(params, x, spec, NULL_PLAN, capacity_factor=8.0)
        return jnp.sum(y * y)

    g = jax.grad(f)(p)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0  # via combine weights
    assert float(jnp.max(jnp.abs(g["w_down"]))) > 0


DUS_SNIPPET = """\
HloModule t

ENTRY %main (a: f32[64,64], u: f32[1,64]) -> f32[64,64] {
  %a = f32[64,64] parameter(0)
  %u = f32[1,64] parameter(1)
  %z = s32[] constant(0)
  ROOT %d = f32[64,64] dynamic-update-slice(%a, %u, %z, %z)
}
"""


def test_analyzer_dus_slice_accounting():
    t = HloCostModel(DUS_SNIPPET).analyze()
    # 2x the update slice (read update + write region), NOT the full buffer
    assert t.bytes_fused == 2 * 64 * 4


def test_flash_bwd_checkpoint_grads_finite():
    """gradient flows through the chunk-checkpointed flash scan."""
    from repro.models.attention import flash_attention_ref
    b, s, h, hd = 1, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.arange(s, dtype=jnp.int32)

    g = jax.grad(lambda q_: jnp.sum(flash_attention_ref(q_, k, v, pos, kv_chunk=32) ** 2))(q)
    assert bool(jnp.isfinite(g).all())
    # and matches dense-attention gradients
    from repro.kernels.ref import attention_ref
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    def dense(q_):
        qq = q_.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        o = attention_ref(qq, kr, vr, causal=True)
        return jnp.sum(o.reshape(b, h, s, hd).transpose(0, 2, 1, 3) ** 2)

    gd = jax.grad(dense)(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd), rtol=2e-4, atol=2e-5)


def test_engine_embeddings_frontend():
    from repro.serve.engine import Engine
    spec = reduced(ARCHS["musicgen-medium"], n_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), spec)
    # embeddings-frontend decode takes (B, D) embeddings per step; the
    # engine's token path is for 'tokens' archs — drive decode directly.
    b, s = 2, 8
    caches = M.init_caches(spec, b, 16, dtype=jnp.float32)
    prompt = jax.random.normal(jax.random.PRNGKey(1), (b, s, spec.d_model)) * 0.1
    logits, caches = M.prefill(params, prompt, caches, spec, compute_dtype=jnp.float32)
    emb = jax.random.normal(jax.random.PRNGKey(2), (b, spec.d_model)) * 0.1
    logits2, _ = M.decode_step(params, caches, emb, s, spec, compute_dtype=jnp.float32)
    assert logits2.shape == (b, spec.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
