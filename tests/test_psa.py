"""PsA schema + PSS deterministic tests (the hypothesis-driven properties
live in test_psa_properties.py behind an importorskip guard)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.psa import (Constraint, Parameter, ParameterSet, paper_psa,
                            pow2_range, table1_psa)
from repro.core.space import DesignSpace, constrained_parallelization_count


def test_paper_table1_counts():
    # "even with just four parallelization dimensions ... 286 combinations"
    assert constrained_parallelization_count(1024, 4) == 286
    # full Table-1 space: 7.69e13
    total = (constrained_parallelization_count(1024, 4) * 2  # weight sharded
             * 2 * 4 ** 4 * 32 * 2                            # collective stack
             * 3 ** 4 * 3 ** 4 * 5 ** 4)                      # network stack
    assert abs(total - 7.69e13) / 7.69e13 < 0.01


def test_cardinality_and_slots():
    ps = paper_psa(1024)
    ds = DesignSpace(ps)
    assert ds.n_genes() == 4 + 1 + 4 + 1 + 1 + 4 + 4 + 4
    assert ps.cardinality() > 1e12


def test_restrict_pins_other_stacks():
    ps = paper_psa(1024)
    defaults = dict(sched_policy="lifo", coll_algo=("ring",) * 4, chunks=4,
                    multidim_coll="baseline", topology=("ring",) * 4,
                    npus_per_dim=(4, 4, 8, 8), bw_per_dim=(100,) * 4)
    w = ps.restrict({"workload"}, defaults)
    ds = DesignSpace(w)
    assert {g.param for g in ds.genes} == {"dp", "pp", "sp", "weight_sharded"}
    cfg = ds.sample(np.random.default_rng(0))
    assert cfg["topology"] == ("ring",) * 4
    assert ds.is_valid(cfg)
    with pytest.raises(KeyError):
        ps.restrict({"workload"}, {})  # missing defaults must be an error


def test_repair_fixes_product_constraint():
    ds = DesignSpace(paper_psa(1024))
    rng = np.random.default_rng(0)
    bad = ds.sample(rng)
    bad = dict(bad, npus_per_dim=(16, 16, 16, 16))  # product 65536 != 1024
    assert not ds.is_valid(bad)
    fixed = ds.repair(bad, rng)
    assert ds.is_valid(fixed)


def test_duplicate_param_names_rejected():
    with pytest.raises(ValueError):
        ParameterSet([Parameter("x", "workload", (1, 2)),
                      Parameter("x", "network", (3, 4))])


def test_predicate_constraint():
    ps = ParameterSet(
        [Parameter("a", "workload", (1, 2, 4)), Parameter("b", "workload", (1, 2, 4))],
        [Constraint("predicate", fn=lambda c: c["a"] >= c["b"], name="a>=b")],
    )
    ds = DesignSpace(ps)
    for s in range(20):
        cfg = ds.sample(np.random.default_rng(s))
        assert cfg["a"] >= cfg["b"]


def test_pow2_range_validates_bounds():
    """Non-power-of-two bounds used to be silently truncated (1..1000 ->
    ..512); now they raise with the nearest powers named."""
    assert pow2_range(1, 1024) == tuple(2 ** i for i in range(11))
    assert pow2_range(4, 4) == (4,)
    with pytest.raises(ValueError, match="not a power of two"):
        pow2_range(1, 1000)
    with pytest.raises(ValueError, match="512 and 1024"):
        pow2_range(1, 1000)
    with pytest.raises(ValueError, match="not a power of two"):
        pow2_range(3, 8)
    with pytest.raises(ValueError, match="lo=16 > hi=8"):
        pow2_range(16, 8)
    with pytest.raises(ValueError, match="positive"):
        pow2_range(0, 8)


def test_sample_reports_persistent_violations():
    """An infeasible space names the failing constraints instead of a bare
    'could not sample' (satellite: infeasibility diagnostics)."""
    ps = ParameterSet(
        params=[Parameter("a", "workload", (2, 4)),
                Parameter("b", "workload", (2, 4))],
        constraints=[Constraint("product_eq", ("a", "b"), 7,
                                name="product(a,b) == 7")])
    ds = DesignSpace(ps)
    with pytest.raises(RuntimeError, match=r"product\(a,b\) == 7"):
        ds.sample(np.random.default_rng(0), max_tries=16)
    with pytest.raises(RuntimeError, match="16/16 tries"):
        ds.sample(np.random.default_rng(0), max_tries=16)


def test_pin_fixes_parameters():
    ps = paper_psa(1024)
    pinned = ps.pin({"chunks": 4, "sched_policy": "lifo",
                     "coll_algo": ["ring", "rhd", "ring", "dbt"]})
    ds = DesignSpace(pinned)
    assert "chunks" not in {g.param for g in ds.genes}
    cfg = ds.sample(np.random.default_rng(0))
    assert cfg["chunks"] == 4 and cfg["sched_policy"] == "lifo"
    assert cfg["coll_algo"] == ("ring", "rhd", "ring", "dbt")  # list coerced
    with pytest.raises(ValueError, match="unknown pinned parameter"):
        ps.pin({"not_a_param": 1})


def test_pin_rejects_out_of_domain_values():
    """A typo'd pin must not silently search outside the design space."""
    ps = paper_psa(1024)
    with pytest.raises(ValueError, match="outside the parameter's choices"):
        ps.pin({"chunks": 3})
    with pytest.raises(ValueError, match="outside the parameter's choices"):
        ps.pin({"sched_policy": "fifoo"})
    with pytest.raises(ValueError, match="4 values"):
        ps.pin({"coll_algo": ("ring", "ring")})          # wrong arity
    with pytest.raises(ValueError, match="4 values"):
        ps.pin({"coll_algo": ("ring", "ring", "ring", "rang")})
