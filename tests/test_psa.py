"""PsA schema + PSS deterministic tests (the hypothesis-driven properties
live in test_psa_properties.py behind an importorskip guard)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.psa import (Constraint, Parameter, ParameterSet, paper_psa,
                            pow2_range, table1_psa)
from repro.core.space import DesignSpace, constrained_parallelization_count


def test_paper_table1_counts():
    # "even with just four parallelization dimensions ... 286 combinations"
    assert constrained_parallelization_count(1024, 4) == 286
    # full Table-1 space: 7.69e13
    total = (constrained_parallelization_count(1024, 4) * 2  # weight sharded
             * 2 * 4 ** 4 * 32 * 2                            # collective stack
             * 3 ** 4 * 3 ** 4 * 5 ** 4)                      # network stack
    assert abs(total - 7.69e13) / 7.69e13 < 0.01


def test_cardinality_and_slots():
    ps = paper_psa(1024)
    ds = DesignSpace(ps)
    assert ds.n_genes() == 4 + 1 + 4 + 1 + 1 + 4 + 4 + 4
    assert ps.cardinality() > 1e12


def test_restrict_pins_other_stacks():
    ps = paper_psa(1024)
    defaults = dict(sched_policy="lifo", coll_algo=("ring",) * 4, chunks=4,
                    multidim_coll="baseline", topology=("ring",) * 4,
                    npus_per_dim=(4, 4, 8, 8), bw_per_dim=(100,) * 4)
    w = ps.restrict({"workload"}, defaults)
    ds = DesignSpace(w)
    assert {g.param for g in ds.genes} == {"dp", "pp", "sp", "weight_sharded"}
    cfg = ds.sample(np.random.default_rng(0))
    assert cfg["topology"] == ("ring",) * 4
    assert ds.is_valid(cfg)
    with pytest.raises(KeyError):
        ps.restrict({"workload"}, {})  # missing defaults must be an error


def test_repair_fixes_product_constraint():
    ds = DesignSpace(paper_psa(1024))
    rng = np.random.default_rng(0)
    bad = ds.sample(rng)
    bad = dict(bad, npus_per_dim=(16, 16, 16, 16))  # product 65536 != 1024
    assert not ds.is_valid(bad)
    fixed = ds.repair(bad, rng)
    assert ds.is_valid(fixed)


def test_duplicate_param_names_rejected():
    with pytest.raises(ValueError):
        ParameterSet([Parameter("x", "workload", (1, 2)),
                      Parameter("x", "network", (3, 4))])


def test_predicate_constraint():
    ps = ParameterSet(
        [Parameter("a", "workload", (1, 2, 4)), Parameter("b", "workload", (1, 2, 4))],
        [Constraint("predicate", fn=lambda c: c["a"] >= c["b"], name="a>=b")],
    )
    ds = DesignSpace(ps)
    for s in range(20):
        cfg = ds.sample(np.random.default_rng(s))
        assert cfg["a"] >= cfg["b"]
