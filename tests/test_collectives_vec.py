"""Vectorized-evaluation tests: the array collective evaluator against the
scalar oracle over the full model grid, the batched whole-population
duration pass against the scalar per-call pass (bit-identical), the
sub-network-carving memoization, and the one-scatter busy accounting."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import cache
from repro.core.backends.base import SimCall
from repro.core.collectives import (ALGO_IDS, ALGOS, COLL_KIND_IDS,
                                    COLL_KINDS, TOPO_KIND_IDS,
                                    collective_time_us, collective_time_vec,
                                    multidim_collective_time_us,
                                    multidim_collective_time_vec)
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.scenario import RequestStreamScenario
from repro.core.simulator import (SystemConfig, _group_net_cached,
                                  _pool_group_dims_cached, group_dims,
                                  plan_duration_tables, plan_durations,
                                  plan_durations_batch, pool_group_dims,
                                  _sim_plan)
from repro.core.systems import system_env
from repro.core.topology import (TOPO_KINDS, Network, TopoDim, carve_dims,
                                 system_2)
from repro.core.workload import Parallelism, generate_trace

RTOL = 1e-9


def _rel(a, b):
    return np.abs(a - b) / np.maximum(np.abs(b), 1e-12)


# ---------------------------------------------------------------------------
# single-dim evaluator: full kind x algo x topo x chunks grid, random dims
# ---------------------------------------------------------------------------

def test_collective_time_vec_full_grid_parity():
    rng = np.random.default_rng(0)
    scalar, kind_id, size, n, bw, lat, topo_id, algo_id, chunks = \
        [], [], [], [], [], [], [], [], []
    for kind in COLL_KINDS:
        for algo in ALGOS:
            for topo in TOPO_KINDS:
                for c in (1, 2, 7, 16):
                    for _ in range(3):
                        npus = int(rng.choice((2, 3, 4, 5, 7, 8, 16, 27, 64)))
                        b = float(rng.uniform(10.0, 900.0))
                        l = float(rng.uniform(0.05, 2.0))
                        sz = float(rng.uniform(1.0, 1e9))
                        dim = TopoDim(topo, npus, b, l)
                        scalar.append(collective_time_us(kind, sz, dim,
                                                         algo, c))
                        kind_id.append(COLL_KIND_IDS[kind])
                        size.append(sz)
                        n.append(npus)
                        bw.append(b)
                        lat.append(l)
                        topo_id.append(TOPO_KIND_IDS[topo])
                        algo_id.append(ALGO_IDS[algo])
                        chunks.append(c)
    got = collective_time_vec(np.array(kind_id), np.array(size), np.array(n),
                              np.array(bw), np.array(lat), np.array(topo_id),
                              np.array(algo_id), np.array(chunks))
    assert got.shape == (len(scalar),)
    assert np.all(_rel(got, np.array(scalar)) < RTOL)


def test_collective_time_vec_degenerate_entries_are_exact_zero():
    """npus <= 1 (padded slots) and size <= 0 price to exactly 0.0 — the
    padding contract the packed class tables rely on."""
    got = collective_time_vec(
        np.array([0, 1, 2]), np.array([1e6, 0.0, 1e6]),
        np.array([1.0, 8.0, 1.0]), np.array([100.0] * 3),
        np.array([0.5] * 3), np.array([0, 1, 2]), np.array([0, 1, 2]),
        np.array([2, 2, 2]))
    assert np.array_equal(got, np.zeros(3))


# ---------------------------------------------------------------------------
# multi-dim evaluator: random fabrics, both modes, partial carves,
# residual virtual dims
# ---------------------------------------------------------------------------

def _pack_dims(carved, coll_algo):
    """Pad one carved-dims row the way ``_pack_class_tables`` does,
    resolving per-dim algorithms against source physical dims."""
    D = max(len(carved), 1)
    npus = np.ones(D)
    bw = np.ones(D)
    lat = np.zeros(D)
    topo = np.zeros(D, dtype=np.int32)
    algo = np.zeros(D, dtype=np.int32)
    for j, (src, d) in enumerate(carved):
        npus[j] = d.npus
        bw[j] = d.bw
        lat[j] = d.latency_us
        topo[j] = TOPO_KIND_IDS[d.kind]
        algo[j] = ALGO_IDS[coll_algo[src]]
    return npus, bw, lat, topo, algo


def test_multidim_vec_parity_random_sweep():
    """Randomized full sweep vs the scalar oracle: every collective kind,
    per-dim algo mix, both decomposition modes, chunk grid, over random
    carvings (gcd-partial dims AND residual virtual dims)."""
    import math

    rng = np.random.default_rng(7)
    rows, scalars = [], []
    n_residual = n_partial = 0
    for trial in range(200):
        ndim = int(rng.integers(2, 5))
        kinds = [str(rng.choice(TOPO_KINDS)) for _ in range(ndim)]
        npus = [int(rng.choice((2, 4, 8))) for _ in range(ndim)]
        bws = [float(rng.uniform(25.0, 900.0)) for _ in range(ndim)]
        lats = [float(rng.uniform(0.1, 1.5)) for _ in range(ndim)]
        net = Network(tuple(TopoDim(k, n, b, l)
                            for k, n, b, l in zip(kinds, npus, bws, lats)))
        coll_algo = tuple(str(rng.choice(ALGOS)) for _ in range(ndim))
        # group sizes with non-power-of-two factors exercise the residual
        # virtual dim (a factor no physical dim covers) and partial carves
        need = int(rng.choice((2, 3, 4, 6, 8, 12, 24, 48, 96)))
        carved = carve_dims(net.dims, [d.npus for d in net.dims], need)
        if not carved:
            continue
        rem = need
        for i in range(ndim):  # residual factor no physical dim covers?
            if rem <= 1:
                break
            g = math.gcd(rem, npus[i])
            rem //= g
        n_residual += rem > 1
        n_partial += any(d.npus < net.dims[src].npus for src, d in carved)
        kind = str(rng.choice(COLL_KINDS))
        chunks = int(rng.choice((1, 2, 4, 16)))
        mode = str(rng.choice(("baseline", "blueconnect")))
        size = float(rng.uniform(1e3, 1e9))
        sub = Network(tuple(d for _, d in carved))
        algos = tuple(coll_algo[src] for src, _ in carved)
        scalars.append(multidim_collective_time_us(kind, size, sub, algos,
                                                   chunks=chunks, mode=mode))
        rows.append((_pack_dims(carved, coll_algo), kind, size, chunks, mode))
    assert len(rows) >= 150
    # the sweep must actually exercise both carving edge cases
    assert n_residual >= 10 and n_partial >= 10
    D = max(len(r[0][0]) for r in rows)
    P = len(rows)
    npus = np.ones((P, D))
    bw = np.ones((P, D))
    lat = np.zeros((P, D))
    topo = np.zeros((P, D), dtype=np.int32)
    algo = np.zeros((P, D), dtype=np.int32)
    kind_id = np.zeros(P, dtype=np.int32)
    size = np.zeros(P)
    chunks = np.zeros(P)
    blue = np.zeros(P, dtype=bool)
    for i, ((n_, b_, l_, t_, a_), kind, sz, c, mode) in enumerate(rows):
        w = len(n_)
        npus[i, :w], bw[i, :w], lat[i, :w] = n_, b_, l_
        topo[i, :w], algo[i, :w] = t_, a_
        kind_id[i] = COLL_KIND_IDS[kind]
        size[i] = sz
        chunks[i] = c
        blue[i] = mode == "blueconnect"
    got = multidim_collective_time_vec(kind_id, size, npus, bw, lat, topo,
                                       algo, chunks, blue)
    assert np.all(_rel(got, np.array(scalars)) < RTOL)


def test_multidim_vec_residual_virtual_dim_and_single_dim():
    """Pinned structural cases: a residual factor becomes a virtual dim at
    the outermost tier (and is priced, not free); a single active dim
    bypasses the cross-dim pipelining entirely."""
    net = Network((TopoDim("ring", 4, 200.0, 0.5),
                   TopoDim("switch", 8, 50.0, 1.0)))
    carved = carve_dims(net.dims, [4, 8], 96)  # 96 = 4*8*3 -> residual 3
    assert [d.npus for _, d in carved] == [4, 8, 3]
    assert carved[-1] == (1, TopoDim("switch", 3, 50.0, 1.0))
    coll_algo = ("ring", "rhd")
    for kind in COLL_KINDS:
        for mode in ("baseline", "blueconnect"):
            sub = Network(tuple(d for _, d in carved))
            algos = tuple(coll_algo[src] for src, _ in carved)
            want = multidim_collective_time_us(kind, 1e7, sub, algos,
                                               chunks=4, mode=mode)
            n_, b_, l_, t_, a_ = _pack_dims(carved, coll_algo)
            got = multidim_collective_time_vec(
                np.array([COLL_KIND_IDS[kind]]), np.array([1e7]),
                n_[None], b_[None], l_[None], t_[None], a_[None],
                np.array([4.0]), np.array([mode == "blueconnect"]))
            assert float(_rel(got[0], want)) < RTOL, (kind, mode)
            assert want > 0.0
    # one active dim (others padded): == the bare single-dim collective
    one = _pack_dims(carved[:1], coll_algo)
    pad = [np.concatenate([x, np.ones(2) if x.dtype == np.float64 and i < 2
                           else np.zeros(2, x.dtype)])
           for i, x in enumerate(one)]
    got = multidim_collective_time_vec(
        np.array([COLL_KIND_IDS["all_gather"]]), np.array([1e7]),
        pad[0][None], pad[1][None], pad[2][None],
        pad[3][None].astype(np.int32), pad[4][None].astype(np.int32),
        np.array([4.0]), np.array([False]))
    want = collective_time_us("all_gather", 1e7, carved[0][1], "ring", 4)
    assert float(_rel(got[0], want)) < RTOL


# ---------------------------------------------------------------------------
# batched duration pass == scalar per-call pass, bit for bit
# ---------------------------------------------------------------------------

def _cfgs_population():
    """A population varying every duration-relevant knob (algos, chunks,
    decomposition mode, policy)."""
    out = []
    for algos, chunks, mode, policy in (
            (("ring", "direct", "ring", "rhd"), 2, "baseline", "fifo"),
            (("dbt", "rhd", "direct", "ring"), 8, "blueconnect", "lifo"),
            (("direct", "direct", "dbt", "dbt"), 1, "baseline", "lifo"),
            (("rhd", "ring", "rhd", "ring"), 16, "blueconnect", "fifo")):
        out.append(SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                                coll_algo=algos, chunks=chunks,
                                multidim_coll=mode, sched_policy=policy))
    return out


def test_plan_durations_batch_bit_identical_train_trace():
    par = Parallelism(1024, 64, 4, 1, True)
    tr = generate_trace(ARCHS["qwen2-1.5b"], par, batch=256, seq=1024)
    calls = [SimCall(tr, cfg, par) for cfg in _cfgs_population()]
    plan, dur = plan_durations_batch(tr, calls)
    assert dur.shape == (len(calls), plan.n_ops)
    for k, call in enumerate(calls):
        _, want = plan_durations(tr, call.cfg, call.par, call.pools)
        assert np.array_equal(dur[k], want), k  # bit-identical, not approx


def test_plan_durations_batch_bit_identical_stream_trace_with_xfer():
    """The multi-pool pipelined request-stream trace: delay ops, partial
    pool carvings, and cross-pool transfer classes all ride the batched
    pass bit-identically."""
    sc = RequestStreamScenario(n_requests=16, seq=512, decode_tokens=8,
                               rate_rps=16.0, seed=3)
    env = system_env("qwen2-1.5b", "system2", scenario=sc,
                     objective="goodput")
    base = dict(dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
                coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
                multidim_coll="baseline",
                topology=("ring", "fc", "ring", "switch"),
                npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100),
                prefill_frac=0.5, decode_batch=4, batch_window_ms=50.0,
                max_inflight=2)
    jobs = [env.scenario.sim_job(env.context(dict(base, chunks=c,
                                                  multidim_coll=m)))
            for c, m in ((2, "baseline"), (8, "blueconnect"),
                         (16, "baseline"))]
    calls = [c for j in jobs for c in j.calls]
    tr = calls[0].trace
    assert all(c.trace is tr for c in calls)  # one shared plan
    assert any(c.pools for c in calls)
    plan, dur = plan_durations_batch(tr, calls)
    # the coverage this test exists for: transfer classes and delay ops
    assert any(group == "xfer" for _p, group, _c, _s in plan.coll_shapes)
    assert plan.delay_ops
    for k, call in enumerate(calls):
        _, want = plan_durations(tr, call.cfg, call.par, call.pools)
        assert np.array_equal(dur[k], want), k


# ---------------------------------------------------------------------------
# sub-network carving memoization
# ---------------------------------------------------------------------------

def test_carving_caches_hit_across_population_and_batches():
    """A population re-pricing one fabric resolves the carving once:
    ``group_dims`` / ``_group_net_cached`` / ``_pool_group_dims_cached``
    all hit, and the per-plan pack memo shares the class tables between
    calls that differ only in chunks/mode/policy."""
    assert cache.caches_enabled()
    par = Parallelism(1024, 64, 4, 1, True)
    # clear FIRST: generate_trace memoizes, and the plan (piggybacked on
    # the trace) would carry pack tables resolved by earlier tests
    cache.clear_all_caches()
    tr = generate_trace(ARCHS["qwen2-1.5b"], par, batch=256, seq=1024)
    plan = _sim_plan(tr)
    cfgs = _cfgs_population()
    h0 = (group_dims.cache_info().hits,
          _group_net_cached.cache_info().hits,
          _pool_group_dims_cached.cache_info().hits)
    pool_group_dims(plan, cfgs[0], par, None)
    pool_group_dims(plan, cfgs[0], par, None)  # same key -> pure hit
    assert _pool_group_dims_cached.cache_info().hits == h0[2] + 1
    assert group_dims.cache_info().misses >= 1
    # the group -> dims carve itself memoizes on the frozen (net, par) key
    group_dims(cfgs[0].network, par)
    assert group_dims.cache_info().hits > h0[0]
    # the whole population shares one fabric: every member's carve resolves
    # from cache (the outer pool-entries layer, plus the per-group algo
    # resolution shared by the many duration classes of each member)
    calls = [SimCall(tr, cfg, par) for cfg in cfgs]
    h1 = _pool_group_dims_cached.cache_info().hits
    plan_duration_tables(tr, calls)
    assert _pool_group_dims_cached.cache_info().hits >= h1 + len(calls)
    assert _group_net_cached.cache_info().hits > h0[1]
    # per-plan pack memo: identical (network, coll_algo, pools) keys share
    # ONE packed table object across differing chunks/mode/policy
    same_carve = [SimCall(tr, SystemConfig(network=system_2(),
                                           device=SYSTEM_2_DEVICE,
                                           coll_algo=("ring",) * 4,
                                           chunks=c, sched_policy=p), par)
                  for c, p in ((1, "fifo"), (4, "lifo"), (16, "fifo"))]
    from repro.core.simulator import _pack_class_tables
    packs = [_pack_class_tables(plan, c.cfg, c.par, c.pools)
             for c in same_carve]
    assert packs[0] is packs[1] is packs[2]


# ---------------------------------------------------------------------------
# busy accounting: the one-scatter 2D np.add.at == per-call bincount
# ---------------------------------------------------------------------------

def test_busy_scatter_both_orientations_match_bincount():
    """Both broadcast orientations of the (population, resource) scatter
    accumulate each cell in increasing-uid order — exactly the order of the
    per-call ``np.bincount`` they replaced — so all three are bit-identical
    even where float addition would not commute."""
    rng = np.random.default_rng(11)
    P, n_ops, n_res = 6, 4000, 13
    dur = rng.uniform(0.0, 1e6, size=(P, n_ops))
    res_of = rng.integers(0, n_res, size=n_ops)
    want = np.stack([np.bincount(res_of, weights=dur[k], minlength=n_res)
                     for k in range(P)])
    pop_major = np.zeros((P, n_res))
    np.add.at(pop_major, (np.arange(P)[:, None], res_of[None, :]), dur)
    op_major = np.zeros((P, n_res))
    np.add.at(op_major.T, (res_of[:, None], np.arange(P)[None, :]), dur.T)
    assert np.array_equal(pop_major, want)
    assert np.array_equal(op_major, want)


# ---------------------------------------------------------------------------
# fused vs unfused backends (jax-guarded, like test_backends)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.core.backends import get_backend, list_backends  # noqa: E402


def test_unfused_backend_registered():
    assert {"jax", "jax-unfused"} <= set(list_backends())
    jb, ub = get_backend("jax"), get_backend("jax-unfused")
    assert jb.fused and jb.name == "jax"
    assert not ub.fused and ub.name == "jax-unfused"
    assert jb is not ub


def test_fused_matches_unfused_and_single():
    """The fused backend (durations priced inside the compiled sweep) and
    the unfused baseline (scalar duration pass feeding the same sweep)
    agree to float64 tolerance; each backend's batch == its own single."""
    par = Parallelism(1024, 64, 4, 1, True)
    tr = generate_trace(ARCHS["qwen2-1.5b"], par, batch=256, seq=1024)
    calls = [SimCall(tr, cfg, par) for cfg in _cfgs_population()]
    fused = get_backend("jax").simulate_batch(tr, calls)
    unfused = get_backend("jax-unfused").simulate_batch(tr, calls)
    for k, call in enumerate(calls):
        rel = _rel(fused[k].makespan_us, unfused[k].makespan_us)
        assert float(rel) < RTOL, k
        one = get_backend("jax-unfused").simulate(tr, call.cfg, call.par)
        assert unfused[k].makespan_us == one.makespan_us
        assert unfused[k].comm_busy_us == one.comm_busy_us
        for res, busy in unfused[k].comm_busy_us.items():
            assert float(_rel(fused[k].comm_busy_us[res], busy)) < RTOL
    # the timing split is populated either way (the benchmark reads it)
    assert set(get_backend("jax").last_timings) == {"durations_s", "sweep_s"}
    assert set(get_backend("jax-unfused").last_timings) == \
        {"durations_s", "sweep_s"}
