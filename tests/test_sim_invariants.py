"""Deterministic event-simulator invariants (no hypothesis dependency —
these must run everywhere the tier-1 suite runs).

Covers: makespan lower bounds, exposed-communication accounting, and the
scheduling-policy knob (LIFO vs FIFO may only diverge under queue
contention)."""
from __future__ import annotations

import pytest

from repro.configs import ARCHS
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.simulator import SimResult, SystemConfig, simulate
from repro.core.topology import build_network
from repro.core.workload import Op, Parallelism, Trace, generate_trace

NET = build_network(("ring", "fc", "ring", "switch"), (4, 8, 4, 8),
                    (400.0, 200.0, 150.0, 100.0))


def _cfg(policy: str = "fifo", multidim: str = "baseline") -> SystemConfig:
    return SystemConfig(network=NET, device=SYSTEM_2_DEVICE,
                        coll_algo=("ring", "direct", "ring", "rhd"),
                        chunks=2, sched_policy=policy, multidim_coll=multidim)


CASES = [
    ("gpt3-13b", Parallelism(1024, dp=8, sp=2, pp=1), "train"),
    ("gpt3-13b", Parallelism(1024, dp=64, sp=1, pp=2, weight_sharded=True), "train"),
    ("gpt3-175b", Parallelism(1024, dp=4, sp=4, pp=4), "train"),
    ("gpt3-13b", Parallelism(1024, dp=16, sp=1, pp=1), "inference"),
    ("gpt3-13b", Parallelism(1024, dp=16, sp=1, pp=1), "decode"),
]


def _check_accounting(res: SimResult):
    assert res.makespan_us > 0
    assert res.makespan_us >= res.compute_busy_us - 1e-9
    for group, busy in res.comm_busy_us.items():
        assert res.makespan_us >= busy - 1e-9, group
    # exposed communication is exactly the non-compute part of the makespan
    assert res.exposed_comm_us == pytest.approx(
        res.makespan_us - res.compute_busy_us, abs=1e-9)


@pytest.mark.parametrize("arch,par,mode", CASES)
@pytest.mark.parametrize("policy", ["fifo", "lifo"])
@pytest.mark.parametrize("multidim", ["baseline", "blueconnect"])
def test_makespan_bounds_real_traces(arch, par, mode, policy, multidim):
    trace = generate_trace(ARCHS[arch], par, batch=1024, seq=2048, mode=mode)
    res = simulate(trace, _cfg(policy, multidim), par)
    _check_accounting(res)


# two comm ops race for the dp engine; a compute op depends on the small one
_PAR = Parallelism(16, dp=4, sp=1, pp=1)  # tp=4 -> dims for tp and dp groups


def _contended_trace() -> Trace:
    return Trace([
        Op(0, "big.ar", "coll", [], coll="all_reduce", size_bytes=1e9, group="dp"),
        Op(1, "small.ar", "coll", [], coll="all_reduce", size_bytes=1e6, group="dp"),
        Op(2, "tail.comp", "comp", [1], flops=1e9, bytes=1e6),
    ])


def _chain_trace() -> Trace:
    return Trace([
        Op(0, "big.ar", "coll", [], coll="all_reduce", size_bytes=1e9, group="dp"),
        Op(1, "small.ar", "coll", [0], coll="all_reduce", size_bytes=1e6, group="dp"),
        Op(2, "tail.comp", "comp", [1], flops=1e9, bytes=1e6),
    ])


def test_lifo_beats_fifo_under_contention():
    """With both collectives queued at t=0, LIFO services the freshest
    (small, critical-path) one first and unblocks the tail compute early."""
    fifo = simulate(_contended_trace(), _cfg("fifo"), _PAR)
    lifo = simulate(_contended_trace(), _cfg("lifo"), _PAR)
    _check_accounting(fifo)
    _check_accounting(lifo)
    assert lifo.makespan_us < fifo.makespan_us
    # same total work either way
    assert lifo.comm_busy_us == pytest.approx(fifo.comm_busy_us)


def test_policies_identical_without_contention():
    """A pure dependency chain never queues two ready ops on one resource,
    so the scheduling policy cannot change the schedule."""
    fifo = simulate(_chain_trace(), _cfg("fifo"), _PAR)
    lifo = simulate(_chain_trace(), _cfg("lifo"), _PAR)
    assert fifo.makespan_us == lifo.makespan_us
    assert fifo.compute_busy_us == lifo.compute_busy_us
    assert fifo.comm_busy_us == lifo.comm_busy_us


def test_policies_identical_on_uncontended_real_trace():
    """dp=1 kills the gradient collectives' contention in a 1-stage trace:
    what remains is (mostly) a chain, and both policies must agree on every
    case where no queue ever holds two ops."""
    par = Parallelism(1024, dp=1, sp=1, pp=1)  # tp=1024: pure tp chain
    trace = generate_trace(ARCHS["gpt3-13b"], par, batch=1024, seq=2048)
    fifo = simulate(trace, _cfg("fifo"), par)
    lifo = simulate(trace, _cfg("lifo"), par)
    assert fifo.makespan_us == lifo.makespan_us
