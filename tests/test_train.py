"""Training substrate: optimizer math, microbatch equivalence, schedules,
loss behaviour."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.train import optimizer as opt
from repro.train.loss import cross_entropy
from repro.train.train_step import RunConfig, init_train_state, make_train_step


def test_lr_schedule_shape():
    cfg = opt.OptConfig(lr=1e-3, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1e-3) < 1e-9
    assert abs(lrs[-1] - 1e-4) < 1e-8  # floor at min_lr_ratio * lr
    peak = int(np.argmax(lrs))
    assert all(lrs[i] >= lrs[i + 1] for i in range(peak, len(lrs) - 1))


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([10.0, -10.0])}
    state = opt.init_state(params)
    cfg = opt.OptConfig(lr=0.5, warmup_steps=0, decay_steps=10**9, weight_decay=0.0)
    for _ in range(60):
        grads = {"w": state["params"]["w"]}  # grad of 0.5*w^2
        state, m = opt.apply_updates(state, grads, cfg)
    assert float(jnp.max(jnp.abs(state["params"]["w"]))) < 1.0
    assert m["grad_norm"] > 0


def test_grad_clip():
    params = {"w": jnp.zeros((4,))}
    state = opt.init_state(params)
    cfg = opt.OptConfig(lr=1e-3, warmup_steps=0, grad_clip=1.0)
    _, m = opt.apply_updates(state, {"w": jnp.full((4,), 1e6)}, cfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_cross_entropy_perfect_prediction():
    logits = jnp.full((1, 3, 5), -20.0)
    labels = jnp.asarray([[1, 2, 3]], jnp.int32)
    logits = logits.at[0, 0, 1].set(20.0).at[0, 1, 2].set(20.0).at[0, 2, 3].set(20.0)
    assert float(cross_entropy(logits, labels)) < 1e-3


def test_cross_entropy_ignore_index():
    logits = jnp.zeros((1, 2, 4))
    labels = jnp.asarray([[1, -1]], jnp.int32)
    expect = float(jnp.log(jnp.asarray(4.0)))
    assert abs(float(cross_entropy(logits, labels)) - expect) < 1e-5


def test_microbatch_equivalence():
    """mb=1 vs mb=4 must produce (near-)identical updates for mean-CE."""
    spec = reduced(ARCHS["musicgen-medium"])  # dense arch: no MoE aux noise
    rng = jax.random.PRNGKey(0)
    b, s = 8, 16
    batch = {
        "inputs": np.random.default_rng(0).standard_normal((b, s, spec.d_model)).astype(np.float32),
        "labels": np.random.default_rng(1).integers(0, spec.vocab_size, (b, s)).astype(np.int32),
    }
    cfg1 = RunConfig(remat="none", microbatches=1)
    cfg4 = RunConfig(remat="none", microbatches=4)
    state = init_train_state(rng, spec, cfg1)
    s1, m1 = jax.jit(make_train_step(spec, cfg=cfg1))(state, batch)
    state = init_train_state(rng, spec, cfg4)
    s4, m4 = jax.jit(make_train_step(spec, cfg=cfg4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-6)


def test_loss_decreases_over_steps():
    spec = reduced(ARCHS["qwen2-1.5b"], n_layers=2)
    from repro.data.pipeline import DataConfig, SyntheticLM
    data = SyntheticLM(spec, DataConfig(global_batch=8, seq_len=32, seed=0))
    cfg = RunConfig(remat="none", opt=opt.OptConfig(lr=6e-3, warmup_steps=5))
    state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
    step = jax.jit(make_train_step(spec, cfg=cfg))
    losses = []
    for i in range(60):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.08, losses


def test_mixed_precision_state_layout():
    spec = reduced(ARCHS["qwen2-1.5b"], n_layers=1)
    cfg = RunConfig(compute_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
    state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
    assert "master" in state
    assert jax.tree.leaves(state["params"])[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(state["master"])[0].dtype == jnp.float32
    assert jax.tree.leaves(state["m"])[0].dtype == jnp.float32
