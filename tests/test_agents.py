"""Search agents: interface compliance + learning behaviour on the real env."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.agents import make_agent
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.dse import run_search
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.space import DesignSpace


def _env():
    return CosmicEnv(spec=ARCHS["gpt3-13b"], n_npus=1024, device=SYSTEM_2_DEVICE,
                     batch=1024, seq=2048)


@pytest.mark.parametrize("kind", ["rw", "ga", "aco", "bo"])
def test_agent_runs_and_proposes_valid(kind):
    space = DesignSpace(paper_psa(1024))
    agent = make_agent(kind, space, seed=0)
    env = _env()
    for _ in range(30 if kind != "bo" else 22):
        cfg = agent.propose()
        assert space.is_valid(cfg)
        ev = env.step(cfg)
        agent.observe(cfg, ev.reward)
    assert agent.best_config is not None
    assert agent.best_reward >= 0


def test_learning_agents_beat_random_walk():
    steps, seeds = 300, (0, 1, 2)
    def best(kind, seed):
        return run_search(paper_psa(1024), _env(), kind, steps=steps, seed=seed).best_reward
    rw = np.mean([best("rw", s) for s in seeds])
    ga = np.mean([best("ga", s) for s in seeds])
    aco = np.mean([best("aco", s) for s in seeds])
    # history-aware agents should find better optima on average at this budget
    assert max(ga, aco) > rw
    assert min(ga, aco) >= rw * 0.7  # and never collapse far below baseline


def test_reward_curve_monotone_nondecreasing():
    res = run_search(paper_psa(1024), _env(), "ga", steps=80, seed=0)
    c = res.reward_curve
    assert all(c[i + 1] >= c[i] for i in range(len(c) - 1))
    assert res.steps_to_peak <= res.steps


def test_aco_pheromones_update():
    space = DesignSpace(paper_psa(1024))
    agent = make_agent("aco", space, seed=0)
    before = [t.copy() for t in agent.tau]
    cfg = agent.propose()
    agent.observe(cfg, 1.0)
    changed = any(not np.allclose(b, a) for b, a in zip(before, agent.tau))
    assert changed


def test_bo_uses_surrogate_after_init():
    space = DesignSpace(paper_psa(1024))
    agent = make_agent("bo", space, seed=0, n_init=5, candidates=32)
    rng = np.random.default_rng(0)
    for i in range(8):
        cfg = agent.propose()
        agent.observe(cfg, float(rng.random()))
    assert len(agent.X) == 8
    cfg = agent.propose()  # surrogate path
    assert space.is_valid(cfg)
