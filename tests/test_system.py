"""End-to-end system behaviour: the paper's full loop (PsA -> PSS -> agents
-> simulator -> discovered design -> executable mesh plan), the training
loop with fault drills, and the serving engine."""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.bridge import design_from_mesh, plan_from_design
from repro.core.compute import SYSTEM_1_DEVICE, SYSTEM_2_DEVICE
from repro.core.dse import run_search
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.workload import Parallelism
from repro.models import model as M

REPO = Path(__file__).resolve().parent.parent


def test_full_stack_beats_single_stack():
    """The paper's headline claim, at test scale: full-stack DSE finds a
    design at least as good as workload-only DSE (strictly better in the
    evaluation benchmarks with bigger budgets)."""
    pset = paper_psa(1024)
    defaults = dict(sched_policy="fifo", coll_algo=("ring",) * 4, chunks=2,
                    multidim_coll="baseline", topology=("ring", "ring", "ring", "switch"),
                    npus_per_dim=(4, 8, 4, 8), bw_per_dim=(100,) * 4)
    wl_only = pset.restrict({"workload"}, defaults)

    def search(ps, seed):
        env = CosmicEnv(spec=ARCHS["gpt3-13b"], n_npus=1024, device=SYSTEM_2_DEVICE,
                        batch=1024, seq=2048)
        return run_search(ps, env, "ga", steps=250, seed=seed).best_reward

    full = max(search(pset, s) for s in (0, 1))
    single = max(search(wl_only, s) for s in (0, 1))
    assert full >= single


def test_discovered_design_is_executable():
    """bridge: a COSMIC workload design point maps onto a jax mesh plan."""
    par = Parallelism(1024, dp=64, sp=4, pp=1, weight_sharded=True)
    plan = plan_from_design(par)
    assert np.prod(plan.shape) == par.dp * par.sp * par.tp
    assert plan.fsdp
    rt = design_from_mesh({"data": 16, "model": 16}, weight_sharded=True)
    assert rt.n_npus == 256 and rt.dp == 16 and rt.tp == 16


def test_agents_find_multiple_distinct_optima():
    """Fig. 9: different agents converge to distinct configs of similar
    quality (design-space redundancy)."""
    env_fn = lambda: CosmicEnv(spec=ARCHS["gpt3-13b"], n_npus=1024,
                               device=SYSTEM_2_DEVICE, batch=1024, seq=2048)
    pset = paper_psa(1024)
    results = {k: run_search(pset, env_fn(), k, steps=150, seed=3) for k in ("ga", "aco")}
    rewards = [r.best_reward for r in results.values()]
    assert all(r > 0 for r in rewards)
    # similar quality (within 10x of each other)...
    assert max(rewards) / max(min(rewards), 1e-30) < 10.0


def _run_train(args: list[str]) -> subprocess.CompletedProcess:
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        capture_output=True, text=True, timeout=900, env=env)


@pytest.mark.slow
def test_train_failure_and_resume(tmp_path):
    base = ["--arch", "qwen2-1.5b", "--reduced", "--steps", "20", "--batch", "2",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "50"]
    crash = _run_train(base + ["--fail-at", "12"])
    assert crash.returncode != 0
    assert "injected failure at step 12" in crash.stderr
    resume = _run_train(base)
    assert resume.returncode == 0, resume.stderr[-2000:]
    assert "resumed from step 10" in resume.stdout
    assert "done at step 20" in resume.stdout


def test_serving_engine_generates():
    from repro.serve.engine import Engine
    spec = reduced(ARCHS["qwen2-1.5b"], n_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), spec)
    eng = Engine(spec, params, max_len=64)
    prompts = np.random.default_rng(0).integers(0, spec.vocab_size, (2, 8)).astype(np.int32)
    out, stats = eng.generate(prompts, max_new=8)
    assert out.shape == (2, 8)
    assert stats.tokens_out == 16
    # greedy decode is deterministic
    out2, _ = eng.generate(prompts, max_new=8)
    np.testing.assert_array_equal(out, out2)
