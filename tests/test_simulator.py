"""Collective cost models, WTG, memory model, and event-sim invariants
(deterministic; the hypothesis-driven properties live in
test_simulator_properties.py behind an importorskip guard)."""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.collectives import (collective_time_us,
                                    multidim_collective_time_us)
from repro.core.compute import SYSTEM_2_DEVICE, TPU_V5E, Device
from repro.core.memory import fits, footprint
from repro.core.rewards import evaluate
from repro.core.simulator import SystemConfig, group_dims, simulate
from repro.core.topology import (Network, TopoDim, build_network, system_1,
                                 system_2, system_3, tpu_v5e_pod)
from repro.core.workload import Parallelism, generate_trace

DIM = TopoDim("ring", 8, 100.0)


def test_allreduce_costs_twice_reduce_scatter_bandwidth():
    t_ar = collective_time_us("all_reduce", 1e9, DIM, "ring")
    t_rs = collective_time_us("reduce_scatter", 1e9, DIM, "ring")
    assert 1.8 < t_ar / t_rs < 2.2


def test_bandwidth_scaling():
    fast = TopoDim("ring", 8, 400.0)
    slow = TopoDim("ring", 8, 100.0)
    assert collective_time_us("all_reduce", 1e9, fast, "ring") < \
        collective_time_us("all_reduce", 1e9, slow, "ring")


def test_latency_vs_bandwidth_algorithms():
    """Small messages favour latency-optimized algorithms (direct/RHD),
    large messages favour ring — the paper's Experiment-2 observation."""
    sw = TopoDim("switch", 16, 100.0)
    small, large = 4e3, 4e9
    t_small = {a: collective_time_us("all_reduce", small, sw, a)
               for a in ("ring", "direct", "rhd")}
    t_large = {a: collective_time_us("all_reduce", large, sw, a)
               for a in ("ring", "direct", "rhd")}
    assert t_small["rhd"] < t_small["ring"]
    assert t_small["direct"] < t_small["ring"]
    assert t_large["ring"] <= t_large["direct"] * 1.05


def test_direct_on_fc_beats_direct_on_ring():
    fc = TopoDim("fc", 8, 100.0)
    ri = TopoDim("ring", 8, 100.0)
    assert collective_time_us("all_reduce", 1e8, fc, "direct") < \
        collective_time_us("all_reduce", 1e8, ri, "direct")


def test_blueconnect_not_slower_hierarchical():
    net = build_network(("ring", "switch"), (8, 8), (100, 100))
    base = multidim_collective_time_us("all_reduce", 1e9, net, ("ring", "ring"),
                                       chunks=4, mode="baseline")
    bc = multidim_collective_time_us("all_reduce", 1e9, net, ("ring", "ring"),
                                     chunks=4, mode="blueconnect")
    assert bc <= base * 1.01


def test_chunking_tradeoff():
    """More chunks -> more latency overhead on a single dim."""
    t1 = collective_time_us("all_reduce", 1e6, DIM, "ring", chunks=1)
    t8 = collective_time_us("all_reduce", 1e6, DIM, "ring", chunks=8)
    assert t8 >= t1


# ---------------------------------------------------------------------------
# topology / cost model
# ---------------------------------------------------------------------------

def test_table3_systems_build():
    for net, n in ((system_1(), 512), (system_2(), 1024), (system_3(), 2048)):
        assert net.n_npus == n
        assert net.dollar_cost() > 0
        assert net.bw_per_npu() > 0
    assert tpu_v5e_pod().n_npus == 256


def test_fc_costs_more_than_ring():
    ring = build_network(("ring",), (8,), (100,))
    fc = build_network(("fc",), (8,), (100,))
    assert fc.dollar_cost() > ring.dollar_cost()


# ---------------------------------------------------------------------------
# WTG
# ---------------------------------------------------------------------------

def test_trace_flops_scale_with_model():
    par = Parallelism(1024, dp=64, sp=4, pp=1)
    small = generate_trace(ARCHS["gpt3-13b"], par, batch=1024, seq=2048)
    large = generate_trace(ARCHS["gpt3-175b"], par, batch=1024, seq=2048)
    assert large.total_flops() > 5 * small.total_flops()


def test_trace_flops_match_6nd_order():
    """Total fwd+bwd FLOPs across the cluster ~ 6*N*D for a dense model."""
    spec = ARCHS["gpt3-13b"]
    par = Parallelism(1024, dp=1024, sp=1, pp=1)  # pure DP: tp=1, no comm
    tr = generate_trace(spec, par, batch=1024, seq=2048)
    cluster_flops = tr.total_flops() * 1024  # per-NPU trace x NPUs
    model_flops = 6 * spec.param_count() * 1024 * 2048
    assert 0.6 < cluster_flops / model_flops < 1.7


def test_tp_adds_collectives_dp_adds_grad_reduction():
    spec = ARCHS["gpt3-13b"]
    tp_trace = generate_trace(spec, Parallelism(64, dp=1, sp=1, pp=1), batch=64, seq=2048)
    dp_trace = generate_trace(spec, Parallelism(64, dp=64, sp=1, pp=1), batch=64, seq=2048)
    tp_colls = tp_trace.total_coll_bytes()
    dp_colls = dp_trace.total_coll_bytes()
    assert tp_colls.get("tp", 0) > 0 and "dp" not in tp_colls
    assert dp_colls.get("dp", 0) > 0 and "tp" not in dp_colls
    # DP gradient traffic ~ parameter bytes
    assert dp_colls["dp"] > spec.param_count() * 1.5


def test_moe_trace_has_all_to_all():
    spec = ARCHS["moonshot-v1-16b-a3b"]
    tr = generate_trace(spec, Parallelism(64, dp=4, sp=1, pp=1), batch=64, seq=2048)
    assert any(o.coll == "all_to_all" for o in tr.ops if o.kind == "coll")


# ---------------------------------------------------------------------------
# memory model
# ---------------------------------------------------------------------------

def test_memory_gate():
    spec = ARCHS["gpt3-175b"]
    tight = Parallelism(1024, dp=1024, sp=1, pp=1)      # no model sharding
    roomy = Parallelism(1024, dp=16, sp=4, pp=4, weight_sharded=True)
    assert not fits(spec, tight, batch=1024, seq=2048)
    assert fits(spec, roomy, batch=1024, seq=2048)


def test_weight_sharding_reduces_params():
    spec = ARCHS["gpt3-13b"]
    base = footprint(spec, Parallelism(64, 8, 1, 1, False), batch=64, seq=2048)
    zero = footprint(spec, Parallelism(64, 8, 1, 1, True), batch=64, seq=2048)
    assert zero.params_gb < base.params_gb
    assert zero.optimizer_gb < base.optimizer_gb * 1.01


# ---------------------------------------------------------------------------
# event simulator
# ---------------------------------------------------------------------------

def _sys(net: Network, policy="fifo") -> SystemConfig:
    return SystemConfig(network=net, device=SYSTEM_2_DEVICE,
                        coll_algo=("ring",) * len(net.dims), chunks=2,
                        sched_policy=policy)


def test_simulate_all_ops_finish_and_overlap_bounded():
    spec = ARCHS["gpt3-13b"]
    par = Parallelism(1024, dp=64, sp=4, pp=1)
    tr = generate_trace(spec, par, batch=1024, seq=2048)
    res = simulate(tr, _sys(system_2()), par)
    assert res.makespan_us > 0
    serial = res.compute_busy_us + sum(res.comm_busy_us.values())
    assert res.makespan_us <= serial * 1.001          # overlap can't exceed serial
    assert res.makespan_us >= res.compute_busy_us     # compute is on the critical path


def test_simulator_deterministic():
    spec = ARCHS["gpt3-13b"]
    par = Parallelism(1024, dp=32, sp=8, pp=1)
    tr = generate_trace(spec, par, batch=1024, seq=2048)
    r1 = simulate(tr, _sys(system_2()), par)
    r2 = simulate(tr, _sys(system_2()), par)
    assert r1.makespan_us == r2.makespan_us


def test_scheduling_policy_changes_schedule():
    spec = ARCHS["gpt3-175b"]
    par = Parallelism(1024, dp=64, sp=1, pp=1, weight_sharded=True)
    tr = generate_trace(spec, par, batch=1024, seq=2048)
    lifo = simulate(tr, _sys(system_2(), "lifo"), par)
    fifo = simulate(tr, _sys(system_2(), "fifo"), par)
    # same work, potentially different makespan; both must be sane
    assert abs(lifo.compute_busy_us - fifo.compute_busy_us) < 1e-6
    assert lifo.makespan_us > 0 and fifo.makespan_us > 0


def test_group_dims_cover_parallelism():
    par = Parallelism(1024, dp=16, sp=4, pp=2)  # tp = 8
    g = group_dims(system_2(), par)
    net = system_2()
    for grp, need in (("tp", 8), ("sp", 4), ("dp", 16), ("pp", 2)):
        got = math.prod(d.npus for _, d in g[grp]) if g[grp] else 1
        assert got == need, (grp, got, need)
        # every carved dim reports the physical dim it was taken from
        for src, d in g[grp]:
            assert 0 <= src < len(net.dims)
            assert d.bw == net.dims[src].bw


def test_dollar_cost_pinned_2dim_fabric():
    """Pin the LIBRA-style cost of a known 2-dim fabric so future edits
    can't silently shift the Perf-per-Cost reward: 8 parallel ring(4)@100
    groups at tier 1.0 (4 links * 100 * 8 = 3200) + 4 parallel switch(8)@50
    groups at tier 2.0 with the 1.5x switch premium
    (8 links * 50 * 2.0 * 1.5 * 4 = 4800)."""
    net = build_network(("ring", "switch"), (4, 8), (100, 50))
    assert net.n_npus == 32
    assert net.dollar_cost() == pytest.approx(8000.0)


def test_evaluate_full_pipeline():
    ev = evaluate(ARCHS["gpt3-13b"], Parallelism(1024, 64, 4, 1, True),
                  _sys(system_2()), batch=1024, seq=2048)
    assert ev.valid and ev.reward > 0 and ev.latency_ms > 0
    bad = evaluate(ARCHS["gpt3-175b"], Parallelism(1024, 1024, 1, 1),
                   _sys(system_2()), batch=1024, seq=2048)
    assert not bad.valid and bad.reward == 0.0


def test_decode_trace_small_messages():
    """Decode-phase collectives are tiny (latency regime) vs prefill."""
    spec = ARCHS["gpt3-175b"]
    par = Parallelism(1024, dp=64, sp=4, pp=1, weight_sharded=True)
    dec = generate_trace(spec, par, batch=64, seq=2048, mode="decode")
    pre = generate_trace(spec, par, batch=64, seq=2048, mode="inference")
    dec_tp = dec.total_coll_bytes().get("tp", 0)
    pre_tp = pre.total_coll_bytes().get("tp", 0)
    assert 0 < dec_tp < pre_tp / 100


def test_serve_mode_evaluate():
    from repro.core.rewards import evaluate as ev
    r = ev(ARCHS["gpt3-13b"], Parallelism(1024, 64, 4, 1, True),
           _sys(system_2()), batch=64, seq=2048, mode="serve")
    assert r.valid and r.reward > 0
    assert r.detail["decode_ms"] < r.detail["prefill_ms"]


def test_mxu_granularity_efficiency():
    """Pathological TP degrees inflate compute time (Fig-4 physics)."""
    spec = ARCHS["gpt3-175b"]
    sane = generate_trace(spec, Parallelism(1024, dp=256, sp=1, pp=1),
                          batch=1024, seq=2048)   # tp=4
    patho = generate_trace(spec, Parallelism(1024, dp=1, sp=1, pp=1),
                           batch=1024, seq=2048)  # tp=1024
    # per-NPU useful flops identical, but the pathological trace carries the
    # MXU-underutilization inflation
    assert patho.total_flops() > 3 * sane.total_flops()
