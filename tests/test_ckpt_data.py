"""Checkpointing (atomic/async/gc/resume) + data pipeline determinism."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.runtime.fault import Heartbeat, StragglerMonitor, run_with_restarts


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "n": {"b": jnp.ones((4,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, t, step=3, meta={"loss": 1.5})
    out, step = restore(tmp_path, t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(out["n"]["b"]), np.asarray(t["n"]["b"]))


def test_latest_step_and_multiple(tmp_path):
    t = _tree()
    for s in (1, 5, 3):
        save(tmp_path, t, step=s)
    assert latest_step(tmp_path) == 5
    _, step = restore(tmp_path, t, step=3)
    assert step == 3


def test_restore_shape_mismatch_raises(tmp_path):
    save(tmp_path, _tree(), step=1)
    bad = {"a": jnp.zeros((3, 3)), "n": {"b": jnp.ones((4,), jnp.int32)}}
    with pytest.raises(ValueError):
        restore(tmp_path, bad)


def test_no_partial_checkpoint_on_crash(tmp_path):
    """tmp dir left from a 'crash' must not shadow a real checkpoint."""
    (tmp_path / ".tmp_step_00000007").mkdir(parents=True)
    save(tmp_path, _tree(), step=7)
    assert latest_step(tmp_path) == 7


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(_tree(), s)
    ck.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]


def test_run_with_restarts_recovers(tmp_path):
    calls = {"n": 0}

    def loop(start):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("injected")
        return 10

    rep = run_with_restarts(loop, target_step=10, max_restarts=5)
    assert rep.completed_steps == 10 and rep.restarts == 2


def test_run_with_restarts_gives_up():
    def loop(start):
        raise RuntimeError("always fails")
    with pytest.raises(RuntimeError, match="exceeded"):
        run_with_restarts(loop, target_step=1, max_restarts=2)


def test_heartbeat(tmp_path):
    hb = Heartbeat(tmp_path / "hb.json")
    assert not hb.is_alive()
    hb.beat(7)
    assert hb.is_alive(timeout_s=5)
    data = json.loads((tmp_path / "hb.json").read_text())
    assert data["step"] == 7


def test_straggler_monitor():
    mon = StragglerMonitor(k_sigma=3.0, min_samples=5)
    rng = np.random.default_rng(0)
    flags = [mon.observe(i, 0.1 + 1e-3 * rng.random()) for i in range(20)]
    assert not any(flags)
    assert mon.observe(20, 1.0)  # 10x step time -> straggler
    assert mon.events and mon.events[0]["step"] == 20
    # baseline stats unpoisoned by the outlier
    assert mon.mean < 0.15


def test_data_determinism_and_host_sharding():
    spec = reduced(ARCHS["qwen2-1.5b"])
    a = SyntheticLM(spec, DataConfig(8, 32, seed=1)).batch_at(5)
    b = SyntheticLM(spec, DataConfig(8, 32, seed=1)).batch_at(5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = SyntheticLM(spec, DataConfig(8, 32, seed=2)).batch_at(5)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # host sharding: two hosts each get half the batch, different content
    h0 = SyntheticLM(spec, DataConfig(8, 32, seed=1, n_hosts=2, host_id=0)).batch_at(5)
    h1 = SyntheticLM(spec, DataConfig(8, 32, seed=1, n_hosts=2, host_id=1)).batch_at(5)
    assert h0["inputs"].shape == (4, 32)
    assert not np.array_equal(h0["inputs"], h1["inputs"])


def test_labels_are_next_tokens():
    spec = reduced(ARCHS["qwen2-1.5b"])
    b = SyntheticLM(spec, DataConfig(4, 16, seed=0)).batch_at(0)
    # inputs[t+1] == labels[t] by construction
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_orders_and_closes():
    spec = reduced(ARCHS["qwen2-1.5b"])
    src = SyntheticLM(spec, DataConfig(2, 8, seed=0))
    pf = Prefetcher(src, start_step=3, depth=2)
    it = iter(pf)
    steps = [next(it)[0] for _ in range(4)]
    pf.close()
    assert steps == [3, 4, 5, 6]
