"""Shared pytest config.

Marker registration + the `-m "not slow"` default live in pyproject.toml;
registering the marker here as well keeps collection warning-free when the
suite is invoked from a different rootdir (e.g. `pytest tests/ -c /dev/null`
in minimal CI containers).
"""
from __future__ import annotations

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute multi-device/e2e tests, deselected by default")


@pytest.fixture()
def clear_dse_caches():
    """Start the test from cold DSE caches and leave them cold afterwards."""
    from repro.core import cache

    cache.clear_all_caches()
    yield
    cache.clear_all_caches()
