"""Shared test utilities."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_with_devices(n_devices: int, code: str, timeout: int = 600) -> str:
    """Run `code` in a fresh interpreter with n host devices.

    Needed because device count is locked at first jax init: the main pytest
    process must keep seeing 1 device (per the dry-run contract)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
