"""Loop-aware HLO analyzer: trip counts, dot flops, collective parsing."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_analysis import HloCostModel, _shape_bytes, parse_hlo


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _shape_bytes("(f32[8], s32[2,2])") == 32 + 16
    assert _shape_bytes("pred[]") == 1


def test_scan_trip_count_multiplies_flops():
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    comp = jax.jit(scanned).lower(x, ws).compile()
    t = HloCostModel(comp.as_text()).analyze()
    expect = 8 * 2 * 128 * 256 * 256
    assert 0.95 < t.flops / expect < 1.15  # dots dominate; tanh adds a little


def test_nested_scan_trip_counts():
    def inner(c, w):
        return jnp.tanh(c @ w), None

    def outer(x, ws):
        def body(c, _):
            c2, _ = jax.lax.scan(inner, c, ws)
            return c2, None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    comp = jax.jit(outer).lower(x, ws).compile()
    t = HloCostModel(comp.as_text()).analyze()
    expect = 3 * 4 * 2 * 64 * 64 * 64
    assert 0.9 < t.flops / expect < 1.3


def test_stock_cost_analysis_undercounts_loops():
    """The reason this module exists."""
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
    comp = jax.jit(scanned).lower(x, ws).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per device
        ca = ca[0]
    stock = ca["flops"]
    ours = HloCostModel(comp.as_text()).analyze().flops
    assert ours > 10 * stock  # 16 iterations vs 1


SHARDED_SNIPPET = """\
HloModule test

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64] get-tuple-element(%p), index=1
  %ar = f32[64,64] all-reduce(%x), channel_id=1, replica_groups=[4,64]<=[256], to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%zero, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body
  %ag = f32[128,64] all-gather(%a), channel_id=2, replica_groups=[8,32]<=[256], dimensions={0}
  ROOT %out = f32[64,64] get-tuple-element(%w), index=1
}
"""


def test_collectives_inside_while_counted_with_trips():
    m = HloCostModel(SHARDED_SNIPPET)
    t = m.analyze()
    ar_bytes = 64 * 64 * 4 * 12        # 12 loop iterations
    ag_bytes = 128 * 64 * 4
    assert t.collective_bytes["all-reduce"] == ar_bytes
    assert t.collective_bytes["all-gather"] == ag_bytes
    assert t.collective_counts["all-reduce"] == 12
    assert t.collective_by_group[("all-reduce", 64)] == ar_bytes
    assert t.collective_by_group[("all-gather", 32)] == ag_bytes


def test_trip_count_from_condition():
    m = HloCostModel(SHARDED_SNIPPET)
    assert m.trip_count("cond") == 12


def test_parse_handles_tuple_params():
    comps = parse_hlo(SHARDED_SNIPPET)
    assert set(comps) >= {"body", "cond", "main"}
    assert any(i.opcode == "while" for i in comps["main"].instructions)
