"""Batched DSE evaluation engine: batched-vs-sequential search equivalence,
bit-identical memoization layers, and process-pool consistency."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import cache
from repro.core.agents import make_agent
from repro.core.collectives import (_multidim_collective_time_impl,
                                    multidim_collective_time_us)
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.dse import run_search
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.space import DesignSpace
from repro.core.topology import build_network, system_2
from repro.core.workload import Parallelism, _generate_trace_impl, generate_trace


def _env():
    return CosmicEnv(spec=ARCHS["gpt3-13b"], n_npus=1024, device=SYSTEM_2_DEVICE,
                     batch=1024, seq=2048)


def _sequential_reference(kind: str, steps: int, seed: int):
    """The seed repo's propose/step/observe loop, via the scalar agent API."""
    space = DesignSpace(paper_psa(1024))
    agent = make_agent(kind, space, seed=seed)
    env = _env()
    best, best_step = -np.inf, 0
    curve = []
    for i in range(steps):
        cfg = agent.propose()
        ev = env.step(cfg)
        agent.observe(cfg, ev.reward)
        if ev.reward > best:
            best, best_step = ev.reward, i
        curve.append(best)
    return best, best_step, curve


@pytest.mark.parametrize("kind", ["ga", "rw", "aco", "bo"])
def test_batched_driver_batch1_equals_sequential(kind, clear_dse_caches):
    """batch_size=1 must reproduce the sequential loop exactly: same RNG
    stream, same rewards, same convergence bookkeeping."""
    steps = 40 if kind != "bo" else 24
    best, best_step, curve = _sequential_reference(kind, steps, seed=0)
    res = run_search(paper_psa(1024), _env(), kind, steps=steps, seed=0,
                     batch_size=1)
    assert res.best_reward == best
    assert res.steps_to_peak == best_step
    assert res.reward_curve == curve


def test_random_walk_any_batch_matches_sequential(clear_dse_caches):
    """RW proposals are history-free, so the batched search coincides with
    the sequential one at EVERY step for any batch size."""
    steps = 48
    best, best_step, curve = _sequential_reference("rw", steps, seed=3)
    res = run_search(paper_psa(1024), _env(), "rw", steps=steps, seed=3,
                     batch_size=8)
    assert res.best_reward == best
    assert res.steps_to_peak == best_step
    assert res.reward_curve == curve


def test_ga_generation_batch_reaches_valid_optimum(clear_dse_caches):
    """Whole-generation GA is a different (but valid) trajectory: it must
    still find a positive-reward design and keep its bookkeeping coherent."""
    res = run_search(paper_psa(1024), _env(), "ga", steps=64, seed=0,
                     batch_size=16)
    assert res.steps == 64 and len(res.reward_curve) == 64
    assert res.best_reward > 0 and res.best_config is not None
    assert res.reward_curve[res.steps_to_peak] == res.best_reward


def test_trace_cache_bit_identical_and_interned(clear_dse_caches):
    spec = ARCHS["gpt3-13b"]
    par = Parallelism(1024, dp=8, sp=2, pp=2, weight_sharded=True)
    for mode in ("train", "inference", "decode"):
        cached = generate_trace(spec, par, batch=512, seq=2048, mode=mode)
        raw = _generate_trace_impl(spec, par, 512, 2048, mode, None)
        assert cached.meta == raw.meta
        assert len(cached.ops) == len(raw.ops)
        for a, b in zip(cached.ops, raw.ops):
            assert (a.uid, a.name, a.kind, a.deps) == (b.uid, b.name, b.kind, b.deps)
            assert (a.flops, a.bytes) == (b.flops, b.bytes)
            assert (a.coll, a.size_bytes, a.group) == (b.coll, b.size_bytes, b.group)
        # repeated design points return the interned trace: near-free
        assert generate_trace(spec, par, batch=512, seq=2048, mode=mode) is cached


def test_collective_cache_bit_identical(clear_dse_caches):
    net = system_2()
    small = build_network(("ring", "fc"), (4, 8), (200.0, 100.0))
    for n, algos in ((net, ("ring", "direct", "rhd", "dbt")),
                     (small, ("dbt", "direct"))):
        for kind in ("all_reduce", "all_gather", "reduce_scatter", "all_to_all"):
            for mode in ("baseline", "blueconnect"):
                for chunks in (1, 4):
                    got = multidim_collective_time_us(kind, 3.7e8, n, algos,
                                                      chunks=chunks, mode=mode)
                    want = _multidim_collective_time_impl(kind, 3.7e8, n,
                                                          tuple(algos), chunks,
                                                          mode, None)
                    assert got == want


def test_disabling_caches_matches_cached_results(clear_dse_caches):
    env_c, env_u = _env(), _env()
    space = DesignSpace(paper_psa(1024))
    cfgs = [space.sample(np.random.default_rng(7)) for _ in range(6)]
    cached = [env_c.step(c) for c in cfgs]
    cache.set_caches_enabled(False)
    try:
        uncached = [env_u.step(c) for c in cfgs]
    finally:
        cache.set_caches_enabled(True)
    for a, b in zip(cached, uncached):
        assert (a.reward, a.latency_ms, a.valid) == (b.reward, b.latency_ms, b.valid)


def test_eval_memo_dedupes_repeated_points(clear_dse_caches):
    env = _env()
    space = DesignSpace(paper_psa(1024))
    cfg = space.sample(np.random.default_rng(1))
    first = env.step(cfg)
    again = env.step(dict(cfg))  # equal-valued copy must hit the memo
    assert again is first
    assert len(env.history) == 2 and env.history[1].reward == first.reward


def test_step_batch_process_pool_matches_serial(clear_dse_caches):
    space = DesignSpace(paper_psa(1024))
    rng = np.random.default_rng(11)
    cfgs = [space.sample(rng) for _ in range(8)]
    serial_env = _env()
    serial = [serial_env.step(c) for c in cfgs]
    with _env() as pool_env:
        pooled = pool_env.step_batch(cfgs, workers=2)
    assert len(pooled) == len(serial)
    for a, b in zip(pooled, serial):
        assert (a.reward, a.latency_ms, a.valid) == (b.reward, b.latency_ms, b.valid)
    # history recorded in input order
    assert [r.config for r in pool_env.history] == cfgs


@pytest.mark.slow
def test_batched_engine_throughput(clear_dse_caches):
    """Caching + batching must beat the uncached sequential loop (the seed
    loop proxy) on the acceptance workload.  The in-process floor is
    conservative (the uncached engine is itself ~2x faster than the seed);
    see ROADMAP.md for the measured 3x-vs-seed numbers at 500 steps."""
    import time

    ratios = []
    for _ in range(3):  # shared-CPU noise: pass if any attempt clears the bar
        try:
            cache.set_caches_enabled(False)
            t0 = time.time()
            run_search(paper_psa(1024), _env(), "ga", steps=500, seed=0)
            t_seq = time.time() - t0
            ref = run_search(paper_psa(1024), _env(), "ga", steps=500, seed=0,
                             batch_size=32)
        finally:
            cache.set_caches_enabled(True)
        cache.clear_all_caches()
        t0 = time.time()
        bat = run_search(paper_psa(1024), _env(), "ga", steps=500, seed=0,
                         batch_size=32)
        t_bat = time.time() - t0
        # caching only changes speed: the batched trajectory is bit-identical
        assert bat.reward_curve == ref.reward_curve
        ratios.append(t_seq / t_bat)
        if ratios[-1] > 1.2:
            break
    assert max(ratios) > 1.2, \
        f"batched only x{max(ratios):.2f} over uncached across {len(ratios)} attempts"
