"""Surrogate subsystem tests: featurization stability + loud signature
mismatch, predictor determinism + fidelity machinery, DesignSpace batch
sampling (pinned equivalence with the scalar path), the screening agent
(determinism, warm start, campaign resume bit-reproducibility), the
once-per-campaign store preload, and the ``store stats`` CLI."""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.dse import run_search
from repro.core.psa import Constraint, Parameter, ParameterSet, paper_psa
from repro.core.space import DesignSpace
from repro.core.surrogate import (SURROGATE_REGISTRY, Featurizer,
                                  build_dataset, env_store_records,
                                  holdout_fidelity, make_surrogate, spearman,
                                  store_records)
from repro.core.study import StudySpec, run_study
from repro.core.systems import system_env, system_pset

ARCH = "qwen2-1.5b"


def _env(**kw):
    return system_env(ARCH, "system2", batch=64, seq=2048, **kw)


def _pset():
    return system_pset("system2")


# ---------------------------------------------------------------------------
# (a) featurization: round trip, stability, loud mismatch
# ---------------------------------------------------------------------------

def test_featurizer_vec_and_config_paths_agree():
    space = DesignSpace(paper_psa(1024))
    feat = Featurizer(space)
    rng = np.random.default_rng(0)
    cfgs = space.sample_batch(32, rng)
    vecs = np.array([space.encode(c) for c in cfgs])
    assert np.array_equal(feat.featurize_configs(cfgs),
                          feat.featurize_vecs(vecs))
    # same config -> same vector, across independent Featurizers
    feat2 = Featurizer(DesignSpace(paper_psa(1024)))
    assert feat2.signature == feat.signature
    assert np.array_equal(feat2.featurize(cfgs[0]), feat.featurize(cfgs[0]))


def test_featurizer_signature_stable_across_processes():
    import os

    import repro.core

    space = DesignSpace(paper_psa(1024))
    sig = Featurizer(space).signature
    env = dict(os.environ)
    env["PYTHONPATH"] = str(
        Path(repro.core.__file__).resolve().parent.parent.parent) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        env=env,
        args=[sys.executable, "-c",
         "from repro.core.psa import paper_psa\n"
         "from repro.core.space import DesignSpace\n"
         "from repro.core.surrogate import Featurizer\n"
         "print(Featurizer(DesignSpace(paper_psa(1024))).signature)"],
        capture_output=True, text=True, check=True)
    assert out.stdout.strip() == sig


def test_featurizer_signature_mismatch_is_loud():
    space = DesignSpace(paper_psa(1024))
    sig = Featurizer(space).signature
    # same pset -> accepted; different pset -> refused with both signatures
    Featurizer(space, expect_signature=sig)
    other = DesignSpace(paper_psa(512))
    with pytest.raises(ValueError, match="feature-signature mismatch"):
        Featurizer(other, expect_signature=sig)


def test_featurizer_rejects_foreign_config():
    space = DesignSpace(paper_psa(1024))
    feat = Featurizer(space)
    cfg = space.sample(np.random.default_rng(0))
    cfg["dp"] = 3  # not a choice of the dp parameter
    with pytest.raises(ValueError, match="cannot be featurized"):
        feat.featurize(cfg)


def test_featurizer_encodings():
    pset = ParameterSet([
        Parameter("deg", "workload", (1, 2, 4, 8, 16)),   # wide -> log2
        Parameter("frac", "workload", (0.25, 0.5, 0.75)),  # narrow -> linear
        Parameter("algo", "collective", ("ring", "direct")),  # -> one-hot
        Parameter("pin", "network", (7,)),       # single choice -> no width
    ])
    feat = Featurizer(DesignSpace(pset))
    assert feat.n_features == 1 + 1 + 2
    v1 = feat.featurize({"deg": 1, "frac": 0.25, "algo": "ring", "pin": 7})
    v2 = feat.featurize({"deg": 16, "frac": 0.75, "algo": "direct", "pin": 7})
    assert v1.tolist() == [0.0, 0.0, 1.0, 0.0]
    assert v2.tolist() == [1.0, 1.0, 0.0, 1.0]
    # log scaling: 4 is the geometric midpoint of 1..16
    vm = feat.featurize({"deg": 4, "frac": 0.5, "algo": "ring", "pin": 7})
    assert vm[0] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# (b) predictors: determinism, fidelity machinery
# ---------------------------------------------------------------------------

def _toy_corpus(n=200, seed=0):
    space = DesignSpace(paper_psa(1024))
    feat = Featurizer(space)
    rng = np.random.default_rng(seed)
    X = feat.featurize_vecs(space.raw_decode_batch(n, rng))
    w = np.random.default_rng(1).normal(size=X.shape[1])
    return X, np.exp(X @ w * 0.5)


@pytest.mark.parametrize("name", sorted(SURROGATE_REGISTRY))
def test_predictor_deterministic_under_seed(name):
    X, y = _toy_corpus()
    m1 = make_surrogate(name, seed=3).fit(X, y)
    m2 = make_surrogate(name, seed=3).fit(X, y)
    p1, s1 = m1.predict(X[:40])
    p2, s2 = m2.predict(X[:40])
    assert np.array_equal(p1, p2) and np.array_equal(s1, s2)
    assert np.all(s1 >= 0)


@pytest.mark.parametrize("name", sorted(SURROGATE_REGISTRY))
def test_predictor_learns_smooth_target(name):
    X, y = _toy_corpus(400)
    rep = holdout_fidelity(name, X, y, seed=0)
    assert rep["spearman"] > 0.6
    assert 0.0 <= rep["topk_recall"] <= 1.0


def test_make_surrogate_unknown_name():
    with pytest.raises(ValueError, match="unknown surrogate"):
        make_surrogate("forest")


def test_spearman():
    a = np.array([1.0, 2.0, 3.0, 4.0])
    assert spearman(a, a * 10) == pytest.approx(1.0)
    assert spearman(a, -a) == pytest.approx(-1.0)
    # ties: rank-averaged, monotone-invariant
    b = np.array([1.0, 1.0, 2.0, 3.0])
    assert spearman(b, b ** 3) == pytest.approx(1.0)
    assert np.isnan(spearman(a[:1], a[:1]))


def test_dataset_builders():
    space = DesignSpace(paper_psa(1024))
    feat = Featurizer(space)
    rng = np.random.default_rng(0)
    cfgs = space.sample_batch(8, rng)
    env = _env(eval_store={})
    evs = env.step_batch(cfgs)
    recs = env.store_records()
    assert len(recs) == len({tuple(sorted(c.items())) for c in cfgs})
    rewards = {tuple(sorted(c.items())): ev.reward
               for c, ev in zip(cfgs, evs)}
    for cfg, r in recs:
        assert rewards[tuple(sorted(cfg.items()))] == r
    # env_store_records parses the shared-store key shape directly
    assert sorted(r for _, r in env_store_records(env.eval_store)) == \
        sorted(r for _, r in recs)
    ds = build_dataset(feat, recs)
    assert ds.X.shape == (len(recs), feat.n_features)
    assert ds.feature_signature == feat.signature


# ---------------------------------------------------------------------------
# (c) DesignSpace batch sampling — the satellite's pinned equivalences
# ---------------------------------------------------------------------------

def test_sample_batch_bit_identical_to_scalar_on_constraint_free_space():
    pset = ParameterSet([
        Parameter("a", "workload", (1, 2, 4, 8)),
        Parameter("b", "workload", ("x", "y", "z")),
        Parameter("c", "workload", (0.1, 0.2)),
    ])
    space = DesignSpace(pset)
    ra, rb = np.random.default_rng(42), np.random.default_rng(42)
    assert space.sample_batch(50, ra) == [space.sample(rb) for _ in range(50)]


def test_sample_batch_valid_and_deterministic_on_constrained_space():
    space = DesignSpace(paper_psa(1024))
    a = space.sample_batch(64, np.random.default_rng(7))
    b = space.sample_batch(64, np.random.default_rng(7))
    assert a == b
    assert all(space.is_valid(c) for c in a)


def test_decode_batch_matches_scalar_decode():
    space = DesignSpace(paper_psa(1024))
    vecs = space.raw_decode_batch(32, np.random.default_rng(3))
    batch = space.decode_batch(vecs)
    for row, cfg in zip(vecs, batch):
        scalar = space.decode(row)
        assert cfg == scalar
        assert all(type(cfg[k]) is type(scalar[k]) for k in cfg)


def test_valid_mask_matches_scalar_is_valid():
    space = DesignSpace(paper_psa(1024))
    vecs = space.raw_decode_batch(128, np.random.default_rng(5))
    mask = space.valid_mask(vecs)
    for row, ok in zip(vecs, mask):
        assert bool(ok) == space.is_valid(space.decode(row))


def test_constraint_mask_predicate_fallback():
    pset = ParameterSet(
        [Parameter("a", "workload", (1, 2, 4)),
         Parameter("b", "workload", (1, 2, 4))],
        [Constraint(kind="predicate", params=("a", "b"),
                    fn=lambda cfg: cfg["a"] <= cfg["b"], name="a<=b")])
    space = DesignSpace(pset)
    vecs = space.raw_decode_batch(64, np.random.default_rng(0))
    mask = space.constraint_mask(vecs, pset.constraints[0])
    for row, ok in zip(vecs, mask):
        cfg = space.decode(row)
        assert bool(ok) == (cfg["a"] <= cfg["b"])


# ---------------------------------------------------------------------------
# (d) screening agent: determinism, warm start, resume reproducibility
# ---------------------------------------------------------------------------

def _search(seed=0, steps=48, **kw):
    return run_search(_pset(), _env(), "surrogate", steps=steps, seed=seed,
                      batch_size=8, warmup=8, pool=256, **kw)


def test_surrogate_agent_deterministic():
    r1, r2 = _search(seed=5), _search(seed=5)
    assert r1.best_reward == r2.best_reward
    assert r1.reward_curve == r2.reward_curve
    assert r1.best_config == r2.best_config


def test_surrogate_agent_proposals_valid_and_screened():
    from repro.core.agents.surrogate import SurrogateScreeningAgent

    space = DesignSpace(_pset())
    agent = SurrogateScreeningAgent(space, seed=0, warmup=8, pool=256)
    rng = np.random.default_rng(0)
    for _ in range(3):
        batch = agent.propose_batch(8)
        assert all(space.is_valid(c) for c in batch)
        agent.observe_batch(batch, [float(rng.random()) for _ in batch])
    assert agent._model is not None  # screening path engaged after warmup
    # post-warmup proposals dedupe against everything already observed
    seen = {tuple(sorted(c.items())) for c in agent._cfgs}
    batch = agent.propose_batch(8)
    assert all(tuple(sorted(c.items())) not in seen for c in batch)


def test_surrogate_warm_start_pinned(tmp_path):
    # corpus from a real prior search, persisted through the JSONL store
    # shape, then warm-starting a new search from the file's records
    spec = StudySpec(
        name="warm", arch=ARCH, system="system2", scenario="train",
        scenario_params={"batch": 64, "seq": 2048}, objective="perf_per_bw",
        agents=("ga",), seeds=(0,), steps=24, batch_size=8,
        eval_store_path=str(tmp_path / "evals.jsonl"))
    run_study(spec, out=tmp_path / "r1.jsonl")
    recs = store_records(tmp_path / "evals.jsonl", spec.eval_signature())
    assert len(recs) > 0
    res = _search(warm_start=recs)
    assert res.warm_start_points == len(recs)
    cold = _search()
    assert cold.warm_start_points == 0
    # pinned: the warm agent's proposals diverge from cold immediately
    # (the corpus skips the random warmup), and the run stays deterministic
    res2 = _search(warm_start=recs)
    assert res.reward_curve == res2.reward_curve
    assert res.best_config == res2.best_config


def test_surrogate_study_resume_bit_reproducible(tmp_path):
    def spec(store):
        return StudySpec(
            name="s", arch=ARCH, system="system2", scenario="train",
            scenario_params={"batch": 64, "seq": 2048},
            objective="perf_per_bw",
            agents=({"kind": "surrogate",
                     "hyper": {"warmup": 8, "pool": 256}},),
            seeds=(0, 1), steps=24, batch_size=8,
            eval_store_path=str(store))

    def rows(path):
        out = []
        for line in Path(path).read_text().splitlines():
            rec = json.loads(line)
            if rec.get("record") != "cell":
                continue
            r = dict(rec["result"])
            for k in ("wall_s", "points_per_s"):
                r.pop(k, None)
            out.append((rec["cell_id"], r))
        return out

    a = run_study(spec(tmp_path / "ea.jsonl"), out=tmp_path / "a.jsonl")
    assert [o.resumed for o in a.outcomes] == [False, False]
    # an identical fresh campaign is bit-identical cell for cell
    b = run_study(spec(tmp_path / "eb.jsonl"), out=tmp_path / "b.jsonl")
    assert rows(tmp_path / "a.jsonl") == rows(tmp_path / "b.jsonl")
    # resuming the finished campaign re-runs nothing and changes nothing
    before = rows(tmp_path / "a.jsonl")
    c = run_study(spec(tmp_path / "ea.jsonl"), out=tmp_path / "a.jsonl",
                  resume=True)
    assert [o.resumed for o in c.outcomes] == [True, True]
    assert rows(tmp_path / "a.jsonl") == before


def test_surrogate_in_agent_registry():
    from repro.core.agents import KNOWN_AGENTS, make_agent

    assert "surrogate" in KNOWN_AGENTS
    agent = make_agent("surrogate", DesignSpace(paper_psa(1024)), seed=0)
    assert agent.name == "surrogate"


# ---------------------------------------------------------------------------
# (e) once-per-campaign store preload (regression for the per-cell re-read)
# ---------------------------------------------------------------------------

def test_persistent_store_read_once_per_campaign(tmp_path, monkeypatch):
    import repro.core.study as study_mod

    store = tmp_path / "evals.jsonl"
    spec = StudySpec(
        name="pre", arch=ARCH, system="system2", scenario="train",
        scenario_params={"batch": 64, "seq": 2048}, objective="perf_per_bw",
        agents=("rw", "ga", {"kind": "surrogate",
                             "hyper": {"warmup": 8, "pool": 256}}),
        seeds=(0,), steps=16, batch_size=8, eval_store_path=str(store))
    run_study(spec, out=tmp_path / "r1.jsonl")   # populate the store

    reads = []
    orig = study_mod.iter_jsonl_lenient

    def counting(path):
        if Path(path) == store:
            reads.append(path)
        return orig(path)

    monkeypatch.setattr(study_mod, "iter_jsonl_lenient", counting)
    res = run_study(spec, out=tmp_path / "r2.jsonl")
    # 3 cells, 1 store: the JSONL is parsed exactly once per campaign and
    # every cell (incl. the surrogate's warm start) feeds off the
    # in-memory entries
    assert len(res.outcomes) == 3
    assert len(reads) == 1
    assert res.store_preloaded > 0


def test_store_records_reader(tmp_path):
    p = tmp_path / "evals.jsonl"
    recs = [{"sig": "A", "config": {"x": 1, "t": [1, 2]}, "reward": 2.0,
             "latency_ms": 1.0, "valid": True, "detail": {}},
            {"sig": "B", "config": {"x": 2}, "reward": 3.0,
             "latency_ms": 1.0, "valid": True, "detail": {}}]
    p.write_text("\n".join(json.dumps(r) for r in recs)
                 + "\n{\"torn", encoding="utf-8")
    both = store_records(p)
    assert len(both) == 2
    only_a = store_records(p, "A")
    assert only_a == [({"x": 1, "t": (1, 2)}, 2.0)]  # lists re-frozen
    with pytest.raises(FileNotFoundError):
        store_records(tmp_path / "missing.jsonl")


# ---------------------------------------------------------------------------
# (f) store stats CLI
# ---------------------------------------------------------------------------

def test_cli_store_stats(tmp_path, capsys):
    from repro.dse import main

    p = tmp_path / "evals.jsonl"
    lines = [json.dumps({"sig": "AA", "config": {"x": i}, "reward": float(i),
                         "latency_ms": 1.0, "valid": i > 0, "detail": {}})
             for i in range(5)]
    lines.append(json.dumps({"sig": "BB", "config": {"x": 9}, "reward": 9.0,
                             "latency_ms": 1.0, "valid": True, "detail": {}}))
    p.write_text("\n".join(lines) + '\n{"torn tail', encoding="utf-8")
    assert main(["store", "stats", str(p)]) == 0
    out = capsys.readouterr().out
    assert "AA" in out and "BB" in out
    assert "6 record(s) across 2 signature(s)" in out
    # exit-2 discipline: missing and empty files
    assert main(["store", "stats", str(tmp_path / "nope.jsonl")]) == 2
    empty = tmp_path / "empty.jsonl"
    empty.write_text("", encoding="utf-8")
    assert main(["store", "stats", str(empty)]) == 2
