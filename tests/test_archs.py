"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, output shapes + finiteness; prefill+decode
consistency for each mixer family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as moem
from repro.configs import ARCHS, ASSIGNED, SHAPE_GRID, cell_is_runnable, reduced
from repro.models import model as M
from repro.train.train_step import RunConfig, init_train_state, make_train_step


def _inputs(spec, rng, b, s):
    if spec.frontend == "tokens":
        return jax.random.randint(rng, (b, s), 0, spec.vocab_size)
    return jax.random.normal(rng, (b, s, spec.d_model)) * 0.1


# compile-heavy hybrid/giant configs (tens of seconds each on CPU) ride the
# `slow` lane; tier-1 keeps one representative of every mixer/FFN family
_HEAVY_ARCHS = {"jamba-v0.1-52b", "gemma3-1b", "deepseek-67b",
                "phi-3-vision-4.2b"}


def _arch_params(names):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
            for a in names]


@pytest.mark.parametrize("arch", _arch_params(sorted(ASSIGNED)))
def test_forward_shapes_finite(arch):
    spec = reduced(ARCHS[arch])
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, spec)
    b, s = 2, 32
    logits, aux = M.forward(params, _inputs(spec, rng, b, s), spec, remat="none")
    assert logits.shape == (b, s, spec.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", _arch_params(sorted(ASSIGNED)))
def test_train_step_runs(arch):
    spec = reduced(ARCHS[arch])
    rng = jax.random.PRNGKey(1)
    cfg = RunConfig(remat="none")
    state = init_train_state(rng, spec, cfg)
    step = jax.jit(make_train_step(spec, cfg=cfg))
    b, s = 2, 16
    batch = {"inputs": np.asarray(_inputs(spec, rng, b, s)),
             "labels": np.random.randint(0, spec.vocab_size, (b, s)).astype(np.int32)}
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", _arch_params(
    ["qwen2-1.5b", "mamba2-130m", "gemma3-1b", "moonshot-v1-16b-a3b",
     "jamba-v0.1-52b", "phi-3-vision-4.2b"]))
def test_prefill_decode_matches_forward(arch, monkeypatch):
    monkeypatch.setattr(moem, "CAPACITY_FACTOR", 8.0)  # no capacity drops
    spec = reduced(ARCHS[arch])
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, spec)
    b, s = 2, 24
    inp = _inputs(spec, rng, b, s)
    logits_full, _ = M.forward(params, inp, spec, remat="none")
    caches = M.init_caches(spec, b, s, dtype=jnp.float32)
    lp, caches = M.prefill(params, inp[:, :-1], caches, spec, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_full[:, -2, :]),
                               rtol=3e-3, atol=3e-3)
    last = inp[:, -1] if spec.frontend == "tokens" else inp[:, -1, :]
    ld, _ = M.decode_step(params, caches, last, s - 1, spec, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(logits_full[:, -1, :]),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_published():
    expected = {  # billions, from the papers / model cards
        "mamba2-130m": (0.125, 0.133),
        "yi-9b": (8.6, 9.0),
        "deepseek-67b": (67.0, 68.0),
        "gemma3-1b": (0.7, 1.1),
        "qwen2-1.5b": (1.5, 1.6),
        "jamba-v0.1-52b": (51.0, 52.5),
        "gpt3-175b": (174.5, 175.5),
        "gpt3-13b": (12.8, 13.5),
    }
    for name, (lo, hi) in expected.items():
        p = ARCHS[name].param_count() / 1e9
        assert lo <= p <= hi, f"{name}: {p}B outside [{lo},{hi}]"
    # active-param sanity for MoE
    assert ARCHS["moonshot-v1-16b-a3b"].active_param_count() / 1e9 < 4.5
    assert ARCHS["granite-moe-3b-a800m"].active_param_count() / 1e9 < 1.1


def test_block_patterns():
    p, r, rem = ARCHS["gemma3-1b"].block_pattern()
    assert (len(p), r, len(rem)) == (6, 4, 2)
    kinds = [ld.mixer for ld in p]
    assert kinds == ["attn_local"] * 5 + ["attn_full"]
    p, r, rem = ARCHS["jamba-v0.1-52b"].block_pattern()
    assert (len(p), r, len(rem)) == (8, 4, 0)
    assert sum(ld.mixer == "attn_full" for ld in p) == 1
    assert sum(ld.ffn == "moe" for ld in p) == 4


def test_shape_grid_cells():
    total = sum(1 for a in ASSIGNED for s in SHAPE_GRID)
    assert total == 40
    runnable = sum(cell_is_runnable(ARCHS[a], s) for a in ASSIGNED for s in SHAPE_GRID)
    assert runnable == 33  # 7 pure-attention archs skip long_500k
