"""Static verification layer: the trace/plan verifier never fires on any
golden scenario trace (zero false positives), catches the whole seeded
defect corpus (zero false negatives), critical-path/slack invariants hold
against simulated makespans, and the PsA lint finds unsatisfiable
constraint sets and dead knobs."""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.analysis import (AnalysisReport, PlanVerificationError,
                                 aggregate_summaries, analyze_job,
                                 critical_path, lint_pset, lint_study,
                                 preflight, verify_plan, verify_trace)
from repro.core.backends.base import SimJob, run_sim_job
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.env import CosmicEnv
from repro.core.psa import Constraint, Parameter, ParameterSet, paper_psa
from repro.core.scenario import (DisaggServeScenario, MultiTenantScenario,
                                 RequestStreamScenario, Tenant,
                                 TrainScenario, register_scenario,
                                 scenario_psa, SCENARIO_REGISTRY)
from repro.core.simulator import SCHED_POLICIES, _sim_plan, plan_durations, \
    simulate
from repro.core.space import DesignSpace
from repro.core.study import StudySpec, run_study
from repro.core.workload import Op, Parallelism, Trace

ARCH = ARCHS["qwen2-1.5b"]


def _env(scenario):
    return CosmicEnv(spec=ARCH, n_npus=1024, device=SYSTEM_2_DEVICE,
                     scenario=scenario)


def _tenants():
    return (Tenant("t0", ARCH, 64, 512, "train", slo_ms=5e5),
            Tenant("t1", ARCH, 16, 512, "serve", slo_ms=5e4))


SCENARIOS = {
    "train": lambda: TrainScenario(64, 512),
    "disagg": lambda: DisaggServeScenario(batch=16, seq=512),
    "request-stream": lambda: RequestStreamScenario(
        n_requests=8, seq=256, decode_tokens=8, rate_rps=8.0, seed=0),
    "multi-tenant": lambda: MultiTenantScenario(tenants=_tenants()),
}


def _jobs(sc, policy, n=3, seed=7):
    """(config, SimJob) pairs for n sampled design points under one sched
    policy; gated-invalid points are skipped (sampling continues until n
    survivors or the try budget runs out)."""
    env = _env(sc)
    pset = scenario_psa(paper_psa(1024), sc, 1024).pin(
        {"sched_policy": policy})
    space = DesignSpace(pset)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n * 12):
        if len(out) == n:
            break
        cfg = space.sample(rng)
        job = sc.sim_job(env.context(cfg))
        if isinstance(job, SimJob):
            out.append((cfg, job))
    return out


# ---------------------------------------------------------------------------
# (a) zero false positives: the verifier never fires on golden traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCENARIOS))
@pytest.mark.parametrize("policy", SCHED_POLICIES)
def test_verifier_clean_on_all_scenario_families(kind, policy,
                                                 clear_dse_caches):
    jobs = _jobs(SCENARIOS[kind](), policy)
    assert jobs, "every probe gated invalid — widen the sample"
    for cfg, job in jobs:
        for c in job.calls:
            rep = verify_trace(c.trace, c.cfg, c.par, c.pools)
            assert rep.issues == (), \
                f"false positive on {kind}/{policy}:\n{rep.format()}"


# ---------------------------------------------------------------------------
# (b) critical-path/slack invariants vs simulated makespans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCENARIOS))
def test_critical_path_invariants(kind, clear_dse_caches):
    checked = 0
    for cfg, job in _jobs(SCENARIOS[kind](), "fifo", n=2):
        for c in job.calls:
            res = simulate(c.trace, c.cfg, c.par, pools=c.pools)
            plan, dur = plan_durations(c.trace, c.cfg, c.par, c.pools)
            cp = critical_path(plan, dur)
            tol = max(cp.length_us, 1.0) * 1e-9
            # the dependency chain is a lower bound on any schedule
            assert cp.length_us <= res.makespan_us + tol
            # so is each unit-capacity resource's total demand
            assert cp.resource_lb_us <= res.makespan_us + tol
            # the reported path is a dependency chain of zero-slack ops
            for u in cp.path:
                assert cp.slack_us[u] <= tol
            for prev, u in zip(cp.path, cp.path[1:]):
                assert prev in c.trace.ops[u].deps
            # the per-category breakdown is a partition of the path
            assert sum(cp.breakdown_us.values()) == \
                pytest.approx(cp.length_us, rel=1e-9)
            assert cp.n_critical >= len(cp.path) > 0
            s = cp.summary(makespan_us=res.makespan_us)
            assert 0.0 < s["cp_frac_of_makespan"] <= 1.0 + 1e-9
            assert sum(s["breakdown_frac"].values()) == pytest.approx(1.0)
            checked += 1
    assert checked


def test_simulate_analyze_flag_attaches_summary(clear_dse_caches):
    sc = TrainScenario(64, 512)
    (cfg, job), = _jobs(sc, "fifo", n=1)
    c = job.calls[0]
    res = simulate(c.trace, c.cfg, c.par, pools=c.pools, analyze=True)
    assert res.analysis is not None
    assert res.analysis["makespan_us"] == res.makespan_us
    assert res.analysis["critical_path_us"] <= res.makespan_us * (1 + 1e-9)
    plain = simulate(c.trace, c.cfg, c.par, pools=c.pools)
    assert plain.analysis is None
    assert plain.makespan_us == res.makespan_us

    ev, summaries = analyze_job(job)
    assert len(summaries) == len(job.calls)
    agg = aggregate_summaries(summaries)
    assert agg["calls"] == len(job.calls)
    assert sum(agg["breakdown_frac"].values()) == pytest.approx(1.0)
    assert aggregate_summaries([]) is None


# ---------------------------------------------------------------------------
# (c) zero false negatives: the seeded defect corpus
# ---------------------------------------------------------------------------

def _comp(uid, deps=()):
    return Op(uid=uid, name=f"op{uid}", kind="comp", deps=tuple(deps),
              flops=1e9, bytes=1e6)


def test_defect_dep_cycle():
    rep = verify_trace(Trace(ops=[_comp(0, (1,)), _comp(1, (0,))], meta={}))
    assert [i.code for i in rep.errors] == ["dep-cycle"]
    with pytest.raises(PlanVerificationError) as ei:
        rep.raise_if_issues()
    assert ei.value.report is rep
    assert "dep-cycle" in str(ei.value)


def test_defect_self_dependency():
    rep = verify_trace(Trace(ops=[_comp(0, (0,))], meta={}))
    assert any(i.code == "dep-cycle" for i in rep.errors)


def test_defect_forward_dag_is_not_flagged():
    # forward (but acyclic) deps force the Kahn fallback — must stay clean
    ops = [Op(uid=0, name="a", kind="comp", deps=(1,), flops=1e9, bytes=1e6),
           Op(uid=1, name="b", kind="comp", deps=(), flops=1e9, bytes=1e6)]
    assert verify_trace(Trace(ops=ops, meta={})).issues == ()


@pytest.mark.parametrize("bad_dep", [5, -3])
def test_defect_dangling_dep(bad_dep):
    rep = verify_trace(Trace(ops=[_comp(0, (bad_dep,))], meta={}))
    assert any(i.code == "dangling-dep" for i in rep.errors)


def test_defect_non_dense_uids():
    ops = [Op(uid=3, name="a", kind="comp", deps=(), flops=1e9, bytes=1e6)]
    rep = verify_trace(Trace(ops=ops, meta={}))
    assert any(i.code == "bad-uid" for i in rep.errors)


def test_defect_dangling_resource():
    plan = _sim_plan(Trace(ops=[_comp(0), _comp(1, (0,))], meta={}))
    bad = dataclasses.replace(plan, res_of=[0, 99], pack_memo={})
    rep = verify_plan(bad)
    assert any(i.code == "dangling-resource" and i.op == 1
               and i.resource == 99 for i in rep.errors)


def test_defect_bad_costs_and_repeat():
    ops = [Op(uid=0, name="a", kind="comp", deps=(), flops=float("nan"),
              bytes=1e6)]
    rep = verify_trace(Trace(ops=ops, meta={}))
    assert any(i.code == "bad-cost" for i in rep.errors)

    ops = [Op(uid=0, name="c", kind="coll", deps=(), coll="allreduce",
              size_bytes=1e6, group="dp", repeat=0)]
    rep = verify_trace(Trace(ops=ops, meta={}))
    assert any(i.code == "bad-repeat" for i in rep.errors)

    ops = [Op(uid=0, name="d", kind="delay", deps=(), delay_us=-5.0)]
    rep = verify_trace(Trace(ops=ops, meta={}))
    assert any(i.code == "bad-delay" for i in rep.errors)


def test_defect_oversubscribed_pool(clear_dse_caches):
    """A pool whose placement demands more NPUs than its network provides
    is flagged with the offending pool and an op scheduled onto it."""
    sc = RequestStreamScenario(n_requests=4, seq=256, decode_tokens=8,
                               rate_rps=8.0, seed=0)
    (cfg, job), = _jobs(sc, "fifo", n=1)
    c = job.calls[0]
    bad_pools = dict(c.pools)
    pool_id, entry = next(iter(bad_pools.items()))
    par0, net0 = entry[0], entry[1]
    over = dataclasses.replace(par0, n_npus=net0.n_npus * 4,
                               dp=net0.n_npus * 4)
    bad_pools[pool_id] = (over,) + tuple(entry[1:])
    rep = verify_trace(c.trace, c.cfg, c.par, bad_pools)
    assert any(i.code == "pool-capacity" and i.pool == pool_id
               and i.op is not None for i in rep.errors)
    # the structural memo must not have absorbed the contextual issue
    del c.trace._verify_report
    assert verify_trace(c.trace, c.cfg, c.par, c.pools).issues == ()


def test_unmapped_pool_is_a_warning(clear_dse_caches):
    sc = DisaggServeScenario(batch=16, seq=512)
    jobs = _jobs(sc, "fifo", n=3)
    assert jobs
    cfg, job = jobs[0]
    c = next(c for c in job.calls if c.pools and len(c.pools) > 1)
    keep = next(iter(c.pools))
    rep = verify_trace(c.trace, c.cfg, c.par, {keep: c.pools[keep]})
    assert rep.ok                       # warnings don't fail a run
    assert any(i.code == "pool-unmapped" and i.severity == "warning"
               for i in rep.warnings)
    del c.trace._verify_report


def test_simulate_and_run_sim_job_verify_flag():
    trace = Trace(ops=[_comp(0, (1,)), _comp(1, (0,))], meta={})
    calls_seen = []
    job = SimJob(calls=(), finalize=lambda rs: calls_seen.append(rs))
    run_sim_job(job, verify=True)       # empty job: nothing to verify
    assert calls_seen == [[]]
    ok = Trace(ops=[_comp(0), _comp(1, (0,))], meta={})
    plan = _sim_plan(ok)
    from repro.core.simulator import SystemConfig
    from repro.core.topology import system_2
    cfg = SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                       coll_algo=("ring",) * 4)
    par = Parallelism(1024, 1, 1, 1)
    with pytest.raises(PlanVerificationError):
        simulate(trace, cfg, par, verify=True)
    res = simulate(ok, cfg, par, verify=True)
    assert res.makespan_us > 0


# ---------------------------------------------------------------------------
# (d) PsA lint: satisfiability + dead knobs
# ---------------------------------------------------------------------------

def test_lint_unsatisfiable_constraint_pair():
    pset = ParameterSet(
        [Parameter("dp", "workload", (1, 2, 4, 8, 16, 32, 64, 128, 256,
                                      512, 1024)),
         Parameter("pp", "workload", (1, 2, 4))],
        [Constraint("product_eq", ("dp", "pp"), 1024),
         Constraint("product_le", ("dp", "pp"), 512)], name="unsat-pair")
    rep = lint_pset(pset)
    assert not rep.ok
    assert any(i.code == "constraint-unsat" and "pair" in i.message
               for i in rep.errors)


def test_lint_oversubscribed_sum_budget():
    pset = ParameterSet(
        [Parameter("t0_npus", "scenario", (256, 512)),
         Parameter("t1_npus", "scenario", (512, 1024))],
        [Constraint("sum_le", ("t0_npus", "t1_npus"), 512)], name="oversub")
    rep = lint_pset(pset)
    assert any(i.code == "constraint-unsat" and "oversubscribed" in i.message
               for i in rep.errors)


def test_lint_unreachable_product_target():
    pset = ParameterSet(
        [Parameter("npus_per_dim", "network", (4, 8), ndim=2)],
        [Constraint("product_eq", ("npus_per_dim",), 100)], name="unreach")
    assert any(i.code == "constraint-unsat"
               for i in lint_pset(pset).errors)


def test_lint_sampling_probe_catches_pinned_unsat():
    # analytically fine, but the pinned value makes sampling infeasible
    pset = ParameterSet(
        [Parameter("dp", "workload", (1, 2, 4)),
         Parameter("pp", "workload", (1, 2, 4))],
        [Constraint("product_eq", ("dp", "pp"), 16)],
        fixed={"dp": 1, "pp": 1}, name="pinned-unsat")
    rep = lint_pset(pset)
    assert any(i.code == "constraint-unsat" for i in rep.errors)


def test_lint_clean_paper_psa_and_dead_knob(clear_dse_caches):
    sc = TrainScenario(64, 512)
    env = _env(sc)
    pset = scenario_psa(paper_psa(1024), sc, 1024)
    assert lint_pset(pset, env=env).ok
    ghost = pset.extend([Parameter("phantom_knob", "scenario", (1, 2, 3))])
    rep = lint_pset(ghost, env=env)
    assert [i.constraint for i in rep.issues
            if i.code == "dead-knob"] == ["phantom_knob"]


def test_searched_params_and_violation_rates():
    pset = ParameterSet(
        [Parameter("a", "workload", (1, 2)),
         Parameter("b", "workload", (1,)),          # single choice: inert
         Parameter("c", "workload", (1, 2, 4))],
        [Constraint("product_le", ("a", "c"), 1)],
        fixed={"c": 1}, name="sp")
    assert [p.name for p in pset.searched_params()] == ["a"]
    rates = DesignSpace(pset).constraint_violation_rates(
        np.random.default_rng(0), tries=64)
    # a=2 violates product_le 1 in half the raw decodes
    assert 0.2 < rates["product(a, c) <= 1"] < 0.8


# ---------------------------------------------------------------------------
# (e) run_study preflight + lint_study end to end
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _DefectiveScenario:
    """Every design point yields a trace with a dependency cycle."""
    name: str = "defective-cyclic"

    def psa_params(self):
        return []

    def psa_constraints(self, n_npus):
        return []

    def traces(self, ctx):
        return {}

    def sim_job(self, ctx):
        trace = Trace(ops=[_comp(0, (1,)), _comp(1, (0,))], meta={})
        from repro.core.backends.base import SimCall
        call = SimCall(trace, ctx.sys_cfg, ctx.parallelism())
        return SimJob((call,), lambda rs: None)

    def evaluate(self, ctx):
        return run_sim_job(self.sim_job(ctx), ctx.backend)


@pytest.fixture()
def defective_scenario_kind():
    kind = "defective-cyclic-test"
    register_scenario(kind, lambda **p: _DefectiveScenario(),
                      replace_existing=True)
    yield kind
    SCENARIO_REGISTRY.pop(kind, None)


def _spec(scenario_kind, **over):
    d = dict(name="t", arch="qwen2-1.5b", system="system2",
             scenario=scenario_kind, agents=[{"kind": "rw"}], steps=5,
             batch_size=2, seeds=[0])
    d.update(over)
    return StudySpec.from_dict(d)


def test_run_study_preflight_fails_fast(defective_scenario_kind, tmp_path,
                                        clear_dse_caches):
    spec = _spec(defective_scenario_kind)
    with pytest.raises(PlanVerificationError) as ei:
        run_study(spec, out=tmp_path / "r.jsonl")
    assert any(i.code == "dep-cycle" for i in ei.value.report.errors)


def test_cli_run_exits_2_on_defective_plan(defective_scenario_kind,
                                           tmp_path, capsys,
                                           clear_dse_caches):
    from repro.dse import main
    spec_path = tmp_path / "bad.json"
    spec_path.write_text(json.dumps(_spec(defective_scenario_kind)
                                    .to_dict()))
    rc = main(["run", str(spec_path), "--out", str(tmp_path / "r.jsonl"),
               "--quiet"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "static verification failed" in err and "dep-cycle" in err


def test_lint_study_flags_defective_scenario(defective_scenario_kind,
                                             clear_dse_caches):
    rep = lint_study(_spec(defective_scenario_kind))
    assert isinstance(rep, AnalysisReport) and not rep.ok
    assert any(i.code == "dep-cycle" for i in rep.errors)


def test_lint_study_clean_and_cost_fields(clear_dse_caches):
    spec = _spec("train", scenario_params={"batch": 64, "seq": 512},
                 agents=[{"kind": "rw"}, {"kind": "ga", "steps": 9}])
    rep = lint_study(spec)
    assert rep.ok, rep.format()
    assert rep.info["cells"] == 2
    assert rep.info["evaluations_max"] == 5 + 9
    assert rep.info["trace_ops"] > 0
    assert float(rep.info["cardinality"]) > 1


def test_preflight_clean_and_gated(clear_dse_caches):
    sc = TrainScenario(64, 512)
    env = _env(sc)
    pset = scenario_psa(paper_psa(1024), sc, 1024)
    rep = preflight(env, pset, seed=0)
    assert rep is not None and rep.ok


# ---------------------------------------------------------------------------
# (f) overhead: verification must be a rounding error next to simulation
# ---------------------------------------------------------------------------

def test_verify_overhead_is_small(clear_dse_caches):
    """Steady-state verification (structural verdict re-derived, plan-level
    array conversions amortized like the plan itself) must stay well under
    the 5%% acceptance bound — asserted leniently here at 25%% because CI
    boxes are noisy and this trace is far smaller than the ~26k-op
    acceptance trace (fixed costs loom larger); the benchmark row
    (``benchmarks.run --only backends``) measures the real ratio."""
    sc = RequestStreamScenario(n_requests=32, seq=512, decode_tokens=16,
                               rate_rps=16.0, seed=0)
    (cfg, job), = _jobs(sc, "fifo", n=1)
    c = job.calls[0]
    simulate(c.trace, c.cfg, c.par, pools=c.pools)   # build + warm the plan
    verify_trace(c.trace, c.cfg, c.par, c.pools)     # amortized conversions
    sim_t = min(_timed(lambda: simulate(c.trace, c.cfg, c.par,
                                        pools=c.pools)) for _ in range(3))

    def cold_verify():
        if hasattr(c.trace, "_verify_report"):
            del c.trace._verify_report
        verify_trace(c.trace, c.cfg, c.par, c.pools)

    ver_t = min(_timed(cold_verify) for _ in range(5))
    assert ver_t < 0.25 * sim_t, \
        f"verify {ver_t * 1e3:.2f}ms vs simulate {sim_t * 1e3:.2f}ms"


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
