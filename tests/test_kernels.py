"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,s,h,g,hd", [
    (2, 256, 4, 2, 64),
    (1, 128, 2, 2, 32),
    (2, 128, 8, 1, 16),
    (1, 512, 4, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, s, h, g, hd, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, g, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, g, hd), dtype)
    o = ops.mha_flash(q, k, v, causal=True, block_q=64, block_k=64)
    rep = h // g
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kr = jnp.repeat(k, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vr = jnp.repeat(v, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    e = ref.attention_ref(qr, kr, vr, causal=True).reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(e, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    b, s, h, hd = 1, 256, 2, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    o = ops.mha_flash(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    e = ref.attention_ref(qr, kr, vr, causal=True, window=window) \
        .reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e), rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_invariance():
    b, s, h, hd = 1, 256, 2, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    o1 = ops.mha_flash(q, k, v, block_q=64, block_k=64)
    o2 = ops.mha_flash(q, k, v, block_q=128, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,s,h,g,hd,ds,chunk", [
    (2, 128, 4, 1, 16, 32, 64),
    (1, 256, 2, 2, 32, 16, 64),
    (2, 64, 4, 4, 8, 8, 32),
    (1, 128, 2, 1, 64, 64, 128),
])
def test_ssd_scan_vs_naive_recurrence(b, s, h, g, hd, ds, chunk):
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, s, h, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, s, g, ds))
    cc = jax.random.normal(ks[4], (b, s, g, ds))
    y, hl = ops.ssd(x, dt, a, bb, cc, chunk=chunk)
    rep = h // g
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    af = jnp.broadcast_to(a[None, :], (b, h)).reshape(b * h)
    bf = jnp.repeat(bb, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, ds)
    cf = jnp.repeat(cc, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, ds)
    ye, he = ref.ssd_ref(xf, dtf, af, bf, cf)
    scale = float(jnp.max(jnp.abs(ye))) + 1e-9
    err = float(jnp.max(jnp.abs(y - ye.reshape(b, h, s, hd).transpose(0, 2, 1, 3))))
    assert err / scale < 1e-4
    herr = float(jnp.max(jnp.abs(hl.transpose(0, 1, 3, 2).reshape(b * h, ds, hd) - he)))
    assert herr / (float(jnp.max(jnp.abs(he))) + 1e-9) < 1e-4


def test_ssd_kernel_matches_model_path():
    """Kernel vs the model's scan-over-chunks jnp implementation."""
    from repro.models.mamba import ssd_chunked
    b, s, h, hd, ds = 2, 128, 4, 16, 32
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, s, h, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, s, 1, ds))
    cc = jax.random.normal(ks[4], (b, s, 1, ds))
    yk, hk = ops.ssd(x, dt, a, bb, cc, chunk=64)
    ym, hm = ssd_chunked(x, dt, a, bb, cc, chunk=64)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hm), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rows,d", [(128, 256), (64, 1024), (37 * 4, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(rows, d, dtype):
    x = (jax.random.normal(RNG, (rows, d)) * 3).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)).astype(dtype) * 0.1
    o = ops.fused_rmsnorm(x, w)
    e = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(e, np.float32), **_tol(dtype))


def test_flash_matches_model_attention_path():
    """Kernel vs the model's flash_attention_ref (online-softmax jnp twin)."""
    from repro.models.attention import flash_attention_ref
    b, s, h, hd = 1, 256, 2, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    positions = jnp.arange(s, dtype=jnp.int32)
    o_model = flash_attention_ref(q, k, v, positions, kv_chunk=64)
    o_kernel = ops.mha_flash(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               rtol=2e-5, atol=2e-5)
