"""Property-based PSS tests (hypothesis-only; the deterministic PsA/PSS
cases live in test_psa.py and always run)."""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property-based PSS tests need the `test` extra")
from hypothesis import given, settings, strategies as st

from repro.core.psa import paper_psa
from repro.core.space import DesignSpace


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sample_always_valid(seed):
    ds = DesignSpace(paper_psa(1024))
    cfg = ds.sample(np.random.default_rng(seed))
    assert ds.is_valid(cfg)
    assert cfg["dp"] * cfg["sp"] * cfg["pp"] <= 1024
    assert np.prod(cfg["npus_per_dim"]) == 1024


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_encode_decode_roundtrip(seed):
    ds = DesignSpace(paper_psa(1024))
    cfg = ds.sample(np.random.default_rng(seed))
    assert ds.decode(ds.encode(cfg)) == cfg
    norm = ds.normalize(ds.encode(cfg))
    assert ((0.0 <= norm) & (norm <= 1.0)).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mutate_crossover_stay_valid(seed):
    rng = np.random.default_rng(seed)
    ds = DesignSpace(paper_psa(1024))
    a, b = ds.sample(rng), ds.sample(rng)
    assert ds.is_valid(ds.mutate(a, rng))
    assert ds.is_valid(ds.crossover(a, b, rng))
