"""Multi-device behaviours (subprocess: device count is locked at jax init).

Covers: GPipe pipeline correctness, compressed all-reduce numerics, sharded
train step == single-device train step, elastic checkpoint resharding.
"""
from __future__ import annotations

import pytest

from helpers import run_with_devices


@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_forward
        mesh = make_mesh((4,), ("pipe",))
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])
        rng = jax.random.PRNGKey(0); d = 16
        params = {"w": jax.random.normal(rng, (4, d, d)) * 0.5,
                  "b": jnp.zeros((4, d))}
        mbs = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d))
        pf = pipeline_forward(stage_fn, mesh, "pipe")
        with mesh:
            out = jax.jit(pf)(params, mbs)
        ref = mbs
        for i in range(4):
            ref = jnp.tanh(ref @ params["w"][i] + params["b"][i])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_psum_numerics_and_error_feedback():
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_mesh
        from repro.parallel.compression import compressed_psum, init_error_state, wire_bytes
        mesh = make_mesh((4,), ("dp",))
        g_local = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 64))

        def f(g, e):
            out, err = compressed_psum({"w": g}, "dp", {"w": e}, bits=8)
            return out["w"], err["w"]

        sf = shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                       out_specs=(P("dp"), P("dp")), check_rep=False)
        e0 = jnp.zeros_like(g_local)
        red, e1 = sf(g_local, e0)
        true_mean = jnp.mean(g_local, axis=0, keepdims=True)
        red_any = red[0:1]
        rel = float(jnp.max(jnp.abs(red_any - true_mean)) / jnp.max(jnp.abs(true_mean)))
        assert rel < 0.05, rel          # 8-bit quantization error bound
        # error feedback: residual equals what quantization dropped
        assert float(jnp.max(jnp.abs(e1))) > 0
        comp, full = wire_bytes({"w": g_local[0]})
        assert comp * 3.5 < full
        print("OK", rel)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, reduced
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import plan_for_mesh, NULL_PLAN
        from repro.train.train_step import RunConfig, init_train_state, make_train_step
        spec = reduced(ARCHS["qwen2-1.5b"])
        cfg = RunConfig(remat="none")
        rng = jax.random.PRNGKey(0)
        state0 = init_train_state(rng, spec, cfg)
        batch = {"inputs": np.random.default_rng(0).integers(0, spec.vocab_size, (8, 32)).astype(np.int32),
                 "labels": np.random.default_rng(1).integers(0, spec.vocab_size, (8, 32)).astype(np.int32)}
        # single device
        s1, m1 = jax.jit(make_train_step(spec, NULL_PLAN, cfg))(state0, batch)
        # 2x2 mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        plan = plan_for_mesh(mesh)
        with mesh:
            s2, m2 = jax.jit(make_train_step(spec, plan, cfg))(state0, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
        l1 = jax.tree.leaves(s1["params"])[3]
        l2 = jax.tree.leaves(s2["params"])[3]
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3, atol=1e-5)
        print("OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    out = run_with_devices(4, """
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import save, restore
        from repro.launch.mesh import make_mesh
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((8,), jnp.float32)}
        d = tempfile.mkdtemp()
        save(d, tree, step=5)
        # restore onto a 4-way mesh with a different layout
        mesh = make_mesh((4,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P(None))}
        restored, step = restore(d, tree, shardings=sh)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
        assert restored["w"].sharding.spec == P("data", None)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_rescale_end_to_end():
    """Train on a 4-device mesh, checkpoint, resume on a 2-device mesh
    (simulating the loss of half the cluster), losses keep decreasing."""
    out = run_with_devices(4, """
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, reduced
        from repro.ckpt.checkpoint import save, restore
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import plan_for_mesh, tree_shardings
        from repro.train.train_step import (RunConfig, init_train_state,
                                            make_train_step, train_state_axes)
        from repro.data.pipeline import DataConfig, SyntheticLM

        spec = reduced(ARCHS["qwen2-1.5b"], n_layers=2)
        cfg = RunConfig(remat="none")
        data = SyntheticLM(spec, DataConfig(8, 32, seed=0))
        ckdir = tempfile.mkdtemp()

        # phase 1: 2x2 mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        plan = plan_for_mesh(mesh)
        state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
        step = jax.jit(make_train_step(spec, plan, cfg))
        with mesh:
            for i in range(5):
                state, m = step(state, data.batch_at(i))
        save(ckdir, state, step=5)
        l5 = float(m["loss"])

        # phase 2: "lose" half the devices -> 2x1 mesh, restore + continue
        mesh2 = make_mesh((2, 1), ("data", "model"))
        plan2 = plan_for_mesh(mesh2)
        ax = train_state_axes(spec, cfg)
        specs = jax.tree.map(lambda a, s: plan2.spec(a, np.shape(s)), ax, state,
                             is_leaf=lambda x: isinstance(x, tuple) and all(
                                 isinstance(e, (str, type(None))) for e in x))
        sh = tree_shardings(mesh2, specs)
        state2, start = restore(ckdir, state, shardings=sh)
        assert start == 5
        step2 = jax.jit(make_train_step(spec, plan2, cfg))
        with mesh2:
            for i in range(start, start + 5):
                state2, m2 = step2(state2, data.batch_at(i))
        assert int(state2["step"]) == 10
        assert np.isfinite(float(m2["loss"]))
        print("OK", l5, float(m2["loss"]))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dp_explicit_with_gradient_compression():
    """Explicit-DP shard_map train step: compressed(int8+EF) gradients track
    the uncompressed run; loss decreases in both."""
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, reduced
        from repro.launch.mesh import make_mesh
        from repro.parallel.dp_explicit import make_dp_train_step
        from repro.train.train_step import RunConfig, init_train_state
        from repro.train import optimizer as opt
        from repro.data.pipeline import DataConfig, SyntheticLM

        spec = reduced(ARCHS["qwen2-1.5b"], n_layers=2)
        cfg = RunConfig(remat="none", opt=opt.OptConfig(lr=6e-3, warmup_steps=2))
        mesh = make_mesh((4,), ("data",))
        data = SyntheticLM(spec, DataConfig(8, 32, seed=0))

        runs = {}
        for bits in (0, 8):
            step, init_extra = make_dp_train_step(spec, mesh, cfg, compress_bits=bits)
            state = init_extra(init_train_state(jax.random.PRNGKey(0), spec, cfg))
            jstep = jax.jit(step)
            losses = []
            with mesh:
                for i in range(25):
                    state, m = jstep(state, data.batch_at(i))
                    losses.append(float(m["loss"]))
            runs[bits] = losses
        l0, l8 = runs[0], runs[8]
        assert np.mean(l0[-5:]) < np.mean(l0[:5]) - 0.02, l0
        assert np.mean(l8[-5:]) < np.mean(l8[:5]) - 0.02, l8
        # compressed training tracks uncompressed within a loose band
        assert abs(np.mean(l8[-5:]) - np.mean(l0[-5:])) < 0.15
        print("OK", np.mean(l0[-5:]), np.mean(l8[-5:]))
    """)
    assert "OK" in out
