"""Property-based collective cost-model tests (hypothesis-only; the
deterministic simulator cases live in test_simulator.py and
test_sim_invariants.py and always run)."""
from __future__ import annotations

import pytest

pytest.importorskip(
    "hypothesis", reason="property-based simulator tests need the `test` extra")
from hypothesis import given, settings, strategies as st

from repro.core.collectives import collective_time_us
from repro.core.topology import TopoDim


@settings(max_examples=40, deadline=None)
@given(size=st.floats(1e3, 1e12), algo=st.sampled_from(["ring", "direct", "rhd", "dbt"]),
       kind=st.sampled_from(["all_reduce", "all_gather", "reduce_scatter", "all_to_all"]),
       topo=st.sampled_from(["ring", "switch", "fc"]),
       n=st.sampled_from([2, 4, 8, 16]))
def test_collective_time_positive_and_monotone(size, algo, kind, topo, n):
    d = TopoDim(topo, n, 200.0)
    t1 = collective_time_us(kind, size, d, algo)
    t2 = collective_time_us(kind, size * 2, d, algo)
    assert t1 > 0
    assert t2 >= t1  # monotone in message size
