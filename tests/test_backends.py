"""Simulation-backend API tests: registry plumbing, ReferenceBackend
bit-identity against pre-refactor golden values, SystemConfig validation,
the SimJob batch driver, and (jax-guarded) JaxBackend parity across every
scenario trace family and both scheduling policies."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.backends import (SimCall, SimJob, backend_available,
                                 get_backend, list_backends,
                                 register_backend, run_sim_job, run_sim_jobs)
from repro.core.compute import SYSTEM_2_DEVICE
from repro.core.env import CosmicEnv
from repro.core.psa import paper_psa
from repro.core.scenario import (DisaggServeScenario, MultiTenantScenario,
                                 RequestStreamScenario, Tenant, scenario_psa)
from repro.core.simulator import SystemConfig, simulate
from repro.core.space import DesignSpace
from repro.core.systems import system_env
from repro.core.topology import system_2
from repro.core.workload import Parallelism, generate_trace


def _sys(policy: str = "fifo") -> SystemConfig:
    return SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                        coll_algo=("ring", "direct", "ring", "rhd"),
                        chunks=2, sched_policy=policy)


BASE_CFG = dict(dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
                coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
                multidim_coll="baseline",
                topology=("ring", "fc", "ring", "switch"),
                npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtins_and_rejects_unknown():
    assert {"reference", "jax"} <= set(list_backends())
    assert get_backend("reference").name == "reference"
    assert get_backend(None).name == "reference"  # the default
    # an instance passes through untouched
    be = get_backend("reference")
    assert get_backend(be) is be
    with pytest.raises(ValueError, match="unknown simulation backend"):
        get_backend("not-a-backend")
    with pytest.raises(ValueError, match="already registered"):
        register_backend("reference", lambda: None)
    assert backend_available("reference")
    assert not backend_available("not-a-backend")


def test_env_and_simulate_reject_unknown_backend():
    with pytest.raises(ValueError, match="unknown simulation backend"):
        CosmicEnv(spec=ARCHS["qwen2-1.5b"], n_npus=1024,
                  device=SYSTEM_2_DEVICE, batch=64, seq=2048,
                  backend="not-a-backend")
    par = Parallelism(64, dp=64, sp=1, pp=1)
    tr = generate_trace(ARCHS["qwen2-1.5b"], par, batch=64, seq=128)
    with pytest.raises(ValueError, match="unknown simulation backend"):
        simulate(tr, _sys(), par, backend="not-a-backend")


# ---------------------------------------------------------------------------
# SystemConfig validation (pinned): a typo'd sched_policy used to silently
# schedule as FIFO
# ---------------------------------------------------------------------------

def test_sched_policy_validated_at_construction():
    for ok in ("fifo", "lifo"):
        assert _sys(ok).sched_policy == ok
    for bad in ("lifoo", "FIFO", "", "random"):
        with pytest.raises(ValueError, match="unknown sched_policy"):
            _sys(bad)


# ---------------------------------------------------------------------------
# ReferenceBackend bit-identity: golden makespans captured from the
# pre-backend simulate() (PR-4 tree), exact to the last ulp
# ---------------------------------------------------------------------------

def test_reference_backend_matches_pre_refactor_golden_values():
    cases = [
        ("gpt3-13b", Parallelism(1024, 64, 4, 1, True), 1024, "train",
         16271035.786701888, 16185591.472128013, 85444.3145738747),
        ("gpt3-175b", Parallelism(1024, 32, 8, 1, True), 1024, "train",
         217819100.03438663, 216970720.1298433, 848379.9045433402),
        ("gpt3-13b", Parallelism(1024, 64, 4, 1), 64, "decode",
         137863.06259999986, 137621.4177999999, 241.64479999995092),
        # dp-grad-overlap-heavy shape (the sched-policy stress case)
        ("gpt3-175b", Parallelism(1024, 64, 1, 1, True), 1024, "train",
         218434834.8352596, None, 1452035.1098963022),
    ]
    for arch, par, batch, mode, makespan, compute, exposed in cases:
        tr = generate_trace(ARCHS[arch], par, batch=batch, seq=2048,
                            mode=mode)
        for policy in ("fifo", "lifo"):
            res = simulate(tr, _sys(policy), par)
            assert res.makespan_us == makespan, (arch, mode, policy)
            assert res.exposed_comm_us == exposed, (arch, mode, policy)
            if compute is not None:
                assert res.compute_busy_us == compute, (arch, mode, policy)


def test_scenario_golden_values_via_reference_backend():
    """Multi-pool + delay-op traces: disagg and request-stream evaluations
    pinned against pre-refactor values (xfer, gates, releases, repeats)."""
    disagg = system_env("qwen2-1.5b", "system2",
                        scenario=DisaggServeScenario(64, 2048, 16),
                        objective="latency")
    ev = disagg.evaluate_config(dict(BASE_CFG, prefill_frac=0.5,
                                     decode_batch=4))
    assert ev.latency_ms == 235.54705323946763
    assert ev.reward == 0.004245436256777772

    stream = system_env(
        "qwen2-1.5b", "system2",
        scenario=RequestStreamScenario(n_requests=32, seq=1024,
                                       decode_tokens=16, rate_rps=16.0,
                                       seed=3),
        objective="goodput")
    ev = stream.evaluate_config(dict(BASE_CFG, prefill_frac=0.5,
                                     decode_batch=4, batch_window_ms=50.0,
                                     max_inflight=2))
    assert ev.latency_ms == 74.93265646512177
    assert ev.reward == 18.606955522152628


def test_simulate_is_a_thin_delegate():
    """Module-level simulate() == ReferenceBackend.simulate, field for
    field, including the opt-in recording flags."""
    par = Parallelism(1024, 64, 4, 1, True)
    tr = generate_trace(ARCHS["gpt3-13b"], par, batch=1024, seq=2048)
    via_delegate = simulate(tr, _sys(), par, record_per_op=True)
    direct = get_backend("reference").simulate(tr, _sys(), par,
                                               record_per_op=True)
    assert via_delegate == direct
    assert via_delegate.per_op_us and via_delegate.op_finish_us


# ---------------------------------------------------------------------------
# SimJob driver: grouped batch execution == per-job execution
# ---------------------------------------------------------------------------

def test_run_sim_jobs_groups_by_trace_and_matches_serial():
    env = system_env("qwen2-1.5b", "system2", batch=64, seq=2048)
    cfgs = [dict(BASE_CFG, chunks=c) for c in (2, 4, 8)]
    jobs = [env.scenario.sim_job(env.context(c)) for c in cfgs]
    assert all(isinstance(j, SimJob) for j in jobs)
    batched = run_sim_jobs(jobs, "reference")
    serial = [env.evaluate_config(c) for c in cfgs]
    assert [b.reward for b in batched] == [s.reward for s in serial]
    assert [b.latency_ms for b in batched] == [s.latency_ms for s in serial]
    # terminal evaluations (gated-invalid points) pass through in order
    bad = dict(BASE_CFG, dp=512, sp=4, pp=4)  # dp*sp*pp > n_npus
    mixed = [env.scenario.sim_job(env.context(c)) for c in (cfgs[0], bad)]
    out = run_sim_jobs(mixed, "reference")
    assert out[0].valid and not out[1].valid


def test_run_sim_job_passes_evaluations_through():
    from repro.core.rewards import Evaluation

    ev = Evaluation(0.0, float("inf"), False, {"why": "gated"})
    assert run_sim_job(ev, "reference") is ev


# ---------------------------------------------------------------------------
# JaxBackend parity (guarded like hypothesis: the jax extra is optional)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

RTOL = 1e-9


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-12)


def test_jax_parity_train_trace_both_policies():
    jb = get_backend("jax")
    for arch, par in (("gpt3-13b", Parallelism(1024, 64, 4, 1, True)),
                      ("gpt3-175b", Parallelism(1024, 64, 1, 1, True))):
        tr = generate_trace(ARCHS[arch], par, batch=1024, seq=2048)
        for policy in ("fifo", "lifo"):
            ref = simulate(tr, _sys(policy), par)
            got = jb.simulate(tr, _sys(policy), par)
            assert _rel(got.makespan_us, ref.makespan_us) < RTOL
            assert _rel(got.compute_busy_us, ref.compute_busy_us) < RTOL
            for k, v in ref.comm_busy_us.items():
                assert _rel(got.comm_busy_us[k], v) < RTOL


@pytest.mark.parametrize("policy", ["fifo", "lifo"])
def test_jax_parity_all_scenarios(policy):
    """Env-level parity on all four scenario families — rewards and
    latencies agree between the jax sweep and the reference event loop."""
    scenarios = [
        ("train", None, {}),
        ("disagg", DisaggServeScenario(64, 2048, 16),
         dict(prefill_frac=0.5, decode_batch=4)),
        ("stream", RequestStreamScenario(n_requests=24, seq=1024,
                                         decode_tokens=16, rate_rps=16.0,
                                         seed=3),
         dict(prefill_frac=0.5, decode_batch=4, batch_window_ms=50.0,
              max_inflight=2)),
        ("tenants", MultiTenantScenario(tenants=(
            Tenant("a", ARCHS["gpt3-13b"], 512, 2048, "train", slo_ms=5e5),
            Tenant("b", ARCHS["qwen2-1.5b"], 64, 2048, "serve",
                   slo_ms=5e4))),
         dict(tenant_npus=(512, 256))),
    ]
    for name, sc, extra in scenarios:
        kw = dict(scenario=sc) if sc is not None else dict(batch=64)
        obj = "goodput" if name == "stream" else "perf_per_bw"
        env_ref = system_env("qwen2-1.5b", "system2", objective=obj, **kw)
        env_jax = system_env("qwen2-1.5b", "system2", objective=obj,
                             backend="jax", **kw)
        cfg = dict(BASE_CFG, sched_policy=policy, **extra)
        ref = env_ref.evaluate_config(cfg)
        got = env_jax.evaluate_config(cfg)
        assert ref.valid and got.valid, name
        assert _rel(got.latency_ms, ref.latency_ms) < RTOL, name
        assert _rel(got.reward, ref.reward) < RTOL, name


def test_jax_parity_seeded_design_space_sweep():
    """Random full-stack design points: jax and reference agree on every
    valid point (and on which points gate invalid)."""
    env_ref = system_env("gpt3-13b", "system2")
    env_jax = system_env("gpt3-13b", "system2", backend="jax")
    space = DesignSpace(paper_psa(1024, max_pp=4))
    rng = np.random.default_rng(7)
    checked = 0
    for _ in range(12):
        cfg = space.sample(rng)
        ref = env_ref.evaluate_config(cfg)
        got = env_jax.evaluate_config(cfg)
        assert got.valid == ref.valid
        if ref.valid:
            checked += 1
            assert _rel(got.latency_ms, ref.latency_ms) < RTOL
    assert checked >= 3  # the sweep actually exercised valid points


def test_jax_batch_is_bit_identical_to_jax_single():
    """simulate_batch over a population == simulate per point (the same
    compiled sweep runs either way)."""
    jb = get_backend("jax")
    par = Parallelism(1024, 64, 4, 1, True)
    tr = generate_trace(ARCHS["qwen2-1.5b"], par, batch=1024, seq=2048)
    cfgs = [SystemConfig(network=system_2(), device=SYSTEM_2_DEVICE,
                         coll_algo=("ring", "direct", "ring", "rhd"),
                         chunks=c, sched_policy=p)
            for c, p in ((2, "fifo"), (8, "lifo"), (16, "fifo"))]
    batch = jb.simulate_batch(tr, [SimCall(tr, c, par) for c in cfgs])
    for cfg, got in zip(cfgs, batch):
        one = jb.simulate(tr, cfg, par)
        assert got.makespan_us == one.makespan_us
        assert got.comm_busy_us == one.comm_busy_us


def test_jax_step_batch_routes_through_simulate_batch():
    """The env's vectorized path (dedupe -> sim_job -> grouped
    simulate_batch) returns exactly what serial jax evaluation returns,
    in input order, with history recorded once per occurrence."""
    sc = RequestStreamScenario(n_requests=24, seq=1024, decode_tokens=16,
                               rate_rps=16.0, seed=3)
    env = system_env("qwen2-1.5b", "system2", scenario=sc,
                     objective="goodput", backend="jax")
    base = dict(BASE_CFG, prefill_frac=0.5, decode_batch=4,
                batch_window_ms=50.0, max_inflight=2)
    cfgs = [dict(base, chunks=c) for c in (2, 4, 8, 4)]  # one duplicate
    out = env.step_batch(cfgs)
    assert len(out) == 4 and len(env.history) == 4
    assert out[1].reward == out[3].reward  # dedupe returned the memo entry
    serial = [env.evaluate_config(c) for c in cfgs]
    assert [o.reward for o in out] == [s.reward for s in serial]


def test_backends_do_not_cross_hit_a_shared_eval_store():
    """The env signature includes the backend, so reference and jax envs
    sharing one eval_store keep separate entries."""
    store: dict = {}
    kw = dict(batch=64, seq=2048, eval_store=store)
    env_ref = system_env("qwen2-1.5b", "system2", **kw)
    env_jax = system_env("qwen2-1.5b", "system2", backend="jax", **kw)
    env_ref.step(dict(BASE_CFG))
    env_jax.step(dict(BASE_CFG))
    assert env_ref.store_misses == 1 and env_ref.store_hits == 0
    assert env_jax.store_misses == 1 and env_jax.store_hits == 0
    assert len(store) == 2
