"""Table 5/6: co-design use cases.

Table 5: full-stack DSE for GPT3-175B on System 2 under both objectives
(the two discovered configurations differ in the network stack).

Table 6 Expr 1: workload+network co-design (collective stack fixed) over an
ensemble of all four paper workloads (multi-model).
Table 6 Expr 2: collective+network co-design (workload fixed) for GPT3-175B
inference — chat (long prefill) and QA (short) — where latency-optimized
collectives should win.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SEEDS, STEPS, emit, make_env, make_pset, timed
from repro.core.dse import run_search


def _fmt(cfg: dict) -> str:
    keys = ("dp", "pp", "sp", "weight_sharded", "sched_policy", "chunks",
            "multidim_coll", "coll_algo", "topology", "npus_per_dim")
    return " ".join(f"{k}={cfg[k]}" for k in keys if k in cfg)


def table5(steps: int) -> list[tuple]:
    rows = []
    for objective in ("perf_per_bw", "perf_per_cost"):
        ps = make_pset("system2")
        res = max((run_search(ps, make_env("gpt3-175b", "system2", objective=objective),
                              "ga", steps=steps, seed=s) for s in SEEDS),
                  key=lambda r: r.best_reward)
        rows.append((f"table5_{objective}", 0.0,
                     f"reward={res.best_reward:.3e} | {_fmt(res.best_config)}"))
    return rows


def table6_expr1(steps: int) -> list[tuple]:
    """multi-model: optimize workload+network jointly, sum of rewards over
    the four workloads; collective stack pinned."""
    ps = make_pset("system2", stacks={"workload", "network"})
    envs = [make_env(a, "system2") for a in
            ("gpt3-175b", "gpt3-13b", "vit-base", "vit-large")]

    from repro.core.agents import make_agent
    from repro.core.space import DesignSpace
    space = DesignSpace(ps)
    agent = make_agent("ga", space, seed=0)
    best_r, best_cfg = -1.0, None
    for _ in range(steps):
        cfg = agent.propose()
        r = float(np.mean([e.step(cfg).reward for e in envs]))
        agent.observe(cfg, r)
        if r > best_r:
            best_r, best_cfg = r, cfg
    return [("table6_expr1_multimodel", 0.0,
             f"reward={best_r:.3e} | {_fmt(best_cfg)}")]


def table6_expr2(steps: int) -> list[tuple]:
    rows = []
    for name, seq in (("chat", 2048), ("qa", 512)):
        ps = make_pset("system2", stacks={"collective", "network"})
        env = make_env("gpt3-175b", "system2", batch=64, seq=seq, mode="serve")
        res = max((run_search(ps, env, "ga", steps=steps, seed=s) for s in SEEDS),
                  key=lambda r: r.best_reward)
        cfg = res.best_config
        lat_opt = sum(a in ("direct", "rhd", "dbt") for a in cfg["coll_algo"])
        rows.append((f"table6_expr2_{name}", 0.0,
                     f"latency_optimized_algos={lat_opt}/4 | {_fmt(cfg)}"))
    return rows


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or STEPS
    out, us = timed(lambda: table5(steps) + table6_expr1(steps) + table6_expr2(steps))
    return [(n, us / (5 * steps), d) for n, _, d in out]


if __name__ == "__main__":
    emit(run())
