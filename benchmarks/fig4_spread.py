"""Fig. 4: latency spread across the design space.

(a) workload-only spread for GPT3-175B on System 2 (paper: 64.5x),
(d) full-stack spread (paper: up to 103x), (e,f) GPT3-13B / ViT-Large
workload-only, (g,h) ViT full-stack.  We sample the space uniformly and
report max/min latency over valid points.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import STEPS, emit, make_env, make_pset, timed
from repro.core.space import DesignSpace


def _spread(arch: str, stacks, n_samples: int, seed: int = 0,
            batch: int = 1024) -> tuple[float, float, float]:
    # the paper's Fig-4 motivation measures the RAW latency spread of the
    # space (no memory validity gate): disable the 24 GB cap here
    env = make_env(arch, "system2", batch=batch)
    env.capacity_gb = float("inf")
    ds = DesignSpace(make_pset("system2", stacks=stacks))
    rng = np.random.default_rng(seed)
    lats = []
    for _ in range(n_samples):
        ev = env.step(ds.sample(rng))
        if ev.valid:
            lats.append(ev.latency_ms)
    lats = np.asarray(lats)
    return float(lats.min()), float(lats.max()), float(lats.max() / lats.min())


def run(n_samples: int | None = None) -> list[tuple]:
    n = n_samples or STEPS
    rows = []
    cases = [
        ("fig4a_gpt3-175b_workload_only", "gpt3-175b", {"workload"}, 1024),
        ("fig4d_gpt3-175b_full_stack", "gpt3-175b", None, 1024),
        ("fig4e_gpt3-13b_workload_only", "gpt3-13b", {"workload"}, 1024),
        ("fig4f_vit-large_workload_only", "vit-large", {"workload"}, 4096),
        ("fig4g_vit-large_full_stack", "vit-large", None, 4096),
        ("fig4h_vit-base_full_stack", "vit-base", None, 4096),
    ]
    for name, arch, stacks, batch in cases:
        (lo, hi, ratio), us = timed(lambda: _spread(arch, stacks, n, batch=batch))
        rows.append((name, us / n, f"spread={ratio:.1f}x min_ms={lo:.1f} max_ms={hi:.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
