"""§Roofline: three-term roofline per (arch x shape) from the dry-run.

    compute    = HLO_FLOPs / (chips * peak)            [197 TFLOP/s bf16]
    memory     = HLO_bytes / (chips * HBM bw)          [819 GB/s]
    collective = wire_bytes / (chips * link bw)        [~50 GB/s/link ICI]

HLO totals come from the loop-aware analyzer (per-device, execution-
weighted); wire bytes apply the per-kind algorithm factor to each
collective's payload using its replica-group size g:
    all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
    collective-permute 1.

Also reported per cell: MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D
(inference), the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant
term, and an upper-bound utilization proxy
    util = ideal_time / max(terms)   (perfect-overlap roofline fraction).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per ICI link

_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
    "collective-broadcast": lambda g: (g - 1) / g,
}


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    tag: str
    kind: str
    status: str
    chips: int = 0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_flops: float = 0.0
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    wire_bytes: float = 0.0
    mem_gib: float = 0.0
    hlo_bytes_raw: float = 0.0
    knobs: dict | None = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def ideal_s(self) -> float:
        return self.model_flops / (self.chips * PEAK_FLOPS) if self.chips else 0.0

    @property
    def util(self) -> float:
        m = max(self.compute_s, self.memory_s, self.collective_s)
        return self.ideal_s / m if m else 0.0

    @property
    def flops_ratio(self) -> float:
        return (self.model_flops / self.chips / self.hlo_flops
                if self.hlo_flops and self.chips else 0.0)


def wire_bytes_per_device(hlo: dict) -> float:
    total = 0.0
    for key, b in hlo.get("collective_by_group", {}).items():
        kind, g = key.rsplit("@", 1)
        g = max(int(g), 1)
        f = _WIRE_FACTOR.get(kind, lambda g: 1.0)(g) if g > 1 else 0.0
        total += b * f
    return total


def load_cell(path: str | Path) -> Cell:
    r = json.loads(Path(path).read_text())
    c = Cell(arch=r["arch"], shape=r["shape"], mesh=r["mesh"], tag=r.get("tag", ""),
             kind=r.get("kind", ""), status=r["status"], knobs=r.get("knobs"))
    if r["status"] != "ok":
        return c
    c.chips = r["n_chips"]
    c.hlo_flops = r["hlo"]["flops_per_device"]
    # fusion-optimistic bytes when available (TPU-like); raw boundary bytes
    # otherwise (older records)
    c.hlo_bytes = r["hlo"].get("fused_bytes_per_device") or r["hlo"]["bytes_per_device"]
    c.hlo_bytes_raw = r["hlo"]["bytes_per_device"]
    c.wire_bytes = wire_bytes_per_device(r["hlo"])
    c.model_flops = r["model_flops"]
    c.mem_gib = r["memory"]["peak_bytes_per_device"] / 2**30
    c.compute_s = c.hlo_flops / PEAK_FLOPS
    c.memory_s = c.hlo_bytes / HBM_BW
    c.collective_s = c.wire_bytes / LINK_BW
    return c


def load_all(out_dir: str = "results/dryrun", mesh: str = "pod",
             tag: str = "") -> list[Cell]:
    cells = []
    for f in sorted(glob.glob(f"{out_dir}/*__{mesh}{('__' + tag) if tag else ''}.json")):
        stem = Path(f).stem
        parts = stem.split("__")
        if not tag and len(parts) > 3:
            continue  # skip tagged (hillclimb) variants in the baseline table
        cells.append(load_cell(f))
    return cells


def markdown_table(cells: list[Cell]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| model/HLO flops | util | mem GiB |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for c in cells:
        if c.status != "ok":
            lines.append(f"| {c.arch} | {c.shape} | - | - | - | {c.status} | - | - | - |")
            continue
        lines.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e} "
            f"| {c.collective_s:.3e} | **{c.dominant}** | {c.flops_ratio:.2f} "
            f"| {c.util:.2f} | {c.mem_gib:.1f} |")
    return "\n".join(lines)


def run(out_dir: str = "results/dryrun") -> list[tuple]:
    cells = load_all(out_dir)
    rows = []
    for c in cells:
        if c.status != "ok":
            rows.append((f"roofline_{c.arch}_{c.shape}", 0.0, c.status))
            continue
        rows.append((
            f"roofline_{c.arch}_{c.shape}", 0.0,
            f"compute={c.compute_s:.3e}s memory={c.memory_s:.3e}s "
            f"collective={c.collective_s:.3e}s dominant={c.dominant} "
            f"flops_ratio={c.flops_ratio:.2f} util={c.util:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
