"""Surrogate subsystem benchmark: predictor fidelity on a real eval-store
corpus + surrogate-guided search vs plain GA at an EQUAL true-simulation
budget (the acceptance comparison) + the warm-start effect.

Rows (gpt3-13b on system2, the paper's Fig. 10 workload):

* ``surrogate_fidelity[model]`` — holdout Spearman rank correlation and
  top-k recall of each registered predictor on a >=500-point corpus of
  true evaluations (``BENCH_SURR_CORPUS`` scales it; CI runs a small one).
* ``surrogate_screen_rate`` — candidates scored per second through the
  fitted predictor (the screening hot path: pool featurization + predict).
* ``surrogate_vs_ga`` — mean best reward over seeds, both agents given the
  same number of true simulations; the surrogate additionally screens a
  ~10^4 pool per generation for free.
* ``surrogate_warm_start`` — cold vs warm-started surrogate at HALF the
  budget: the warm agent's predictor starts from the corpus a previous
  campaign persisted.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import SEEDS, STEPS, make_env, make_pset
from repro.core.dse import run_search
from repro.core.space import DesignSpace
from repro.core.surrogate import (SURROGATE_REGISTRY, Featurizer,
                                  build_dataset, holdout_fidelity,
                                  make_surrogate)

ARCH = "gpt3-13b"
CORPUS = int(os.environ.get("BENCH_SURR_CORPUS", "1000"))


def make_corpus(n: int = CORPUS, seed: int = 7):
    """n true evaluations of constraint-valid random design points — the
    stand-in for what a persistent eval store accumulates over campaigns."""
    env = make_env(ARCH, "system2")
    space = DesignSpace(make_pset("system2"))
    rng = np.random.default_rng(seed)
    cfgs = space.sample_batch(n, rng)
    evs = env.step_batch(cfgs)
    return space, [(c, ev.reward) for c, ev in zip(cfgs, evs)]


def fidelity_rows(space: DesignSpace, records) -> list[tuple]:
    feat = Featurizer(space)
    ds = build_dataset(feat, records)
    rows = []
    for name in sorted(SURROGATE_REGISTRY):
        t0 = time.time()
        rep = holdout_fidelity(name, ds.X, ds.y, seed=0)
        fit_s = time.time() - t0
        rows.append((f"surrogate_fidelity[{name}]", fit_s * 1e6,
                     f"spearman={rep['spearman']:.3f} "
                     f"topk_recall={rep['topk_recall']:.2f} "
                     f"n_train={rep['n_train']} n_holdout={rep['n_holdout']} "
                     f"n_features={feat.n_features}"))
    # screening throughput: featurize + score a 10^4 raw pool through the
    # fitted default model (the per-generation cost the agent pays instead
    # of 10^4 simulations)
    model = make_surrogate("knn", seed=0)
    model.fit(ds.X, ds.y)
    rng = np.random.default_rng(0)
    pool = space.raw_decode_batch(10_000, rng)
    t0 = time.time()
    model.predict(feat.featurize_vecs(pool))
    wall = time.time() - t0
    rows.append(("surrogate_screen_rate", wall / len(pool) * 1e6,
                 f"cands_per_s={len(pool) / wall:.0f} pool={len(pool)} "
                 f"n_fit={ds.n}"))
    return rows


def equal_budget_rows(records, steps: "int | None" = None) -> list[tuple]:
    steps = steps or min(max(STEPS, 128), 256)
    pset = make_pset("system2")
    bs = 32

    def best(kind, seed, **kw):
        return run_search(pset, make_env(ARCH, "system2"), kind, steps=steps,
                          seed=seed, batch_size=bs, **kw).best_reward

    ga = [best("ga", s) for s in SEEDS]
    su = [best("surrogate", s) for s in SEEDS]
    wins = sum(a >= g for a, g in zip(su, ga))
    rows = [("surrogate_vs_ga", 0.0,
             f"surrogate_best={np.mean(su):.4g} ga_best={np.mean(ga):.4g} "
             f"ratio=x{np.mean(su) / max(np.mean(ga), 1e-300):.2f} "
             f"wins={wins}_of_{len(SEEDS)} steps={steps} seeds={len(SEEDS)}")]
    # warm start at half budget, corpus = the fidelity corpus (what a
    # previous campaign's persistent store would hand run_study)
    half = max(steps // 2, 32)
    cold = [run_search(pset, make_env(ARCH, "system2"), "surrogate",
                       steps=half, seed=s, batch_size=bs).best_reward
            for s in SEEDS]
    warm = [run_search(pset, make_env(ARCH, "system2"), "surrogate",
                       steps=half, seed=s, batch_size=bs,
                       warm_start=records).best_reward for s in SEEDS]
    rows.append(("surrogate_warm_start", 0.0,
                 f"warm_best={np.mean(warm):.4g} cold_best={np.mean(cold):.4g} "
                 f"ratio=x{np.mean(warm) / max(np.mean(cold), 1e-300):.2f} "
                 f"steps={half} corpus={len(records)}"))
    return rows


def run(steps: "int | None" = None) -> list[tuple]:
    space, records = make_corpus()
    return fidelity_rows(space, records) + equal_budget_rows(records, steps)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
