"""Shared benchmark plumbing: CSV emission, budgets, and thin delegates to
the first-class registries (`repro.core.systems`) — the env/pset assembly
that used to live here is now the library's own front door."""
from __future__ import annotations

import os
import time
from typing import Any, Callable

from repro.core.compute import Device
from repro.core.env import CosmicEnv
from repro.core.psa import ParameterSet
from repro.core.systems import (SYSTEM_REGISTRY, get_system, system_env,
                                system_pset)

# search budget per DSE run; scaled by BENCH_SCALE env (default keeps the
# whole suite minutes-scale on one CPU core)
STEPS = int(os.environ.get("BENCH_STEPS", "400"))
SEEDS = tuple(range(int(os.environ.get("BENCH_SEEDS", "2"))))

# legacy view over the system registry (benchmark modules index
# SYSTEMS[name] -> (n_npus, device))
SYSTEMS: dict[str, tuple[int, Device]] = {
    name: (p.n_npus, p.device) for name, p in SYSTEM_REGISTRY.items()
}


def make_env(arch: str, system: str, *, batch: int = 1024, seq: int | None = None,
             objective: str = "perf_per_bw", mode: str = "train",
             scenario=None, eval_store: dict | None = None,
             decode_tokens: int = 64, backend: str = "reference") -> CosmicEnv:
    return system_env(arch, system, batch=batch, seq=seq,
                      objective=objective, mode=mode, scenario=scenario,
                      eval_store=eval_store, decode_tokens=decode_tokens,
                      backend=backend)


def make_pset(system: str, *, stacks: set[str] | None = None, max_pp: int = 4) -> ParameterSet:
    return system_pset(system, stacks=stacks, max_pp=max_pp)


# multi-wave load point for the pipelined-vs-analytic disagg comparison
# (shared by examples/dse_request_stream.py and benchmarks/serve_scenarios):
# the small model's tp=1 decode replicas fit memory and decode_batch=2
# forces the 512-request burst through 2 decode waves
PIPELINE_COMPARE_ARCH = "qwen2-1.5b"
PIPELINE_COMPARE_CFG = dict(
    dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
    coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
    multidim_coll="baseline", topology=("ring", "fc", "ring", "switch"),
    npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100),
    prefill_frac=0.875, decode_batch=2)


def compare_pipelined_vs_analytic(batch: int = 512, seq: int = 2048,
                                  decode_tokens: int = 64) -> dict:
    """Evaluate the fixed multi-wave point under both disagg trace models:
    {True: pipelined Evaluation, False: analytic Evaluation}."""
    from repro.core.scenario import DisaggServeScenario

    out = {}
    for pipelined in (True, False):
        sc = DisaggServeScenario(batch, seq, decode_tokens,
                                 pipelined=pipelined)
        env = system_env(PIPELINE_COMPARE_ARCH, "system2", scenario=sc,
                         objective="latency")
        out[pipelined] = env.evaluate_config(PIPELINE_COMPARE_CFG)
    return out


def emit(rows: list[tuple]) -> None:
    """name,us_per_call,derived CSV lines (the run.py contract)."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def timed(fn: Callable[[], Any]) -> tuple[Any, float]:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
