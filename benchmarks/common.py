"""Shared benchmark plumbing: target systems, default PsA, CSV emission."""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Callable

from repro.configs import ARCHS
from repro.core.compute import (SYSTEM_1_DEVICE, SYSTEM_2_DEVICE,
                                SYSTEM_3_DEVICE, Device)
from repro.core.env import CosmicEnv
from repro.core.psa import ParameterSet, paper_psa
from repro.core.topology import system_1, system_2, system_3

# search budget per DSE run; scaled by BENCH_SCALE env (default keeps the
# whole suite minutes-scale on one CPU core)
STEPS = int(os.environ.get("BENCH_STEPS", "400"))
SEEDS = tuple(range(int(os.environ.get("BENCH_SEEDS", "2"))))

SYSTEMS: dict[str, tuple[int, Device]] = {
    "system1": (512, SYSTEM_1_DEVICE),
    "system2": (1024, SYSTEM_2_DEVICE),
    "system3": (2048, SYSTEM_3_DEVICE),
}

# Table-3 baseline stacks used as pinned defaults for single-stack DSE
BASE_DEFAULTS = {
    "system1": dict(sched_policy="fifo", coll_algo=("ring", "ring", "ring", "rhd"),
                    chunks=2, multidim_coll="baseline",
                    topology=("ring", "ring", "ring", "switch"),
                    npus_per_dim=(4, 4, 4, 8), bw_per_dim=(200, 200, 200, 50)),
    "system2": dict(sched_policy="fifo", coll_algo=("ring", "direct", "ring", "rhd"),
                    chunks=2, multidim_coll="baseline",
                    topology=("ring", "fc", "ring", "switch"),
                    npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100)),
    "system3": dict(sched_policy="fifo", coll_algo=("direct", "rhd", "ring", "ring"),
                    chunks=2, multidim_coll="baseline",
                    topology=("fc", "switch", "ring", "ring"),
                    npus_per_dim=(8, 16, 4, 4), bw_per_dim=(450, 100, 50, 50)),
}
WORKLOAD_DEFAULTS = dict(dp=64, pp=1, sp=4, weight_sharded=1)


def make_env(arch: str, system: str, *, batch: int = 1024, seq: int | None = None,
             objective: str = "perf_per_bw", mode: str = "train",
             scenario=None, eval_store: dict | None = None,
             decode_tokens: int = 64) -> CosmicEnv:
    n, dev = SYSTEMS[system]
    spec = ARCHS[arch]
    return CosmicEnv(spec=spec, n_npus=n, device=dev, scenario=scenario,
                     batch=batch, seq=seq or spec.max_seq, mode=mode,
                     decode_tokens=decode_tokens, objective=objective,
                     eval_store=eval_store)


def make_pset(system: str, *, stacks: set[str] | None = None, max_pp: int = 4) -> ParameterSet:
    n, _ = SYSTEMS[system]
    ps = paper_psa(n, max_pp=max_pp)
    if stacks is not None:
        defaults = {**BASE_DEFAULTS[system], **WORKLOAD_DEFAULTS}
        ps = ps.restrict(stacks, defaults)
    return ps


# multi-wave load point for the pipelined-vs-analytic disagg comparison
# (shared by examples/dse_request_stream.py and benchmarks/serve_scenarios):
# the small model's tp=1 decode replicas fit memory and decode_batch=2
# forces the 512-request burst through 2 decode waves
PIPELINE_COMPARE_ARCH = "qwen2-1.5b"
PIPELINE_COMPARE_CFG = dict(
    dp=8, sp=1, pp=1, weight_sharded=0, sched_policy="fifo",
    coll_algo=("ring", "direct", "ring", "rhd"), chunks=2,
    multidim_coll="baseline", topology=("ring", "fc", "ring", "switch"),
    npus_per_dim=(4, 8, 4, 8), bw_per_dim=(400, 200, 150, 100),
    prefill_frac=0.875, decode_batch=2)


def compare_pipelined_vs_analytic(batch: int = 512, seq: int = 2048,
                                  decode_tokens: int = 64) -> dict:
    """Evaluate the fixed multi-wave point under both disagg trace models:
    {True: pipelined Evaluation, False: analytic Evaluation}."""
    from repro.core.scenario import DisaggServeScenario

    out = {}
    for pipelined in (True, False):
        sc = DisaggServeScenario(batch, seq, decode_tokens,
                                 pipelined=pipelined)
        env = CosmicEnv(spec=ARCHS[PIPELINE_COMPARE_ARCH],
                        n_npus=SYSTEMS["system2"][0],
                        device=SYSTEMS["system2"][1], scenario=sc,
                        objective="latency")
        out[pipelined] = env.evaluate_config(PIPELINE_COMPARE_CFG)
    return out


def emit(rows: list[tuple]) -> None:
    """name,us_per_call,derived CSV lines (the run.py contract)."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def timed(fn: Callable[[], Any]) -> tuple[Any, float]:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
