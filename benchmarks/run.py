"""Benchmark harness — one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,roofline] [--steps N]
    PYTHONPATH=src python -m benchmarks.run --study study.json [--resume]

Prints ``name,us_per_call,derived`` CSV (us_per_call = mean simulator/DSE
step cost where applicable).  The DSE-driven modules (fig10, serve) run as
declarative studies; ``--study`` forwards an arbitrary serialized
``StudySpec`` to the ``repro.dse`` campaign runner.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module list")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--study", default=None,
                    help="run a StudySpec JSON via repro.dse instead of the "
                         "benchmark modules")
    ap.add_argument("--resume", action="store_true",
                    help="with --study: skip cells already in the results file")
    args = ap.parse_args()

    if args.study:
        from repro.dse import main as dse_main
        argv = ["run", args.study]
        if args.resume:
            argv.append("--resume")
        if args.steps is not None:
            argv += ["--steps", str(args.steps)]
        raise SystemExit(dse_main(argv))

    from benchmarks import (calibration, fig4_spread, fig6_fullstack,
                            fig8_scalability, fig10_agents, roofline,
                            serve_scenarios, table6_codesign)
    from benchmarks.common import emit

    modules = {
        "fig4": lambda: fig4_spread.run(args.steps),
        "fig6": lambda: fig6_fullstack.run(args.steps),
        "fig8": lambda: fig8_scalability.run(args.steps),
        "fig10": lambda: fig10_agents.run(args.steps),
        "table6": lambda: table6_codesign.run(args.steps),
        "serve": lambda: serve_scenarios.run(args.steps),
        "roofline": lambda: roofline.run(),
        "calibration": lambda: calibration.run(),
    }
    only = [m.strip() for m in args.only.split(",") if m.strip()]
    todo = only or list(modules)

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in todo:
        if name not in modules:
            print(f"unknown benchmark {name!r}; known: {sorted(modules)}", file=sys.stderr)
            raise SystemExit(2)
        emit(modules[name]())
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
