"""Benchmark harness — one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,roofline] [--steps N]
    PYTHONPATH=src python -m benchmarks.run --only backends --json BENCH_backends.json
    PYTHONPATH=src python -m benchmarks.run --study study.json [--resume]

Prints ``name,us_per_call,derived`` CSV (us_per_call = mean simulator/DSE
step cost where applicable).  ``--json PATH`` additionally writes the same
rows as a machine-readable artifact (``derived``'s ``k=v`` tokens parsed
into fields) — the perf-trajectory record CI uploads for the ``backends``
module.  The DSE-driven modules (fig10, serve) run as declarative studies;
``--study`` forwards an arbitrary serialized ``StudySpec`` to the
``repro.dse`` campaign runner.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _derived_fields(derived: str) -> dict:
    """Parse a row's ``k=v`` derived tokens (the repo-wide convention) into
    a dict, keeping floats numeric; bare tokens land under ``note``."""
    out: dict = {}
    notes = []
    for tok in str(derived).split():
        if "=" in tok:
            k, v = tok.split("=", 1)
            try:
                out[k] = float(v.lstrip("x"))
            except ValueError:
                out[k] = v
        else:
            notes.append(tok)
    if notes:
        out["note"] = " ".join(notes)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module list")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as a JSON artifact")
    ap.add_argument("--study", default=None,
                    help="run a StudySpec JSON via repro.dse instead of the "
                         "benchmark modules")
    ap.add_argument("--resume", action="store_true",
                    help="with --study: skip cells already in the results file")
    args = ap.parse_args()

    if args.study:
        from repro.dse import main as dse_main
        argv = ["run", args.study]
        if args.resume:
            argv.append("--resume")
        if args.steps is not None:
            argv += ["--steps", str(args.steps)]
        raise SystemExit(dse_main(argv))

    from benchmarks import (calibration, fig4_spread, fig6_fullstack,
                            fig8_scalability, fig10_agents, roofline,
                            serve_scenarios, surrogate_bench,
                            table6_codesign)
    from benchmarks.common import emit

    import os

    modules = {
        "fig4": lambda: fig4_spread.run(args.steps),
        "fig6": lambda: fig6_fullstack.run(args.steps),
        "fig8": lambda: fig8_scalability.run(args.steps),
        "fig10": lambda: fig10_agents.run(args.steps),
        "table6": lambda: table6_codesign.run(args.steps),
        "serve": lambda: serve_scenarios.run(args.steps),
        "fleet": lambda: serve_scenarios.fleet_rows(args.steps),
        "surrogate": lambda: surrogate_bench.run(args.steps),
        "roofline": lambda: roofline.run(),
        "calibration": lambda: calibration.run(),
        # the backend perf-trajectory rows alone (trace size scales with
        # BENCH_BACKEND_REQUESTS so CI can run a small-trace variant)
        "backends": lambda: fig10_agents.backend_rows(
            n_requests=int(os.environ.get("BENCH_BACKEND_REQUESTS", "256"))),
    }
    only = [m.strip() for m in args.only.split(",") if m.strip()]
    todo = only or [m for m in modules if m != "backends"]

    print("name,us_per_call,derived")
    t0 = time.time()
    all_rows: list[tuple] = []
    for name in todo:
        if name not in modules:
            print(f"unknown benchmark {name!r}; known: {sorted(modules)}", file=sys.stderr)
            raise SystemExit(2)
        rows = modules[name]()
        all_rows.extend(rows)
        emit(rows)
    wall = time.time() - t0
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"modules": todo, "wall_s": round(wall, 2),
                       "rows": [{"name": n, "us_per_call": us,
                                 **_derived_fields(d)}
                                for n, us, d in all_rows]}, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total wall: {wall:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
