"""Fig. 8: scalability — System 3 (2,048 NPUs), ViT-Large + GPT3-175B,
global batch 1,024..16,384; workload-only vs full-stack (paper: full-stack
wins 1.71-3.75x on ViT-Large, 4.19-5.05x on GPT3-175B)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SEEDS, STEPS, emit, make_env, make_pset, timed
from repro.core.dse import run_search

BATCHES = (1024, 2048, 4096, 8192, 16384)


def run_one(arch: str, batch: int, steps: int) -> tuple[float, float]:
    full_ps = make_pset("system3")
    wl_ps = make_pset("system3", stacks={"workload"})
    full = max(run_search(full_ps, make_env(arch, "system3", batch=batch),
                          "ga", steps=steps, seed=s).best_reward for s in SEEDS)
    wl = max(run_search(wl_ps, make_env(arch, "system3", batch=batch),
                        "ga", steps=steps, seed=s).best_reward for s in SEEDS)
    return full, wl


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or max(STEPS // 2, 100)
    rows = []
    for arch in ("vit-large", "gpt3-175b"):
        gains = []
        t_us = 0.0
        for batch in BATCHES:
            (full, wl), us = timed(lambda: run_one(arch, batch, steps))
            t_us += us
            gains.append(full / max(wl, 1e-30))
        detail = " ".join(f"b{b}=x{g:.2f}" for b, g in zip(BATCHES, gains))
        rows.append((f"fig8_{arch}_system3", t_us / (len(BATCHES) * steps * 2),
                     f"fullstack_vs_workload {detail}"))
    return rows


if __name__ == "__main__":
    emit(run())
