"""Fig. 9/10: agent comparison — RW/GA/ACO/BO on full-stack GPT3-175B DSE:
convergence speed (steps to peak), final reward, and distinctness of the
discovered configurations."""
from __future__ import annotations

import numpy as np

from benchmarks.common import STEPS, emit, make_env, make_pset, timed
from repro.core.dse import run_search

AGENTS = ("rw", "ga", "aco", "bo")


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or max(STEPS, 300)
    rows = []
    results = {}
    for agent in AGENTS:
        # BO's cubic GP cost caps its budget
        s = min(steps, 200) if agent == "bo" else steps
        res, us = timed(lambda: run_search(
            make_pset("system2"), make_env("gpt3-175b", "system2"),
            agent, steps=s, seed=0))
        results[agent] = res
        rows.append((f"fig10_{agent}", us / s,
                     f"best={res.best_reward:.3e} steps_to_peak={res.steps_to_peak} "
                     f"invalid_rate={res.invalid_rate:.2f}"))
    # Fig 9: distinct high-performing configs across agents
    cfgs = [tuple(sorted((k, str(v)) for k, v in r.best_config.items()))
            for r in results.values() if r.best_config]
    rows.append(("fig9_distinct_optima", 0.0,
                 f"distinct={len(set(cfgs))}_of_{len(cfgs)}"))
    return rows


if __name__ == "__main__":
    emit(run())
