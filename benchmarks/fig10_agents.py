"""Fig. 9/10: agent comparison — RW/GA/ACO/BO on full-stack GPT3-175B DSE:
convergence speed (steps to peak), final reward, and distinctness of the
discovered configurations.  The whole comparison is ONE declarative study
(four agents, one seed, shared eval_store): the campaign runs the batched
engine in its sequential mode (batch_size=1: per-point feedback, like the
paper's Fig. 10, so steps_to_peak is comparable across agents) but still
rides the trace/collective caches; the throughput row measures the
population path (batch 32) against the uncached sequential loop (the seed
baseline)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import STEPS, emit, make_env, make_pset
from repro.core import cache
from repro.core.dse import run_search
from repro.core.study import StudySpec, run_study

AGENTS = ("rw", "ga", "aco", "bo")


def dse_throughput(steps: int = 500, arch: str = "gpt3-13b") -> tuple[float, float]:
    """(uncached sequential, batched+cached) points/sec on one GA search —
    the acceptance measurement for the batched engine (uncached sequential
    is the in-process proxy for the seed evaluation loop)."""
    was_enabled = cache.caches_enabled()
    try:
        cache.set_caches_enabled(False)
        t0 = time.time()
        run_search(make_pset("system2"), make_env(arch, "system2"), "ga",
                   steps=steps, seed=0)
        seq = steps / (time.time() - t0)
        cache.set_caches_enabled(True)
        cache.clear_all_caches()
        t0 = time.time()
        run_search(make_pset("system2"), make_env(arch, "system2"), "ga",
                   steps=steps, seed=0, batch_size=32)
        batched = steps / (time.time() - t0)
    finally:
        cache.set_caches_enabled(was_enabled)
    return seq, batched


BACKEND_ROW_ORDER = ("reference", "jax-unfused", "jax")


def backend_throughput(points: int = 32, n_requests: int = 256,
                       repeats: int = 3) -> "list[dict] | None":
    """Points/sec per simulation backend (reference / jax-unfused / jax)
    evaluating one agent population of collective/network stacks over a
    LARGE pipelined request-stream trace — the acceptance measurement for
    the backend API and the fused-evaluation path.  All rows run through
    ``CosmicEnv.step_batch`` (the PR-1 batched engine); the jax rows swap
    the per-point heapq event loop for one shared-plan ``simulate_batch``
    sweep, and the fused ``jax`` row additionally prices all durations
    inside the same compiled call.  Each row carries the backend's
    duration-pass vs compiled-sweep wall split (``last_timings``) so the
    bottleneck claim stays measurable.  None when jax is unavailable."""
    from repro.core.backends import backend_available, get_backend
    from repro.core.scenario import RequestStreamScenario

    if not backend_available("jax"):
        return None
    # n_requests=256 Poisson requests through disaggregated pools -> a
    # ~26k-op pipelined multi-wave trace; trace-shaping knobs are pinned so
    # the whole population shares ONE scheduling plan
    scenario = RequestStreamScenario(n_requests=n_requests, seq=2048,
                                     decode_tokens=64, rate_rps=32.0, seed=0)
    pinned = dict(dp=8, sp=1, pp=1, weight_sharded=0,
                  topology=("ring", "fc", "ring", "switch"),
                  npus_per_dim=(4, 8, 4, 8),
                  prefill_frac=0.5, decode_batch=8, batch_window_ms=50.0,
                  max_inflight=2)
    rng = np.random.default_rng(0)
    algos = ("ring", "direct", "rhd", "dbt")
    cfgs = []
    for _ in range(points):
        cfgs.append(dict(
            pinned,
            coll_algo=tuple(rng.choice(algos) for _ in range(4)),
            chunks=int(rng.choice((2, 4, 8, 16))),
            sched_policy=str(rng.choice(("fifo", "lifo"))),
            multidim_coll=str(rng.choice(("baseline", "blueconnect"))),
            bw_per_dim=tuple(int(b) for b in
                             rng.choice(range(50, 501, 50), size=4))))
    rows = []
    for backend in BACKEND_ROW_ORDER:
        env = make_env("qwen2-1.5b", "system2", scenario=scenario,
                       objective="goodput", backend=backend)
        # warm trace caches + compile the sweep at the population shape
        env.step_batch(cfgs)
        best = float("inf")
        for _ in range(1 if backend == "reference" else repeats):
            env.clear_memo()
            t0 = time.time()
            env.step_batch(cfgs)
            best = min(best, time.time() - t0)
        timings = getattr(get_backend(backend), "last_timings", {})
        rows.append({
            "backend": backend, "points": points, "n_requests": n_requests,
            "pts_per_s": len(cfgs) / best, "ms_per_gen": best * 1e3,
            "durations_ms": timings.get("durations_s", float("nan")) * 1e3,
            "sweep_ms": timings.get("sweep_s", float("nan")) * 1e3,
        })
    return rows


def verify_overhead_rows(n_requests: int = 256) -> list[tuple]:
    """Static-verification cost vs a reference-backend evaluation on the
    acceptance trace: the ISSUE-8 bound is overhead < 5%.  ``verify_ms``
    re-derives the structural verdict + contextual checks each rep (the
    per-evaluation steady state — the plan-level array views, built once
    with the plan, stay amortized exactly like the plan itself);
    ``memo_us`` is the memoized-report path every later evaluation of the
    same trace pays.  Works without jax (reference backend only)."""
    from repro.core.analysis import verify_trace
    from repro.core.scenario import RequestStreamScenario
    from repro.core.simulator import simulate

    scenario = RequestStreamScenario(n_requests=n_requests, seq=2048,
                                     decode_tokens=64, rate_rps=32.0, seed=0)
    env = make_env("qwen2-1.5b", "system2", scenario=scenario,
                   objective="goodput", backend="reference")
    cfg = dict(dp=8, sp=1, pp=1, weight_sharded=0,
               topology=("ring", "fc", "ring", "switch"),
               npus_per_dim=(4, 8, 4, 8), bw_per_dim=(100, 200, 300, 400),
               coll_algo=("ring", "direct", "rhd", "dbt"), chunks=4,
               sched_policy="fifo", multidim_coll="baseline",
               prefill_frac=0.5, decode_batch=8, batch_window_ms=50.0,
               max_inflight=2)
    job = env.scenario.sim_job(env.context(cfg))
    call = job.calls[0]
    simulate(call.trace, call.cfg, call.par, pools=call.pools)  # warm plan
    verify_trace(call.trace, call.cfg, call.par, call.pools)
    sim_s = float("inf")
    for _ in range(3):
        t0 = time.time()
        simulate(call.trace, call.cfg, call.par, pools=call.pools)
        sim_s = min(sim_s, time.time() - t0)
    ver_s = float("inf")
    for _ in range(5):
        if hasattr(call.trace, "_verify_report"):
            del call.trace._verify_report
        t0 = time.time()
        verify_trace(call.trace, call.cfg, call.par, call.pools)
        ver_s = min(ver_s, time.time() - t0)
    t0 = time.time()
    for _ in range(100):
        verify_trace(call.trace, call.cfg, call.par, call.pools)
    memo_us = (time.time() - t0) / 100 * 1e6
    return [("verify_overhead", ver_s * 1e6,
             f"verify_ms={ver_s * 1e3:.3f} simulate_ms={sim_s * 1e3:.2f} "
             f"overhead=x{ver_s / max(sim_s, 1e-12):.4f} "
             f"memo_us={memo_us:.1f} n_ops={len(call.trace.ops)}")]


def backend_rows(points: int = 32, n_requests: int = 256) -> list[tuple]:
    """The ``backend_throughput`` measurement as emit()-able benchmark rows
    (one per backend plus a speedup summary) — also the payload of the
    ``BENCH_backends.json`` perf-trajectory artifact.  The static-analysis
    overhead row rides along (it needs only the reference backend, so it
    emits even where jax is unavailable)."""
    bt = backend_throughput(points=points, n_requests=n_requests)
    if bt is None:
        return [("backend_throughput", 0.0, "jax_unavailable"),
                *verify_overhead_rows(n_requests=n_requests)]
    rows = []
    for r in bt:
        rows.append((f"backend_throughput[{r['backend']}]", 0.0,
                     f"pts_per_s={r['pts_per_s']:.1f} "
                     f"ms_per_gen={r['ms_per_gen']:.1f} "
                     f"durations_ms={r['durations_ms']:.1f} "
                     f"sweep_ms={r['sweep_ms']:.1f} "
                     f"points={r['points']} n_requests={r['n_requests']}"))
    by = {r["backend"]: r["pts_per_s"] for r in bt}
    rows.append(("backend_throughput", 0.0,
                 f"ref_pts_per_s={by['reference']:.1f} "
                 f"jax_pts_per_s={by['jax-unfused']:.1f} "
                 f"fused_pts_per_s={by['jax']:.1f} "
                 f"fused_vs_ref=x{by['jax'] / max(by['reference'], 1e-9):.2f} "
                 f"fused_vs_jax=x{by['jax'] / max(by['jax-unfused'], 1e-9):.2f}"))
    rows.extend(verify_overhead_rows(n_requests=n_requests))
    return rows


def agents_study(steps: int) -> StudySpec:
    """All four agents over the same space as one campaign — any design
    point one agent visited is free for the rest (shared eval store).
    BO's cubic GP cost caps its per-cell budget."""
    return StudySpec(
        name="fig10-agents", arch="gpt3-175b", system="system2",
        scenario="train", objective="perf_per_bw",
        agents=tuple({"kind": a, "steps": min(steps, 200)} if a == "bo"
                     else a for a in AGENTS),
        seeds=(0,), steps=steps, batch_size=1)


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or max(STEPS, 300)
    rows = []
    study = run_study(agents_study(steps))
    for cell in study.outcomes:
        res = cell.result
        rows.append((f"fig10_{cell.agent}", res.wall_s * 1e6 / res.steps,
                     f"best={res.best_reward:.3e} steps_to_peak={res.steps_to_peak} "
                     f"invalid_rate={res.invalid_rate:.2f} "
                     f"points_per_s={res.points_per_s:.0f}"))
    lookups = study.store_hits + study.store_misses
    rows.append(("fig10_eval_store", 0.0,
                 f"hits={study.store_hits} misses={study.store_misses} "
                 f"hit_rate={study.store_hits / max(lookups, 1):.2f} "
                 f"distinct_points={study.distinct_points}"))
    # Fig 9: distinct high-performing configs across agents
    cfgs = [tuple(sorted((k, str(v)) for k, v in o.result.best_config.items()))
            for o in study.outcomes if o.result.best_config]
    rows.append(("fig9_distinct_optima", 0.0,
                 f"distinct={len(set(cfgs))}_of_{len(cfgs)}"))
    seq, batched = dse_throughput(steps=steps)  # 500 via BENCH_STEPS=500
    rows.append(("dse_throughput", 0.0,
                 f"seq_pts_per_s={seq:.0f} batched_pts_per_s={batched:.0f} "
                 f"speedup=x{batched / max(seq, 1e-9):.2f}"))
    rows.extend(backend_rows())
    return rows


if __name__ == "__main__":
    emit(run())
