"""Fig. 9/10: agent comparison — RW/GA/ACO/BO on full-stack GPT3-175B DSE:
convergence speed (steps to peak), final reward, and distinctness of the
discovered configurations.  The convergence rows run the batched engine in
its sequential mode (batch_size=1: per-point feedback, like the paper's
Fig. 10, so steps_to_peak is comparable across agents) but still ride the
trace/collective caches; the throughput row measures the population path
(batch 32) against the uncached sequential loop (the seed baseline)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import STEPS, emit, make_env, make_pset, timed
from repro.core import cache
from repro.core.dse import run_search

AGENTS = ("rw", "ga", "aco", "bo")


def dse_throughput(steps: int = 500, arch: str = "gpt3-13b") -> tuple[float, float]:
    """(uncached sequential, batched+cached) points/sec on one GA search —
    the acceptance measurement for the batched engine (uncached sequential
    is the in-process proxy for the seed evaluation loop)."""
    was_enabled = cache.caches_enabled()
    try:
        cache.set_caches_enabled(False)
        t0 = time.time()
        run_search(make_pset("system2"), make_env(arch, "system2"), "ga",
                   steps=steps, seed=0)
        seq = steps / (time.time() - t0)
        cache.set_caches_enabled(True)
        cache.clear_all_caches()
        t0 = time.time()
        run_search(make_pset("system2"), make_env(arch, "system2"), "ga",
                   steps=steps, seed=0, batch_size=32)
        batched = steps / (time.time() - t0)
    finally:
        cache.set_caches_enabled(was_enabled)
    return seq, batched


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or max(STEPS, 300)
    rows = []
    results = {}
    # all four agents explore the same space over the same system: a shared
    # eval store means a design point any agent already visited is free for
    # the rest of the sweep
    store: dict = {}
    store_hits = store_misses = 0
    for agent in AGENTS:
        # BO's cubic GP cost caps its budget
        s = min(steps, 200) if agent == "bo" else steps
        env = make_env("gpt3-175b", "system2", eval_store=store)
        res, us = timed(lambda: run_search(
            make_pset("system2"), env, agent, steps=s, seed=0))
        store_hits += env.store_hits
        store_misses += env.store_misses
        results[agent] = res
        rows.append((f"fig10_{agent}", us / s,
                     f"best={res.best_reward:.3e} steps_to_peak={res.steps_to_peak} "
                     f"invalid_rate={res.invalid_rate:.2f} "
                     f"points_per_s={res.points_per_s:.0f}"))
    lookups = store_hits + store_misses
    rows.append(("fig10_eval_store", 0.0,
                 f"hits={store_hits} misses={store_misses} "
                 f"hit_rate={store_hits / max(lookups, 1):.2f} "
                 f"distinct_points={len(store)}"))
    # Fig 9: distinct high-performing configs across agents
    cfgs = [tuple(sorted((k, str(v)) for k, v in r.best_config.items()))
            for r in results.values() if r.best_config]
    rows.append(("fig9_distinct_optima", 0.0,
                 f"distinct={len(set(cfgs))}_of_{len(cfgs)}"))
    seq, batched = dse_throughput(steps=steps)  # 500 via BENCH_STEPS=500
    rows.append(("dse_throughput", 0.0,
                 f"seq_pts_per_s={seq:.0f} batched_pts_per_s={batched:.0f} "
                 f"speedup=x{batched / max(seq, 1e-9):.2f}"))
    return rows


if __name__ == "__main__":
    emit(run())
