"""Simulator <-> compiler calibration (the ASTRA-sim cross-validation
analogue): compare the WTG's analytical per-NPU FLOPs and collective bytes
against the loop-aware HLO totals of the dry-run for the production mesh.

The production layout (batch over 'data', TP+SP sharing 'model') maps to
Parallelism(256, dp=16, sp=1, pp=1) -> tp=16.  Expected systematic gaps,
reported not hidden:
  * HLO flops > sim flops: remat recompute (+~33%) + elementwise ops;
  * HLO collective bytes > sim bytes: ZeRO-3 weight gathers per microbatch,
    backward re-gathers under remat, CPU f32 carriage (2x vs TPU bf16).
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.core.bridge import calibrate
from repro.core.hlo_analysis import CostTotals
from repro.core.workload import Parallelism, generate_trace


def _totals_from_record(rec: dict) -> CostTotals:
    t = CostTotals()
    t.flops = rec["hlo"]["flops_per_device"]
    t.bytes_accessed = rec["hlo"]["bytes_per_device"]
    for k, v in rec["hlo"]["collective_bytes"].items():
        t.collective_bytes[k] = v
    return t


def run(out_dir: str = "results/dryrun") -> list[tuple]:
    rows = []
    for f in sorted(glob.glob(f"{out_dir}/*__train_4k__pod.json")):
        if len(Path(f).stem.split("__")) > 3:
            continue
        rec = json.loads(Path(f).read_text())
        if rec["status"] != "ok":
            continue
        spec = ARCHS[rec["arch"]]
        shape = SHAPES["train_4k"]
        par = Parallelism(rec["n_chips"], dp=16, sp=1, pp=1, weight_sharded=True)
        trace = generate_trace(spec, par, batch=shape.global_batch, seq=shape.seq_len)
        cal = calibrate(trace, _totals_from_record(rec), rec["n_chips"])
        rows.append((
            f"calibration_{rec['arch']}_train_4k", 0.0,
            f"sim/hlo_flops={cal.flops_ratio:.2f} "
            f"sim/hlo_coll_bytes={cal.coll_bytes_ratio:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
