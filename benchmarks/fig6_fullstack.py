"""Fig. 6 + Fig. 7: full-stack vs single-stack DSE.

For Systems 1 and 2, run COSMIC restricted to workload-only,
collective-only, network-only, and the full stack; report best reward per
scenario normalized to full-stack (paper: full-stack wins 1.50-48.41x on
perf/BW-NPU and 3.94-127.17x on perf/network-cost).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SEEDS, STEPS, emit, make_env, make_pset, timed
from repro.core.dse import run_search

SCENARIOS = {
    "workload_only": {"workload"},
    "collective_only": {"collective"},
    "network_only": {"network"},
    "full_stack": None,
}


def run_one(system: str, objective: str, steps: int) -> dict[str, float]:
    best: dict[str, float] = {}
    for name, stacks in SCENARIOS.items():
        ps = make_pset(system, stacks=stacks)
        vals = []
        for seed in SEEDS:
            env = make_env("gpt3-175b", system, objective=objective)
            vals.append(run_search(ps, env, "ga", steps=steps, seed=seed).best_reward)
        best[name] = float(np.max(vals))
    return best


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or STEPS
    rows = []
    for fig, objective in (("fig6", "perf_per_bw"), ("fig7", "perf_per_cost")):
        for system in ("system1", "system2"):
            best, us = timed(lambda: run_one(system, objective, steps))
            full = best["full_stack"]
            gains = {k: full / max(v, 1e-30) for k, v in best.items() if k != "full_stack"}
            lo, hi = min(gains.values()), max(gains.values())
            detail = " ".join(f"{k}=x{v:.2f}" for k, v in gains.items())
            rows.append((f"{fig}_{system}_{objective}", us / steps / 4,
                         f"fullstack_gain={lo:.2f}-{hi:.2f}x {detail}"))
    return rows


if __name__ == "__main__":
    emit(run())
