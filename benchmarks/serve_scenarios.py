"""Scenario sweep: monolithic serving vs disaggregated prefill/decode vs
request-stream (arrival-driven, queueing) vs multi-tenant partitioning,
each a full-stack GA search on gpt3-13b/system2.

Rows report best end-to-end latency (serving), the disagg-vs-monolithic
latency ratio (the disaggregation win), the pipelined-vs-analytic
multi-wave ratio, SLO goodput + TTFT/TPOT percentiles for the request
stream, and weighted SLO attainment for the multi-tenant cluster.
"""
from __future__ import annotations

from benchmarks.common import (STEPS, SYSTEMS, compare_pipelined_vs_analytic,
                               emit, make_env, make_pset)
from repro.configs import ARCHS
from repro.core.dse import run_search
from repro.core.scenario import (DisaggServeScenario, MultiTenantScenario,
                                 RequestStreamScenario, Tenant, TrainScenario,
                                 scenario_psa)

N_NPUS = SYSTEMS["system2"][0]


def _search(scenario, objective: str, steps: int, arch: str = "gpt3-13b"):
    pset = scenario_psa(make_pset("system2"), scenario, N_NPUS)
    with make_env(arch, "system2", scenario=scenario,
                  objective=objective) as env:
        return run_search(pset, env, "ga", steps=steps, seed=0,
                          batch_size=32)


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or STEPS
    rows = []

    mono = _search(TrainScenario(64, 2048, "serve"), "latency", steps)
    rows.append(("serve_monolithic", 0.0,
                 f"best_latency_ms={mono.best_latency_ms:.1f} "
                 f"points_per_s={mono.points_per_s:.0f}"))

    dis = _search(DisaggServeScenario(64, 2048), "latency", steps)
    cfg = dis.best_config or {}
    rows.append(("serve_disagg", 0.0,
                 f"best_latency_ms={dis.best_latency_ms:.1f} "
                 f"prefill_frac={cfg.get('prefill_frac')} "
                 f"decode_batch={cfg.get('decode_batch')} "
                 f"points_per_s={dis.points_per_s:.0f}"))
    rows.append(("serve_disagg_vs_monolithic", 0.0,
                 f"speedup=x{mono.best_latency_ms / max(dis.best_latency_ms, 1e-9):.2f}"))

    # pipelined multi-wave trace vs analytic single-wave composition on a
    # fixed multi-wave point (no search: the trace model is the variable)
    cmp = compare_pipelined_vs_analytic()
    rows.append(("serve_pipelined_vs_analytic", 0.0,
                 f"pipelined_ms={cmp[True].latency_ms:.1f} "
                 f"analytic_ms={cmp[False].latency_ms:.1f} "
                 f"speedup=x{cmp[False].latency_ms / max(cmp[True].latency_ms, 1e-9):.3f}"))

    stream_sc = RequestStreamScenario(n_requests=64, seq=2048,
                                      decode_tokens=64, rate_rps=8.0)
    stream = _search(stream_sc, "goodput", steps)
    sd = {}
    if stream.best_config:
        with make_env("gpt3-13b", "system2", scenario=stream_sc,
                      objective="goodput") as env:
            sd = env.evaluate_config(stream.best_config).detail
    rows.append(("serve_request_stream", 0.0,
                 f"goodput_rps={stream.best_reward:.2f} "
                 f"ttft_p99_ms={sd.get('ttft_p99_ms', 0):.1f} "
                 f"tpot_p99_ms={sd.get('tpot_p99_ms', 0):.2f} "
                 f"waves={sd.get('waves')} "
                 f"points_per_s={stream.points_per_s:.0f}"))

    tenants = (
        Tenant("train-13b", ARCHS["gpt3-13b"], 512, 2048, "train",
               slo_ms=4e5, weight=2.0),
        Tenant("serve-13b", ARCHS["gpt3-13b"], 64, 2048, "serve", slo_ms=3e3),
        Tenant("serve-1.5b", ARCHS["qwen2-1.5b"], 64, 2048, "serve",
               slo_ms=3e2, device_name="system3-h100"),
    )
    mt = _search(MultiTenantScenario(tenants=tenants), "perf_per_bw", steps)
    sizes = (mt.best_config or {}).get("tenant_npus")
    rows.append(("multi_tenant", 0.0,
                 f"weighted_slo_attainment={mt.best_reward:.3f} "
                 f"tenant_npus={sizes} points_per_s={mt.points_per_s:.0f}"))
    return rows


if __name__ == "__main__":
    emit(run())
