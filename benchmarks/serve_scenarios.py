"""Scenario sweep: monolithic serving vs disaggregated prefill/decode vs
request-stream (arrival-driven, queueing) vs multi-tenant partitioning,
each a declarative full-stack GA study on gpt3-13b/system2.

Rows report best end-to-end latency (serving), the disagg-vs-monolithic
latency ratio (the disaggregation win), the pipelined-vs-analytic
multi-wave ratio, SLO goodput + TTFT/TPOT percentiles for the request
stream, and weighted SLO attainment for the multi-tenant cluster.
"""
from __future__ import annotations

from benchmarks.common import STEPS, compare_pipelined_vs_analytic, emit
from repro.core.dse import SearchResult
from repro.core.study import StudySpec, run_study


def _study(name: str, scenario: str, params: dict, objective: str,
           steps: int, arch: str = "gpt3-13b",
           overrides: dict | None = None) -> tuple[StudySpec, SearchResult]:
    spec = StudySpec(name=name, arch=arch, system="system2",
                     scenario=scenario, scenario_params=params,
                     objective=objective, agents=("ga",), seeds=(0,),
                     steps=steps, batch_size=32,
                     psa_overrides=overrides or {})
    return spec, run_study(spec).outcomes[0].result


def run(steps: int | None = None) -> list[tuple]:
    steps = steps or STEPS
    rows = []

    _, mono = _study("serve-monolithic", "train",
                     dict(batch=64, seq=2048, mode="serve"), "latency", steps)
    rows.append(("serve_monolithic", 0.0,
                 f"best_latency_ms={mono.best_latency_ms:.1f} "
                 f"points_per_s={mono.points_per_s:.0f}"))

    _, dis = _study("serve-disagg", "disagg-serve", dict(batch=64, seq=2048),
                    "latency", steps)
    cfg = dis.best_config or {}
    rows.append(("serve_disagg", 0.0,
                 f"best_latency_ms={dis.best_latency_ms:.1f} "
                 f"prefill_frac={cfg.get('prefill_frac')} "
                 f"decode_batch={cfg.get('decode_batch')} "
                 f"points_per_s={dis.points_per_s:.0f}"))
    rows.append(("serve_disagg_vs_monolithic", 0.0,
                 f"speedup=x{mono.best_latency_ms / max(dis.best_latency_ms, 1e-9):.2f}"))

    # pipelined multi-wave trace vs analytic single-wave composition on a
    # fixed multi-wave point (no search: the trace model is the variable)
    cmp = compare_pipelined_vs_analytic()
    rows.append(("serve_pipelined_vs_analytic", 0.0,
                 f"pipelined_ms={cmp[True].latency_ms:.1f} "
                 f"analytic_ms={cmp[False].latency_ms:.1f} "
                 f"speedup=x{cmp[False].latency_ms / max(cmp[True].latency_ms, 1e-9):.3f}"))

    stream_spec, stream = _study(
        "serve-request-stream", "request-stream",
        dict(n_requests=64, seq=2048, decode_tokens=64, rate_rps=8.0),
        "goodput", steps)
    sd = {}
    if stream.best_config:
        sd = stream_spec.build_env().evaluate_config(stream.best_config).detail
    rows.append(("serve_request_stream", 0.0,
                 f"goodput_rps={stream.best_reward:.2f} "
                 f"ttft_p99_ms={sd.get('ttft_p99_ms', 0):.1f} "
                 f"tpot_p99_ms={sd.get('tpot_p99_ms', 0):.2f} "
                 f"waves={sd.get('waves')} "
                 f"points_per_s={stream.points_per_s:.0f}"))

    tenants = [
        dict(name="train-13b", arch="gpt3-13b", batch=512, seq=2048,
             phase="train", slo_ms=4e5, weight=2.0),
        dict(name="serve-13b", arch="gpt3-13b", batch=64, seq=2048,
             phase="serve", slo_ms=3e3),
        dict(name="serve-1.5b", arch="qwen2-1.5b", batch=64, seq=2048,
             phase="serve", slo_ms=3e2, device_name="system3-h100"),
    ]
    _, mt = _study("multi-tenant", "multi-tenant", dict(tenants=tenants),
                   "perf_per_bw", steps)
    sizes = (mt.best_config or {}).get("tenant_npus")
    rows.append(("multi_tenant", 0.0,
                 f"weighted_slo_attainment={mt.best_reward:.3f} "
                 f"tenant_npus={sizes} points_per_s={mt.points_per_s:.0f}"))
    return rows


# a diurnal day on a 4-replica qwen fleet: the traffic troughs are where
# autoscaling earns its goodput-per-dollar uplift over static provisioning
_FLEET_PARAMS = dict(n_requests=512, seq=1024, decode_tokens=16,
                     arrival="diurnal", rate_rps=24.0, period_s=30.0,
                     replicas=4, epoch_s=5.0, max_batch=16)


def fleet_rows(steps: int | None = None) -> list[tuple]:
    """Fleet-searched (router x autoscaler x engine x parallelism) vs the
    best STATIC UNIFORM fleet the same search budget can find (fleet knobs
    pinned to round-robin / no autoscaling), on goodput per dollar."""
    steps = steps or STEPS

    _, base = _study(
        "fleet-static-uniform", "fleet", _FLEET_PARAMS, "goodput_per_dollar",
        steps, arch="qwen2-1.5b",
        overrides=dict(router="round-robin", autoscale_target=0.0,
                       autoscale_cooldown_s=10.0))
    spec, searched = _study("fleet-searched", "fleet", _FLEET_PARAMS,
                            "goodput_per_dollar", steps, arch="qwen2-1.5b")

    # the fleet knobs are cheap relative to the engine/parallelism search:
    # polish both winners with the exhaustive router x autoscaler grid (it
    # contains the pinned static point, so searched >= static by
    # construction and strictly beats it whenever any policy helps)
    env, sc = spec.build_env(), spec.build_scenario()
    best_reward, best_cfg = searched.best_reward, searched.best_config
    for seed_cfg in {id(c): c for c in (searched.best_config,
                                        base.best_config) if c}.values():
        for router in sc.routers:
            for target in sc.autoscale_targets:
                for cd in sc.autoscale_cooldowns_s:
                    cfg = dict(seed_cfg, router=router,
                               autoscale_target=target,
                               autoscale_cooldown_s=cd)
                    ev = env.evaluate_config(cfg)
                    if ev.valid and ev.reward > best_reward:
                        best_reward, best_cfg = ev.reward, cfg

    sd = env.evaluate_config(best_cfg).detail if best_cfg else {}
    rows = [
        ("fleet_static_uniform", 0.0,
         f"goodput_per_dollar={base.best_reward:.3f} "
         f"points_per_s={base.points_per_s:.0f}"),
        ("fleet_searched", 0.0,
         f"goodput_per_dollar={best_reward:.3f} "
         f"router={(best_cfg or {}).get('router')} "
         f"autoscale_target={(best_cfg or {}).get('autoscale_target')} "
         f"goodput_rps={sd.get('goodput_rps', 0):.2f} "
         f"provisioned_cost={sd.get('provisioned_cost', 0):.0f} "
         f"points_per_s={searched.points_per_s:.0f}"),
        ("fleet_searched_vs_static", 0.0,
         f"uplift=x{best_reward / max(base.best_reward, 1e-9):.3f} "
         f"beats_static={best_reward > base.best_reward}"),
    ]
    return rows


if __name__ == "__main__":
    emit(run())
